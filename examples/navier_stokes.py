import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Pseudo-spectral incompressible Navier-Stokes on a pencil-decomposed box —
the paper's motivating application (DNS of turbulence; Sec. 1).

Taylor-Green vortex in [0, 2pi)^3, vorticity-free projection form:

    du/dt = P[-(u . grad) u] - nu k^2 u_hat      (spectral space)

Nonlinear term evaluated pseudo-spectrally (3 inverse + 9 forward 1-D FFT
sweeps per evaluation, 2/3-rule dealiased), Leray projection in spectral
space, RK2 time stepping.  Every transform is the paper's fused-exchange
pencil FFT.  Checks: incompressibility preserved and kinetic energy decays
at the viscous rate (dE/dt = -2 nu Z at t=0 for Taylor-Green).

Run:  PYTHONPATH=src python examples/navier_stokes.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
N = 48
NU = 0.05
DT = 5e-3
STEPS = 12

plan = ParallelFFT(mesh, (N, N, N), grid=("p0", "p1"), real=True, method="fused")

# wavenumbers on the r2c output grid
kx = jnp.fft.fftfreq(N, 1 / N)
ky = jnp.fft.fftfreq(N, 1 / N)
kz = jnp.arange(N // 2 + 1, dtype=jnp.float32)
KX = kx[:, None, None]
KY = ky[None, :, None]
KZ = kz[None, None, :]
K2 = KX**2 + KY**2 + KZ**2
K2_safe = jnp.where(K2 == 0, 1.0, K2)
# 2/3-rule dealiasing mask
cut = N // 3
DEALIAS = ((jnp.abs(KX) < cut) & (jnp.abs(KY) < cut) & (KZ < cut)).astype(jnp.float32)


def fwd(u):
    return plan.forward(u)


def bwd(u_hat):
    return plan.backward(u_hat)


def project(v_hat):
    """Leray projection: remove the compressible part (k . v) k / |k|^2."""
    div = KX * v_hat[0] + KY * v_hat[1] + KZ * v_hat[2]
    return jnp.stack([v_hat[0] - KX * div / K2_safe,
                      v_hat[1] - KY * div / K2_safe,
                      v_hat[2] - KZ * div / K2_safe])


def rhs(u_hat):
    """P[-(u.grad)u] - nu k^2 u_hat, pseudo-spectral + dealiased."""
    u = jnp.stack([bwd(u_hat[i]) for i in range(3)])           # physical
    grads = jnp.stack([
        jnp.stack([bwd(1j * k * u_hat[i]) for k in (KX, KY, KZ)])
        for i in range(3)])                                    # du_i/dx_j
    conv = jnp.einsum("jxyz,ijxyz->ixyz", u, grads)            # (u.grad)u
    conv_hat = jnp.stack([fwd(conv[i]) * DEALIAS for i in range(3)])
    return project(-conv_hat) - NU * K2 * u_hat


@jax.jit
def step(u_hat):
    k1 = rhs(u_hat)
    k2 = rhs(u_hat + DT * k1)
    return project(u_hat + 0.5 * DT * (k1 + k2))


def energy(u_hat):
    # Parseval on the rfft grid: kz>0 modes count twice
    w = jnp.where(KZ == 0, 1.0, 2.0)
    return 0.5 * jnp.sum(w * jnp.abs(u_hat) ** 2) / N**3


def max_divergence(u_hat):
    return jnp.max(jnp.abs(KX * u_hat[0] + KY * u_hat[1] + KZ * u_hat[2]))


# Taylor-Green initial condition
x = jnp.arange(N) * 2 * jnp.pi / N
X, Y, Z = jnp.meshgrid(x, x, x, indexing="ij")
u0 = jnp.stack([jnp.cos(X) * jnp.sin(Y) * jnp.sin(Z),
                -jnp.sin(X) * jnp.cos(Y) * jnp.sin(Z),
                jnp.zeros_like(X)])
u_hat = project(jnp.stack([fwd(u0[i]) for i in range(3)]))

E0 = float(energy(u_hat))
print(f"Taylor-Green DNS: N={N}^3, mesh={dict(mesh.shape)}, nu={NU}, dt={DT}")
print(f"t=0      E={E0:.6f}  max|div|={float(max_divergence(u_hat)):.2e}")
Es = [E0]
for n in range(STEPS):
    u_hat = step(u_hat)
    Es.append(float(energy(u_hat)))
div = float(max_divergence(u_hat))
print(f"t={STEPS * DT:.3f}  E={Es[-1]:.6f}  max|div|={div:.2e}")

# checks: energy decays monotonically at ~the viscous rate; flow stays solenoidal
assert all(e2 < e1 + 1e-9 for e1, e2 in zip(Es, Es[1:])), "energy must decay"
assert div < 1e-3 * np.sqrt(E0), f"divergence grew: {div}"
# Taylor-Green: dE/dt(0) = -2 nu Z(0), Z(0) = 3/16 *(2pi)^3... in our
# normalization E0 = 1/8, Z0 = 3 E0 -> expected initial decay rate 6 nu E0
rate = (Es[0] - Es[1]) / (DT * Es[0])
print(f"measured initial decay rate {rate:.3f} vs 6*nu = {6 * NU:.3f}")
assert abs(rate - 6 * NU) < 0.1 * 6 * NU
print("ok")
