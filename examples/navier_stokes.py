import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Pseudo-spectral incompressible Navier-Stokes on a pencil-decomposed box —
the paper's motivating application (DNS of turbulence; Sec. 1).

Taylor-Green vortex in [0, 2pi)^3, vorticity-free projection form:

    du/dt = P[-(u . grad) u] - nu k^2 u_hat      (spectral space)

Nonlinear term evaluated pseudo-spectrally, Leray projection in spectral
space, RK2 time stepping.  Dealiasing is the 3/2 rule *fused into the
transforms*: the state lives on N^3 retained modes, every transform runs
on the padded M = 3N/2 grid via per-axis ``TransformSpec.pruned`` /
``r2c(n_keep=...)`` specs, and the truncation/zero-padding rides the
plan's exchange stages — no separate dealiasing mask, and the exchanges
ship only the retained modes.

All transforms go through the *batched* multi-field API: (u, v, w) ride
one 3-field plan invocation, the nine velocity gradients one 9-field
invocation, and the convective term one more 3-field invocation, so each
RHS evaluation issues 3 all-to-alls per exchange stage — each carrying a
whole stack (batch_fusion="stacked") — instead of the 15 a per-field
loop would pay — the message-aggregation win the paper's DNS workload
motivates.  Checks: batched forward is
bit-identical to the per-field loop, incompressibility is preserved, and
kinetic energy decays at the viscous rate (dE/dt = -2 nu Z at t=0 for
Taylor-Green).

Run:  PYTHONPATH=src python examples/navier_stokes.py
(set NS_STEPS to shorten the run, e.g. NS_STEPS=2 in CI)
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fftcore import TransformSpec, dealias_grid
from repro.core.meshutil import balanced_dims, make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

mesh = make_mesh(balanced_dims(len(jax.devices())), ("p0", "p1"))
N = 32  # retained modes per axis
M = dealias_grid(N)  # 3/2-rule physical grid (48)
NU = 0.05
DT = 5e-3
STEPS = int(os.environ.get("NS_STEPS", "8"))

plan = ParallelFFT(
    mesh, (M, M, M), grid=("p0", "p1"), config=PlanConfig(method="fused"),
    transforms=(TransformSpec.pruned(N), TransformSpec.pruned(N),
                TransformSpec.r2c(n_keep=N // 2 + 1)),
)
SCALE = float(M) ** 3  # unnormalized fft sums -> true Fourier coefficients

# wavenumbers of the retained (dealiased) spectrum; the centered-keep
# ordering of a pruned axis is exactly fftfreq order
kx = jnp.fft.fftfreq(N, 1 / N)
ky = jnp.fft.fftfreq(N, 1 / N)
kz = jnp.arange(N // 2 + 1, dtype=jnp.float32)
KX = kx[:, None, None]
KY = ky[None, :, None]
KZ = kz[None, None, :]
K2 = KX**2 + KY**2 + KZ**2
K2_safe = jnp.where(K2 == 0, 1.0, K2)
# the -N/2 rows have no +N/2 partner in the retained set (see
# TransformSpec.pruned); keep them empty so spectra stay Hermitian-consistent
HERM = ((KX != -N // 2) & (KY != -N // 2)).astype(jnp.float32)


def fwd(u):
    """Physical (M^3) -> dealiased Fourier coefficients (N, N, N//2+1).
    A leading batch axis transforms the whole stack of fields through one
    batched plan invocation (one exchange per stage for all fields)."""
    return plan.forward(u) / SCALE


def bwd(c):
    """Dealiased coefficients -> physical field on the padded M^3 grid
    (batched along a leading axis, like :func:`fwd`)."""
    return plan.backward(c * SCALE)


def project(v_hat):
    """Leray projection: remove the compressible part (k . v) k / |k|^2."""
    div = KX * v_hat[0] + KY * v_hat[1] + KZ * v_hat[2]
    return jnp.stack([v_hat[0] - KX * div / K2_safe,
                      v_hat[1] - KY * div / K2_safe,
                      v_hat[2] - KZ * div / K2_safe])


def rhs(u_hat):
    """P[-(u.grad)u] - nu k^2 u_hat; products on the padded grid are
    dealiased by the plan's fused 3/2-rule truncation.  Every transform is
    batched: one 3-field backward for u, one 9-field backward for the
    gradient tensor, one 3-field forward for the convective term."""
    u = bwd(u_hat)                                             # physical (3, M^3)
    ik_u_hat = jnp.stack([1j * k * u_hat[i]
                          for i in range(3) for k in (KX, KY, KZ)])
    grads = bwd(ik_u_hat).reshape(3, 3, M, M, M)               # du_i/dx_j
    conv = jnp.einsum("jxyz,ijxyz->ixyz", u, grads)            # (u.grad)u
    conv_hat = fwd(conv) * HERM
    return project(-conv_hat) - NU * K2 * u_hat


@jax.jit
def step(u_hat):
    k1 = rhs(u_hat)
    k2 = rhs(u_hat + DT * k1)
    return project(u_hat + 0.5 * DT * (k1 + k2))


def energy(u_hat):
    # Parseval on the rfft grid: kz>0 modes count twice
    w = jnp.where(KZ == 0, 1.0, 2.0)
    return 0.5 * jnp.sum(w * jnp.abs(u_hat) ** 2)


def max_divergence(u_hat):
    return jnp.max(jnp.abs(KX * u_hat[0] + KY * u_hat[1] + KZ * u_hat[2]))


# Taylor-Green initial condition on the padded grid
x = jnp.arange(M) * 2 * jnp.pi / M
X, Y, Z = jnp.meshgrid(x, x, x, indexing="ij")
u0 = jnp.stack([jnp.cos(X) * jnp.sin(Y) * jnp.sin(Z),
                -jnp.sin(X) * jnp.cos(Y) * jnp.sin(Z),
                jnp.zeros_like(X)])
u0_hat = fwd(u0)  # one batched invocation for all three components
# the batched (stacked, lossless) path must be bit-identical to the
# per-field loop it replaces
assert jnp.array_equal(u0_hat, jnp.stack([fwd(u0[i]) for i in range(3)])), \
    "batched forward diverged from the per-field loop"
u_hat = project(u0_hat)

E0 = float(energy(u_hat))
print(f"Taylor-Green DNS: {N}^3 retained modes on a {M}^3 grid (3/2-rule "
      f"fused dealiasing), mesh={dict(mesh.shape)}, nu={NU}, dt={DT}")
print(f"t=0      E={E0:.6f}  max|div|={float(max_divergence(u_hat)):.2e}")
Es = [E0]
for _ in range(STEPS):
    u_hat = step(u_hat)
    Es.append(float(energy(u_hat)))
div = float(max_divergence(u_hat))
print(f"t={STEPS * DT:.3f}  E={Es[-1]:.6f}  max|div|={div:.2e}")

# checks: energy decays monotonically at ~the viscous rate; flow stays solenoidal
assert all(e2 < e1 + 1e-9 for e1, e2 in zip(Es, Es[1:])), "energy must decay"
assert div < 1e-3 * np.sqrt(E0), f"divergence grew: {div}"
# Taylor-Green: dE/dt(0) = -2 nu Z(0) with Z(0) = 3 E(0) -> decay rate 6 nu
rate = (Es[0] - Es[1]) / (DT * Es[0])
print(f"measured initial decay rate {rate:.3f} vs 6*nu = {6 * NU:.3f}")
assert abs(rate - 6 * NU) < 0.1 * 6 * NU

# --- guarded execution demo ------------------------------------------------
# A long DNS wants to survive a bad exchange, not die mid-run: the same
# plan shape with guard="degrade" runs fused health checks and, when a
# fault trips them, walks the precision/engine ladder and re-executes.
# Inject a NaN into stage 0's input on the fused engine — the degraded
# plan must recover a spectrum matching the healthy one and report every
# transition it took.
from repro.robustness import FaultPlan  # noqa: E402

with FaultPlan().nan_input(stage=0, engine="fused"):
    guarded = ParallelFFT(
        mesh, (M, M, M), grid=("p0", "p1"),
        config=PlanConfig(method="fused", guard="degrade"),
        transforms=(TransformSpec.pruned(N), TransformSpec.pruned(N),
                    TransformSpec.r2c(n_keep=N // 2 + 1)),
    )
    g_hat, rep = guarded.forward(u0)
g_hat = g_hat / SCALE
assert rep.ok, f"guarded execution did not recover: {rep.tripped}"
assert rep.transitions, "the injected fault should have forced a transition"
assert jnp.allclose(g_hat, u0_hat, atol=1e-4 * float(jnp.abs(u0_hat).max()))
print(f"guarded forward recovered in {rep.attempts} attempts; "
      f"transitions: {[t['kind'] for t in rep.transitions]}; "
      f"final schedule: {[list(e) for e in rep.schedule]}")
print("ok")
