import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Chebyshev-Dirichlet Poisson solver on a pencil-decomposed 3-D domain.

Solves  -lap(u) = f  on [-1, 1] x [0, 2pi)^2 with homogeneous Dirichlet
walls u(x=+-1) = 0 and periodic y, z — the canonical non-periodic workload
the per-axis TransformSpec framework opens up.  The distributed transform
is a mixed plan: DCT-II along x (the Chebyshev transform on Chebyshev-Gauss
points), c2c along y, r2c along z.  Per (ky, kz) mode the 1-D Helmholtz
problem  u'' - (ky^2 + kz^2) u = -f_hat,  u(+-1) = 0  is solved in
Chebyshev coefficient space with the tau method (the last two coefficient
equations are replaced by the boundary rows).

Run:  PYTHONPATH=src python examples/poisson.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meshutil import balanced_dims, make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

mesh = make_mesh(balanced_dims(len(jax.devices())), ("p0", "p1"))
NX, NY, NZ = 32, 32, 32
plan = ParallelFFT(mesh, (NX, NY, NZ), grid=("p0", "p1"),
                   transforms=("dct2", "c2c", "r2c"),
                   config=PlanConfig(method="fused"))

# Chebyshev-Gauss points along x (the DCT-II grid), uniform periodic y/z
theta = (2 * np.arange(NX) + 1) * np.pi / (2 * NX)
x = np.cos(theta)
y = np.arange(NY) * 2 * np.pi / NY
z = np.arange(NZ) * 2 * np.pi / NZ
X, Y, Z = np.meshgrid(x, y, z, indexing="ij")

# manufactured solution honouring u(x=+-1) = 0
u_star = np.sin(np.pi * X) * np.cos(2 * Y) * np.sin(3 * Z)
f = (np.pi**2 + 2**2 + 3**2) * u_star

f_hat = np.array(plan.forward(jnp.asarray(f, jnp.float32)), np.complex128)

# DCT-II output -> Chebyshev series coefficients: a_0 = X_0/(2N), a_k = X_k/N
a_f = f_hat / NX
a_f[0] /= 2.0

# Chebyshev second-derivative operator in coefficient space:
# (D2 a)_k = (1/c_k) sum_{p=k+2, p-k even} p (p^2 - k^2) a_p,  c_0 = 2
D2 = np.zeros((NX, NX))
for k in range(NX):
    for p in range(k + 2, NX, 2):
        D2[k, p] = p * (p**2 - k**2)
D2[0] /= 2.0

# per-mode Helmholtz u'' - lam u = -f_hat with tau boundary rows
ky = np.fft.fftfreq(NY, 1 / NY)
kz = np.arange(NZ // 2 + 1)
lam = (ky[:, None] ** 2 + kz[None, :] ** 2)  # (NY, NZ//2+1)
A = np.broadcast_to(D2, (NY, NZ // 2 + 1, NX, NX)) - lam[..., None, None] * np.eye(NX)
A = A.copy()
A[..., NX - 2, :] = 1.0                       # u(1) = sum a_k = 0
A[..., NX - 1, :] = (-1.0) ** np.arange(NX)   # u(-1) = sum (-1)^k a_k = 0
g = -np.moveaxis(a_f, 0, -1)                  # (NY, NZ//2+1, NX)
g[..., NX - 2:] = 0.0
a_u = np.linalg.solve(A, g[..., None])[..., 0]
a_u = np.moveaxis(a_u, -1, 0)                 # back to (NX, NY, NZ//2+1)

# Chebyshev coefficients -> DCT-II spectral values, inverse transform
u_hat = a_u * NX
u_hat[0] *= 2.0
u = np.asarray(plan.backward(jnp.asarray(u_hat, jnp.complex64)))

err = float(np.max(np.abs(u - u_star)))
print(f"Chebyshev-Dirichlet Poisson: ({NX},{NY},{NZ}), mesh={dict(mesh.shape)}, "
      f"transforms=(dct2, c2c, r2c), max|u - u*| = {err:.2e}")
assert err < 1e-3, err
print("ok")
