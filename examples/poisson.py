import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Spectral Poisson solver on a pencil-decomposed 3-D grid.

Solves  -lap(u) = f  on the periodic box [0, 2pi)^3 with the distributed
r2c/c2r transform: u_hat = f_hat / |k|^2.  This is the canonical "FFT at
the core of a PDE solver" workload the paper's DNS motivation describes.

Run:  PYTHONPATH=src python examples/poisson.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
N = (64, 64, 64)
plan = ParallelFFT(mesh, N, grid=("p0", "p1"), real=True, method="fused")

# manufactured solution: u* = sin(3x) cos(2y) sin(z)  ->  f = |k*|^2 u*
x, y, z = np.meshgrid(*(np.arange(n) * 2 * np.pi / n for n in N), indexing="ij")
u_star = np.sin(3 * x) * np.cos(2 * y) * np.sin(z)
f = (3**2 + 2**2 + 1**2) * u_star

f_hat = plan.forward(jnp.asarray(f, jnp.float32))

# wavenumbers on the OUTPUT pencil's logical grid (rfft halves the last axis)
kx = np.fft.fftfreq(N[0], 1 / N[0])
ky = np.fft.fftfreq(N[1], 1 / N[1])
kz = np.arange(N[2] // 2 + 1)
K2 = (kx[:, None, None] ** 2 + ky[None, :, None] ** 2 + kz[None, None, :] ** 2)
K2[0, 0, 0] = 1.0  # zero mode

u_hat = f_hat / jnp.asarray(K2, jnp.float32)
u_hat = u_hat.at[0, 0, 0].set(0.0)
u = plan.backward(u_hat)

err = float(jnp.max(jnp.abs(u - u_star)))
print(f"Poisson solve: N={N}, mesh={dict(mesh.shape)}, max|u - u*| = {err:.2e}")
assert err < 1e-3, err
print("ok")
