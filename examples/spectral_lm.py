import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""FNet-style spectral token mixing built from the paper's primitive.

Demonstrates that ``exchange``/ParallelFFT is a *framework* feature, not an
FFT-private routine: a token-mixing layer that Fourier-transforms the
(seq, d_model) activation grid — distributed over (data, model) — using the
same fused redistribution as the FFT examples, inside a jitted train step.

Run:  PYTHONPATH=src python examples/spectral_lm.py
"""

import jax
import jax.numpy as jnp

from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

mesh = make_mesh((2, 4), ("data", "model"))
B, S, D, V = 8, 128, 64, 256

# 2-D FFT mixing over (seq, feature) of a (B, S, D) activation block,
# sequence sharded over "model": slab redistribution inside the layer.
plan = ParallelFFT(mesh, (S, D), grid=("model",), config=PlanConfig(method="fused"))


def mix(h):
    """Real part of 2-D DFT — the FNet mixing operator, distributed."""
    out = jax.vmap(lambda x: plan.backward(plan.forward(x)))(h.astype(jnp.complex64))
    return jnp.real(out).astype(h.dtype)


def init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "emb": jax.random.normal(k1, (V, D), jnp.float32) * 0.02,
        "w1": jax.random.normal(k2, (D, 4 * D), jnp.float32) * D**-0.5,
        "w2": jax.random.normal(k3, (4 * D, D), jnp.float32) * (4 * D) ** -0.5,
    }


def loss_fn(params, tokens, targets):
    h = params["emb"][tokens]
    h = h + mix(h)                                  # spectral mixing layer
    h = h + jax.nn.gelu(h @ params["w1"]) @ params["w2"]
    logits = h @ params["emb"].T
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, targets[..., None], -1))


params = init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
targets = jnp.roll(tokens, -1, axis=1)

step = jax.jit(jax.value_and_grad(loss_fn))
loss0 = None
for _ in range(10):
    loss, g = step(params, tokens, targets)
    params = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    loss0 = loss0 if loss0 is not None else float(loss)
print(f"spectral LM: loss {loss0:.4f} -> {float(loss):.4f} over 10 steps")
assert float(loss) < loss0
print("ok")
