import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Quickstart — the paper's Appendix A in repro: full 3-D complex FFT with a
2-D pencil decomposition, forward + backward, roundtrip check.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meshutil import balanced_dims, make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

# 2-D process grid (3x4 in the paper's Fig. 3; 2x4 here on 8 host devices —
# adapts to however many devices the XLA_FLAGS above actually provide)
mesh = make_mesh(balanced_dims(len(jax.devices())), ("p0", "p1"))

# global 3-D array, paper Appendix A uses {42, 127, 256} — deliberately
# non-divisible extents to exercise the padding policy
N = (42, 63, 64)
plan = ParallelFFT(mesh, N, grid=("p0", "p1"), config=PlanConfig(method="fused"))

rng = np.random.default_rng(0)
u = (rng.standard_normal(N) + 1j * rng.standard_normal(N)).astype(np.complex64)

u_hat = plan.forward(jnp.asarray(u))          # three 1-D FFTs + two exchanges
u_back = plan.backward(u_hat)                  # and back

np.testing.assert_allclose(np.asarray(u_back), u, rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(u_hat), np.fft.fftn(u), rtol=1e-4, atol=1e-2)
print(f"roundtrip ok: shape={N}, mesh={dict(mesh.shape)}, "
      f"plan: {sum(1 for s in plan.stages)} stages "
      f"({plan.d} FFTs + {plan.k} exchanges)")
print("input pencil:", plan.input_pencil.placement, "->",
      "output pencil:", plan.output_pencil.placement)
