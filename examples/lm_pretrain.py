"""End-to-end driver: pretrain a small LM with the full runtime stack
(sharded params, AdamW, deterministic data, async checkpoints, restart).

Presets (container is a single CPU core — pick your patience):
  10m   ~10M params,  seq 256  (default; a few s/step on CPU)
  100m  ~100M params, seq 512  (the assignment's reference driver size)

Run:   PYTHONPATH=src python examples/lm_pretrain.py --steps 50
Resume after a kill: rerun the same command — it restarts from the last
atomic checkpoint and replays the identical data stream.
"""

from __future__ import annotations

import argparse
from dataclasses import replace

import jax

from repro import configs
from repro.core.meshutil import make_mesh
from repro.data import SyntheticLMData
from repro.models.lm import LM
from repro.models.sharding import Axes
from repro.runtime import TrainConfig, Trainer

PRESETS = {
    "10m": dict(n_layers=8, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                vocab=4096, head_dim=32, seq=256, batch=4),
    "100m": dict(n_layers=12, d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
                 vocab=16384, head_dim=64, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="10m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_pretrain")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    seq, batch = p.pop("seq"), p.pop("batch")
    cfg = replace(configs.get("glm4_9b"), name=f"lm-{args.preset}", **p)

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    lm = LM(cfg, mesh, Axes(multi_pod=False), q_block=64, xent_chunks=4)
    from repro.models.config import param_count
    print(f"model: {param_count(cfg) / 1e6:.1f}M params, seq={seq}, batch={batch}, "
          f"devices={len(jax.devices())}")

    data = SyntheticLMData(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    tc = TrainConfig(steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
                     lr=args.lr, warmup=20)
    trainer = Trainer(lm, data, tc)

    def log(m):
        if m["step"] % 10 == 0 or m["step"] < 3:
            print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.2f}  {m['time']:.2f}s", flush=True)

    _, _, hist = trainer.run(on_metrics=log)
    first = sum(h["loss"] for h in hist[:5]) / max(len(hist[:5]), 1)
    last = sum(h["loss"] for h in hist[-5:]) / max(len(hist[-5:]), 1)
    print(f"done: loss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"(ckpts in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
