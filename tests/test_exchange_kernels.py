"""Fused exchange-kernel parity suite (repro.kernels.exchange).

Every kernel path — encode/decode (fused & pipelined wire form) and
pack/unpack (traditional chunk-major form, both scatter orders) — against
the jnp reference codec, across codecs x complex/real x odd extents x
batch counts, in interpret mode on CPU.  Engine-level and full-plan
``impl="pallas"``-vs-``"jnp"`` parity runs on multi-device subprocesses
through real collectives.

Parity contract: bf16 is **bitwise** against the jnp codec (same
round-to-nearest convert on both paths).  int8 payloads may differ by
±1 quantum at exact round boundaries and scales by 1 ULP between
compilation contexts, so int8 comparisons bound the error by one
quantization step instead of demanding bit equality.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import exchange as xk
from repro.kernels.transpose.ops import transpose01


def _rand(shape, iscomplex, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    if iscomplex:
        x = (x + 1j * rng.standard_normal(shape)).astype(np.complex64)
    return x


def _ref_codec_roundtrip(y, *, axis, m, nbatch, codec):
    """The jnp reference codec loss for an identity exchange: encode then
    decode with the same per-(field, chunk) blocking
    ``redistribute._all_to_all_comm`` uses (``axis`` split into ``m``
    chunks; one int8 scale per field x chunk block)."""
    iscomplex = np.iscomplexobj(y)
    planes = (quant.complex_to_planes(jnp.asarray(y)) if iscomplex
              else jnp.asarray(y)[None].astype(jnp.float32))
    if codec == "bf16":
        p = quant.decode_bf16(quant.encode_bf16(planes))
    else:
        sa = axis + 1  # planes coords
        view = list(planes.shape)
        view[sa:sa + 1] = [m, planes.shape[sa] // m]
        block = (sa,) + tuple(range(1, nbatch + 1))
        q, scale = quant.quantize_int8(planes.reshape(view), block_axis=block)
        p = quant.dequantize_int8(q, scale).reshape(planes.shape)
    return np.asarray(quant.planes_to_complex(p) if iscomplex else p[0])


def _quantum(y):
    """Upper bound on one int8 quantization step anywhere in ``y``."""
    return float(np.max(np.abs(np.stack([y.real, np.imag(y)])))) / 127.0


@pytest.mark.parametrize("codec", ["bf16", "int8"])
@pytest.mark.parametrize("iscomplex", [True, False])
@pytest.mark.parametrize("shape,axis,m,nbatch", [
    ((6, 8, 10), 1, 4, 0),     # mid split axis, odd neighbours
    ((8, 6, 10), 0, 2, 0),     # leading split axis
    ((3, 6, 8, 10), 2, 4, 1),  # stacked fields: per-field scale blocks
])
def test_encode_decode_matches_jnp_codec(codec, iscomplex, shape, axis, m, nbatch):
    """decode(encode(y)) — the fused/pipelined wire form under an identity
    exchange — must equal the jnp codec roundtrip: bitwise for bf16,
    within one quantum for int8."""
    y = _rand(shape, iscomplex, seed=axis + m)
    q, scale, stats = xk.encode_payload(jnp.asarray(y), axis=axis, m=m,
                                        nbatch=nbatch, codec=codec)
    assert stats is None  # guard off: no counters traced
    if codec == "int8":
        assert scale is not None and scale.dtype == jnp.float32
    out = np.asarray(xk.decode_payload(q, axis=axis, m=m, nbatch=nbatch,
                                       scale=scale, codec=codec,
                                       iscomplex=iscomplex))
    ref = _ref_codec_roundtrip(y, axis=axis, m=m, nbatch=nbatch, codec=codec)
    if codec == "bf16":
        np.testing.assert_array_equal(out, ref)
    else:
        np.testing.assert_allclose(out, ref, atol=1.25 * _quantum(y), rtol=0)


def test_pack_chunks_bf16_layout_bitwise():
    """pack_chunks' chunk-major payload must be exactly the jnp pack
    (reshape + moveaxis) of the bf16-encoded planes — the kernel's output
    index map IS Eq. 16, not an approximation of it."""
    y = _rand((8, 6, 10), True)
    axis, m = 0, 4
    payload, scale, _ = xk.pack_chunks(jnp.asarray(y), axis=axis, m=m,
                                       codec="bf16")
    assert scale is None
    planes = quant.encode_bf16(quant.complex_to_planes(jnp.asarray(y)))
    view = list(planes.shape)
    view[axis + 1:axis + 2] = [m, planes.shape[axis + 1] // m]
    ref = jnp.moveaxis(planes.reshape(view), axis + 1, 0)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(ref))


@pytest.mark.parametrize("codec", ["bf16", "int8"])
@pytest.mark.parametrize("iscomplex", [True, False])
@pytest.mark.parametrize("shape,v,w,m,nbatch", [
    ((8, 6, 10), 0, 2, 4, 0),     # scatter axis after the chunk source
    ((6, 10, 8), 2, 0, 2, 0),     # w < v: the other scatter order
    ((3, 8, 6, 10), 0, 1, 4, 1),  # stacked fields
])
def test_unpack_inverts_pack_both_orders(codec, iscomplex, shape, v, w, m, nbatch):
    """unpack(pack(y)) under an identity exchange must equal the jnp
    traditional path (reshape/moveaxis pack, codec roundtrip, moveaxis/
    merge unpack) for both w<v and w>v scatter orders."""
    y = _rand(shape, iscomplex, seed=v * 10 + w)
    bv, bw = v + nbatch, w + nbatch
    payload, scale, _ = xk.pack_chunks(jnp.asarray(y), axis=bv, m=m,
                                       nbatch=nbatch, codec=codec)
    out = np.asarray(xk.unpack_chunks(payload, v=v, w=w, m=m, nbatch=nbatch,
                                      scale=scale, codec=codec,
                                      iscomplex=iscomplex))
    # reference: same codec loss, then the jnp pack/unpack layout ops
    yc = _ref_codec_roundtrip(y, axis=bv, m=m, nbatch=nbatch, codec=codec)
    view = list(yc.shape)
    view[bv:bv + 1] = [m, yc.shape[bv] // m]
    z = np.moveaxis(np.moveaxis(yc.reshape(view), bv, 0), 0, bw)
    ref = z.reshape(z.shape[:bw] + (m * z.shape[bw + 1],) + z.shape[bw + 2:])
    assert out.shape == ref.shape
    if codec == "bf16":
        np.testing.assert_array_equal(out, ref)
    else:
        np.testing.assert_allclose(out, ref, atol=1.25 * _quantum(y), rtol=0)


@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_guard_stats_ride_the_fused_codec(codec):
    """guard=True must return the health counters from inside the kernel:
    injected non-finites are counted exactly; int8 counts its saturated
    (clipped-to-127) elements."""
    y = _rand((8, 6, 10), True).copy()
    y[0, 0, :3] = np.nan  # 3 non-finite real-plane elements
    _, _, stats = xk.encode_payload(jnp.asarray(y), axis=0, m=4, codec=codec,
                                    guard=True)
    assert int(stats["nonfinite"]) == 3
    if codec == "int8":
        # each (field, chunk) block's max-abs element lands exactly on 127
        assert int(stats["saturated"]) >= 1
    _, _, pstats = xk.pack_chunks(jnp.asarray(y), axis=0, m=4, codec=codec,
                                  guard=True)
    assert int(pstats["nonfinite"]) == 3


def test_pallas_applicable_gate():
    """The one shared gate: lossy payloads only — lossless stages always
    run the jnp reference path regardless of the requested impl."""
    for method in ("fused", "traditional", "pipelined"):
        assert xk.pallas_applicable(method, "bf16")
        assert xk.pallas_applicable(method, "int8")
        assert not xk.pallas_applicable(method, None)
        assert not xk.pallas_applicable(method, "complex64")


@pytest.mark.parametrize("shape", [(9, 17, 5), (1, 31, 2), (8, 8, 3), (13, 7, 1)])
def test_transpose01_pad_and_slice_non_tile_multiples(shape):
    """The tiled local-transpose kernel at non-tile-multiple extents: the
    pad-to-tile/run/slice-back path must be exact (the padding must never
    leak into the result)."""
    x = _rand(shape, False, seed=sum(shape))
    np.testing.assert_array_equal(np.asarray(transpose01(jnp.asarray(x))),
                                  x.swapaxes(0, 1))
    xc = _rand(shape, True, seed=sum(shape))
    np.testing.assert_array_equal(np.asarray(transpose01(jnp.asarray(xc))),
                                  xc.swapaxes(0, 1))


def test_engine_impl_parity_through_collectives(subproc):
    """exchange(impl="pallas") vs impl="jnp" through real all-to-alls on a
    (2, 2) mesh, every engine x payload: lossless and bf16 bitwise, int8
    within one quantization step."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 2), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 10)   # odd trailing extents: padded pencil
cases = [
    ((None, "p1", None), (2, 2, 1), 0, 1),          # slab
    (("p0", "p1", None), (2, 2, 2), 2, 1),          # pencil, v trailing
]
for placement, divisors, v, w in cases:
    src = make_pencil(mesh, shape, placement, divisors=divisors)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
    quantum = float(np.abs(np.stack([x.real, x.imag])).max()) / 127.0
    for method in ("fused", "traditional", "pipelined"):
        for cd in ("complex64", "bf16", "int8"):
            gj, dj = exchange(xs, src, v=v, w=w, method=method, chunks=2,
                              comm_dtype=cd, impl="jnp")
            gp, dp = exchange(xs, src, v=v, w=w, method=method, chunks=2,
                              comm_dtype=cd, impl="pallas")
            assert dp.placement == dj.placement
            gj, gp = np.asarray(gj), np.asarray(gp)
            if cd == "int8":
                np.testing.assert_allclose(gp, gj, atol=2.1 * quantum, rtol=0)
            else:
                # lossless: pallas is a documented no-op; bf16: same
                # round-to-nearest convert on both paths
                assert np.array_equal(gp, gj), (placement, method, cd)
print("ENGINE IMPL PARITY OK")
""", ndev=4)


def test_plan_impl_parity_and_guard(subproc):
    """Full ParallelFFT parity: an exchange_impl="pallas" plan against the
    jnp reference plan, per engine x payload, including an r2c plan with
    odd extents, the batched multi-field path, and a guarded pallas plan
    whose health stats flow out of the fused kernels."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

mesh = make_mesh((2, 2), ("p0", "p1"))
rng = np.random.default_rng(0)

def plans(shape, transforms, **kw):
    base = {"method": "fused", **kw}
    pj = ParallelFFT(mesh, shape, ("p0", "p1"), transforms=transforms,
                     config=PlanConfig(**base))
    pp = ParallelFFT(mesh, shape, ("p0", "p1"), transforms=transforms,
                     config=PlanConfig(exchange_impl="pallas", **base))
    return pj, pp

shape = (16, 12, 20)
x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
for method in ("fused", "traditional", "pipelined"):
    for cd in ("bf16", "int8"):
        pj, pp = plans(shape, None, method=method, chunks=2, comm_dtype=cd)
        yj = np.asarray(pj.forward(jnp.asarray(x)))
        yp = np.asarray(pp.forward(jnp.asarray(x)))
        if cd == "bf16":
            assert np.array_equal(yp, yj), (method, cd)
        else:
            # +-1 quantum per exchange, amplified by the later FFT stages:
            # bound the relative spectrum error between the impls instead
            rel = np.linalg.norm(yp - yj) / np.linalg.norm(yj)
            assert rel < 5e-3, (method, cd, rel)
        back = np.asarray(pp.backward(pp.forward(jnp.asarray(x))))
        rel = np.linalg.norm(back - x) / np.linalg.norm(x)
        assert rel < (1e-2 if cd == "bf16" else 5e-2), (method, cd, rel)

# r2c with an odd trailing extent (pad-and-slice inside the plan)
rshape = (16, 12, 9)
xr = rng.standard_normal(rshape).astype(np.float32)
pj, pp = plans(rshape, ("c2c", "c2c", "r2c"), comm_dtype="bf16")
assert np.array_equal(np.asarray(pp.forward(jnp.asarray(xr))),
                      np.asarray(pj.forward(jnp.asarray(xr))))

# batched multi-field path: one exchange ships all fields
xb = (rng.standard_normal((3, *shape))
      + 1j * rng.standard_normal((3, *shape))).astype(np.complex64)
pj, pp = plans(shape, None, comm_dtype="bf16")
assert np.array_equal(np.asarray(pp.forward_many(jnp.asarray(xb))),
                      np.asarray(pj.forward_many(jnp.asarray(xb))))

# guarded pallas plan: stats ride the fused codec out of the kernels
gp = ParallelFFT(mesh, shape, ("p0", "p1"),
                 config=PlanConfig(method="fused", comm_dtype="int8",
                                   exchange_impl="pallas", guard="strict"))
y, rep = gp.forward(jnp.asarray(x))
assert rep.ok and rep.attempts == 1
assert len(rep.stages) == gp.n_exchanges
print("PLAN IMPL PARITY OK")
""", ndev=4, timeout=1200)
