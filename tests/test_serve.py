"""Serving-engine tests: lifecycle units single-process, engine behavior in
8-virtual-device subprocesses, and the chaos soak (`-m faults`).

The soak is the PR's acceptance test: waves of serve-level fault matrices
against fresh servers sharing one schedule DB — every request must land in
a structured terminal outcome within deadline+grace (zero hangs, zero
silent corruption) and quarantine counts must track breaker trips, not
request counts (no leak across requests)."""

from __future__ import annotations

import json
import threading
import time

import pytest

from repro.robustness import faults
from repro.serve import (
    OUTCOME_STATUSES, TRIP_SHED, TRIP_TIMEOUT,
    Outcome, RequestFuture, backoff_s,
)
from repro.serve.registry import CircuitBreaker


# -- lifecycle units (no devices needed) ------------------------------------


def test_backoff_deterministic_and_bounded():
    a = [backoff_s("r1", k, base=0.05, cap=1.0) for k in range(1, 8)]
    b = [backoff_s("r1", k, base=0.05, cap=1.0) for k in range(1, 8)]
    assert a == b  # deterministic jitter: same (rid, attempt) -> same delay
    assert a != [backoff_s("r2", k, base=0.05, cap=1.0) for k in range(1, 8)]
    for k, v in enumerate(a, start=1):
        raw = min(1.0, 0.05 * 2 ** (k - 1))
        assert 0.5 * raw <= v < raw  # jitter fraction in [0.5, 1.0)
    assert backoff_s("r1", 0) == 0.0
    assert backoff_s("r1", 50) < 1.0  # capped


def test_outcome_status_validated():
    with pytest.raises(ValueError):
        Outcome("exploded", "r0")
    o = Outcome("shed", "r0", trip=TRIP_SHED)
    assert o.summary()["status"] == "shed"
    assert set(OUTCOME_STATUSES) == {
        "ok", "degraded", "shed", "deadline-exceeded", "error"}


def test_request_future_first_resolve_wins():
    fut = RequestFuture("r0", time.monotonic() + 5.0)
    assert fut.resolve(Outcome("ok", "r0", value=1))
    assert not fut.resolve(Outcome("error", "r0"))  # loser observes the race
    assert fut.result().status == "ok"
    assert fut.result().value == 1


def test_request_future_deadline_self_resolves():
    fut = RequestFuture("r0", time.monotonic() + 0.05)
    t0 = time.monotonic()
    out = fut.result(grace=0.05)
    assert time.monotonic() - t0 < 2.0  # bounded wait, no hang
    assert out.status == "deadline-exceeded" and out.trip == TRIP_TIMEOUT
    # a late completion loses the race but is observable to the resolver
    assert not fut.resolve(Outcome("ok", "r0", value=1))
    assert fut.result().status == "deadline-exceeded"


def test_request_future_result_concurrent_with_resolve():
    fut = RequestFuture("r0", time.monotonic() + 5.0)
    got = []
    t = threading.Thread(target=lambda: got.append(fut.result()))
    t.start()
    time.sleep(0.02)
    fut.resolve(Outcome("ok", "r0"))
    t.join(timeout=5.0)
    assert got and got[0].status == "ok"


def test_circuit_breaker_transitions():
    b = CircuitBreaker(threshold=2, cooldown_s=0.05)
    assert b.state == "closed" and b.allow()
    assert not b.record_failure()          # 1 failure: still closed
    assert b.record_failure()              # 2nd trips
    assert b.state == "open" and not b.allow()
    time.sleep(0.06)
    assert b.state == "half-open"
    assert b.allow()                       # probe slot
    assert not b.allow()                   # ... exactly one
    assert b.record_failure()              # failed probe re-opens instantly
    assert b.state == "open"
    time.sleep(0.06)
    assert b.allow()
    b.record_success()                     # clean probe closes
    assert b.state == "closed" and b.trips == 2
    # success also resets the consecutive-failure count
    b.record_failure()
    b.record_success()
    assert not b.record_failure()


def test_serve_taps_unarmed_are_noops(tmp_path):
    # no FaultPlan armed: every serve tap must be free and side-effect-less
    t0 = time.monotonic()
    faults.tap_serve_execute()
    assert time.monotonic() - t0 < 0.05
    assert faults.serve_burst() == 1
    p = tmp_path / "cache.json"
    assert faults.tap_serve_cache(p) is False
    assert not p.exists()


def test_serve_faults_bounded_times(tmp_path):
    with faults.FaultPlan().executor_crash(times=2).request_burst(
            factor=3, times=1).cache_corruption(mode="truncate", times=1):
        assert faults.serve_burst() == 3
        assert faults.serve_burst() == 1   # bounded: used up
        p = tmp_path / "db.json"
        assert faults.tap_serve_cache(p) and p.read_text() == ""
        assert not faults.tap_serve_cache(p)  # disarmed after 1 fire
        for _ in range(2):
            with pytest.raises(faults.FaultInjected):
                faults.tap_serve_execute()
        faults.tap_serve_execute()         # 3rd call: crash exhausted


def test_fault_context_is_thread_local():
    # the serve engine traces fallback executors concurrently with a
    # background retune thread; stage context must not leak across threads
    with faults.FaultPlan().corrupt_wire(codec="bf16"):
        seen = {}

        def other():
            seen["match"] = bool(faults._matching("corrupt_wire"))

        with faults.stage_context(0, "fused", "bf16"):
            assert faults._matching("corrupt_wire")
            t = threading.Thread(target=other)
            t.start()
            t.join()
        assert seen["match"] is False  # peer thread saw no bf16 context


# -- engine behavior (8 virtual devices, subprocess) ------------------------

_CLEAN_SCRIPT = r"""
import json, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig
from repro.serve import ServeConfig, SpectralServer

mesh, grid = make_mesh((8,), ("p0",)), ("p0",)
pc = PlanConfig(method="fused", guard="degrade")
rng = np.random.default_rng(0)
xs = [rng.standard_normal((16, 16, 16)).astype(np.float32) for _ in range(5)]
with SpectralServer(mesh, grid, plan_config=pc,
                    config=ServeConfig(deadline_s=120.0, max_batch=8)) as srv:
    futs = [srv.submit(x) for x in xs]
    outs = [f.result() for f in futs]
    stats = srv.stats()
ref = ParallelFFT(mesh, (16, 16, 16), grid,
                  config=PlanConfig(method="fused")).forward(xs[0])
match = bool(np.allclose(np.asarray(outs[0].value), np.asarray(ref),
                         atol=1e-4))
print("CLEAN=" + json.dumps({
    "statuses": [o.status for o in outs],
    "batched": [o.batched for o in outs],
    "match": match,
    "coalesced_batches": stats["coalesced_batches"],
    "batched_requests": stats["batched_requests"],
    "plans": stats["registry"]["plans"]}))
"""


def test_serve_clean_coalescing(subproc):
    out = json.loads(subproc(_CLEAN_SCRIPT).split("CLEAN=")[1])
    assert out["statuses"] == ["ok"] * 5
    assert out["match"], "served spectrum != direct plan.forward"
    # all five same-shape requests rode one batched invocation
    assert out["coalesced_batches"] >= 1
    assert out["batched_requests"] >= 4
    assert max(out["batched"]) >= 4
    assert out["plans"] == 1


_LRU_SCRIPT = r"""
import json, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.planconfig import PlanConfig
from repro.serve import ServeConfig, SpectralServer

mesh, grid = make_mesh((8,), ("p0",)), ("p0",)
pc = PlanConfig(method="fused", guard="degrade")
rng = np.random.default_rng(0)
with SpectralServer(mesh, grid, plan_config=pc,
                    config=ServeConfig(deadline_s=120.0, capacity=1)) as srv:
    outs = []
    for shape in [(16, 16, 16), (8, 16, 16), (16, 16, 16)]:
        x = rng.standard_normal(shape).astype(np.float32)
        outs.append(srv.submit(x).result())
    stats = srv.stats()
print("LRU=" + json.dumps({
    "statuses": [o.status for o in outs],
    "shapes_ok": [list(np.asarray(o.value).shape) for o in outs],
    "plans": stats["registry"]["plans"],
    "builds": stats["registry"]["builds"],
    "evictions": stats["registry"]["evictions"]}))
"""


def test_serve_lru_eviction(subproc):
    out = json.loads(subproc(_LRU_SCRIPT).split("LRU=")[1])
    assert out["statuses"] == ["ok"] * 3
    assert out["shapes_ok"] == [[16, 16, 16], [8, 16, 16], [16, 16, 16]]
    assert out["plans"] == 1               # capacity-1 LRU
    assert out["builds"] == 3              # third request rebuilt evicted plan
    assert out["evictions"] == 2


_SHED_SCRIPT = r"""
import json, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.planconfig import PlanConfig
from repro.robustness import faults
from repro.serve import ServeConfig, SpectralServer

mesh, grid = make_mesh((8,), ("p0",)), ("p0",)
pc = PlanConfig(method="fused", guard="degrade")
rng = np.random.default_rng(0)
x = rng.standard_normal((16, 16, 16)).astype(np.float32)
burst = faults.serve_burst()
with faults.FaultPlan().slow_collective(seconds=0.4, times=100) \
        .request_burst(factor=4, times=1):
    burst = faults.serve_burst()
    with SpectralServer(mesh, grid, plan_config=pc,
                        config=ServeConfig(deadline_s=120.0, max_queue=2,
                                           max_batch=1)) as srv:
        futs = [srv.submit(x) for _ in range(2 * burst)]
        outs = [f.result() for f in futs]
        stats = srv.stats()
print("SHED=" + json.dumps({
    "burst": burst,
    "statuses": [o.status for o in outs],
    "shed_latency": max(o.latency_s for o in outs if o.status == "shed"),
    "shed_stat": stats["shed"]}))
"""


@pytest.mark.faults
def test_serve_overload_shed(subproc):
    out = json.loads(subproc(_SHED_SCRIPT).split("SHED=")[1])
    assert out["burst"] == 4
    statuses = out["statuses"]
    assert len(statuses) == 8
    n_shed = statuses.count("shed")
    assert n_shed >= 4                     # bounded queue under 4x burst
    assert n_shed == out["shed_stat"]
    assert statuses.count("ok") + n_shed == len(statuses)
    assert out["shed_latency"] < 0.1       # shed is instant, never queued


_BREAKER_SCRIPT = r"""
import json, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.planconfig import PlanConfig
from repro.robustness import faults
from repro.serve import ServeConfig, SpectralServer

mesh, grid = make_mesh((8,), ("p0",)), ("p0",)
pc = PlanConfig(method="fused", comm_dtype="bf16", guard="strict")
sc = ServeConfig(deadline_s=120.0, breaker_threshold=2,
                 breaker_cooldown_s=60.0, max_retries=0)
rng = np.random.default_rng(0)
x = rng.standard_normal((16, 16, 16)).astype(np.float32)
with faults.FaultPlan().corrupt_wire(codec="bf16"):
    with SpectralServer(mesh, grid, plan_config=pc, config=sc) as srv:
        outs = [srv.submit(x).result(grace=5.0) for _ in range(4)]
        stats = srv.stats()
ref = None
print("BREAKER=" + json.dumps({
    "statuses": [o.status for o in outs],
    "trips": [o.trip for o in outs],
    "breaker_trips": stats["registry"]["breaker_trips"],
    "fallback_served": stats["fallback_served"],
    "errors": stats["error"]}))
"""


@pytest.mark.faults
def test_serve_breaker_trips_and_degrades(subproc):
    out = json.loads(subproc(_BREAKER_SCRIPT).split("BREAKER=")[1])
    # persistent wire corruption on the strict bf16 plan: every request is
    # still served — through the lossless fallback ladder — as degraded
    assert out["statuses"] == ["degraded"] * 4
    assert out["trips"][0] == "guard-error"       # pre-trip one-off fallback
    assert set(out["trips"][2:]) == {"circuit-open"}
    assert out["breaker_trips"] >= 1
    assert out["fallback_served"] == 4
    assert out["errors"] == 0


_CRASH_SCRIPT = r"""
import json, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.planconfig import PlanConfig
from repro.robustness import faults
from repro.serve import ServeConfig, SpectralServer

mesh, grid = make_mesh((8,), ("p0",)), ("p0",)
pc = PlanConfig(method="fused", guard="degrade")
rng = np.random.default_rng(0)
x = rng.standard_normal((16, 16, 16)).astype(np.float32)
with faults.FaultPlan().executor_crash(times=1).slow_collective(
        seconds=0.05, times=2):
    with SpectralServer(mesh, grid, plan_config=pc,
                        config=ServeConfig(deadline_s=120.0,
                                           backoff_base_s=0.01)) as srv:
        out = srv.submit(x).result()
        stats = srv.stats()
print("CRASH=" + json.dumps({
    "status": out.status, "retries": out.retries,
    "stat_retries": stats["retries"], "errors": stats["error"]}))
"""


@pytest.mark.faults
def test_serve_crash_retry_recovers(subproc):
    out = json.loads(subproc(_CRASH_SCRIPT).split("CRASH=")[1])
    # a bounded (times=1) crash burns one retry and then recovers cleanly
    assert out["status"] == "ok"
    assert out["retries"] == 1
    assert out["stat_retries"] == 1
    assert out["errors"] == 0


# -- chaos soak (the PR acceptance test) ------------------------------------

_SOAK_SCRIPT = r"""
import json, numpy as np, os, time
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.planconfig import PlanConfig
from repro.robustness import faults
from repro.serve import OUTCOME_STATUSES, ServeConfig, SpectralServer

mesh, grid = make_mesh((8,), ("p0",)), ("p0",)
CACHE = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                     "serve_soak_%d.json" % os.getpid())
DEADLINE, GRACE = 120.0, 5.0
rng = np.random.default_rng(0)

def wave(plan_config, fault_plan, n, *, max_batch=4):
    sc = ServeConfig(deadline_s=DEADLINE, grace_s=GRACE, max_batch=max_batch,
                     max_queue=16, backoff_base_s=0.01,
                     breaker_threshold=2, breaker_cooldown_s=60.0)
    ctx = fault_plan if fault_plan is not None else faults.FaultPlan()
    with ctx:
        n = n * faults.serve_burst()
        with SpectralServer(mesh, grid, plan_config=plan_config,
                            config=sc) as srv:
            futs = [srv.submit(
                rng.standard_normal((16, 16, 16)).astype(np.float32))
                for _ in range(n)]
            outs = [f.result(grace=GRACE) for f in futs]
            stats = srv.stats()
    return outs, stats, list(ctx.fired)

auto = PlanConfig(method="auto", comm_dtype="bf16", guard="degrade",
                  tuner_cache=CACHE)
strict = PlanConfig(method="auto", comm_dtype="bf16", guard="strict",
                    tuner_cache=CACHE)

def poison_strict_entry():
    # the ISSUE's "poisoned cache entry" fault: plant a structurally valid
    # bf16 schedule the tuner never timed, so the strict wave's auto plan
    # replays it and the bf16-targeted wire corruption deterministically
    # hits the primary path (a freshly tuned winner might be lossless)
    from repro.core.pfft import ParallelFFT
    probe = ParallelFFT(mesh, (16, 16, 16), grid, config=strict)
    faults.FaultPlan.poison_cache(
        CACHE, probe, [("fused", 1, "bf16", "jnp", "stacked")])

waves = [
    ("clean", auto, None, 4, 4, None),
    ("transient", auto,
     faults.FaultPlan().executor_crash(times=1)
                       .slow_collective(seconds=0.05, times=2), 4, 4, None),
    ("corrupt-degrade", auto,
     faults.FaultPlan().corrupt_wire(codec="bf16"), 3, 4, None),
    ("breaker-strict", strict,
     faults.FaultPlan().corrupt_wire(codec="bf16"), 4, 1,
     poison_strict_entry),
    ("cache-corruption-burst", auto,
     faults.FaultPlan().cache_corruption(mode="garbage", times=1)
                       .request_burst(factor=2, times=1), 3, 4, None),
]

report = {"waves": {}}
total_trips = 0
for name, pc, fp, n, mb, setup in waves:
    if setup is not None:
        setup()
    t0 = time.monotonic()
    outs, stats, fired = wave(pc, fp, n, max_batch=mb)
    total_trips += stats["registry"]["breaker_trips"]
    report["waves"][name] = {
        "n": len(outs),
        "statuses": [o.status for o in outs],
        "trips": [o.trip for o in outs],
        "unresolved": sum(o is None for o in outs),
        "bad_status": [o.status for o in outs
                       if o.status not in OUTCOME_STATUSES],
        "over_deadline": [o.latency_s for o in outs
                          if o.latency_s > DEADLINE + GRACE + 1.0],
        "errors": stats["error"],
        "breaker_trips": stats["registry"]["breaker_trips"],
        "fired": len(fired),
        "wall_s": round(time.monotonic() - t0, 2),
    }

disk = tuner.load_cache(CACHE)
quarantines = {k[:40]: v.get("quarantines", 0)
               for k, v in disk.items() if isinstance(v, dict)}
report["total_quarantines"] = sum(quarantines.values())
report["total_breaker_trips"] = total_trips
report["cache_entries"] = len(disk)
report["cache_well_formed"] = bool(disk)
print("SOAK=" + json.dumps(report))
"""


@pytest.mark.faults
def test_chaos_soak(subproc):
    out = json.loads(subproc(_SOAK_SCRIPT, timeout=1500).split("SOAK=")[1])
    waves = out["waves"]
    assert set(waves) == {"clean", "transient", "corrupt-degrade",
                          "breaker-strict", "cache-corruption-burst"}
    for name, w in waves.items():
        # every request resolved, structured, and inside deadline+grace
        assert w["unresolved"] == 0, (name, w)
        assert w["bad_status"] == [], (name, w)
        assert w["over_deadline"] == [], (name, w)
        assert len(w["statuses"]) == w["n"]
    assert waves["clean"]["statuses"] == ["ok"] * waves["clean"]["n"]
    assert waves["clean"]["breaker_trips"] == 0
    assert waves["transient"]["errors"] == 0
    # persistent wire corruption under degrade: served, never erroring out
    cd = waves["corrupt-degrade"]
    assert set(cd["statuses"]) <= {"ok", "degraded"}
    # strict wave: breaker engaged, everything still served degraded
    bs = waves["breaker-strict"]
    assert bs["breaker_trips"] >= 1
    assert set(bs["statuses"]) <= {"degraded", "error"}
    assert bs["statuses"].count("degraded") >= bs["n"] - 1
    # burst wave doubled the offered load and still terminated everything
    cb = waves["cache-corruption-burst"]
    assert cb["n"] == 6
    # quarantine counts track breaker trips, not request volume (no leak)
    assert out["total_quarantines"] <= out["total_breaker_trips"]
    assert out["cache_well_formed"]  # corrupted DB was rebuilt, not kept
    # (the soak uses a fresh server per wave — trace-time faults only bake
    # into newly compiled executors — but one shared schedule DB across all
    # waves; the quarantine-leak assertion is about that shared state)
