"""Distributed FFT vs np.fft oracles on 8 virtual devices."""

import numpy as np
from _hyp import given, settings, strategies as st

from repro.core.pfft import ParallelFFT


def test_pfft_all_decompositions(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
cases = [
    # (shape, grid, real, method)
    ((16, 12, 20), ("p0",), False, "fused"),          # slab
    ((16, 12, 20), ("p0", "p1"), False, "fused"),     # pencil
    ((16, 12, 20), (("p0", "p1"),), False, "fused"),  # slab on composed group
    ((16, 12, 20), ("p0", "p1"), True, "fused"),      # r2c pencil
    ((16, 12, 20), ("p0", "p1"), False, "traditional"),
    ((16, 12, 20), ("p0", "p1"), True, "traditional"),
    ((13, 9, 11), ("p0", "p1"), False, "fused"),      # non-divisible (padding)
    ((13, 9, 11), ("p0", "p1"), True, "fused"),
    ((8, 6, 10, 12), ("p0", "p1"), False, "fused"),   # 4-D on 2-D grid
]
for shape, grid, real, method in cases:
    plan = ParallelFFT(mesh, shape, grid, real=real, method=method)
    x = rng.standard_normal(shape).astype(np.float32)
    if not real:
        x = (x + 1j * rng.standard_normal(shape)).astype(np.complex64)
    y = plan.forward(jnp.asarray(x))
    want = np.fft.rfftn(x) if real else np.fft.fftn(x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=3e-4, atol=3e-3)
    back = plan.backward(y)
    np.testing.assert_allclose(np.asarray(back), x, rtol=3e-4, atol=3e-3)
    print("ok", shape, grid, real, method)

# 4-D array on a 3-D processor grid (paper Sec. 3.6 / Appendix B)
mesh3 = make_mesh((2, 2, 2), ("a", "b", "c"))
plan = ParallelFFT(mesh3, (8, 8, 8, 8), ("a", "b", "c"))
x = (rng.standard_normal((8, 8, 8, 8)) + 1j * rng.standard_normal((8, 8, 8, 8))).astype(np.complex64)
np.testing.assert_allclose(np.asarray(plan.forward(jnp.asarray(x))), np.fft.fftn(x),
                           rtol=3e-4, atol=3e-3)
print("PFFT DECOMPS OK")
""", ndev=8)


def test_pfft_pipelined_and_auto_match_fused(subproc):
    """method="pipelined" (several chunk counts) and method="auto" produce
    the same pencils and allclose values as "fused" for slab and pencil
    decompositions, c2c and r2c — and match the np.fft oracle."""
    subproc("""
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
cache = tempfile.mktemp(suffix=".json")
shape = (16, 12, 20)
for grid in (("p0",), ("p0", "p1")):
    for real in (False, True):
        ref = ParallelFFT(mesh, shape, grid, real=real, method="fused")
        x = rng.standard_normal(shape).astype(np.float32)
        if not real:
            x = (x + 1j * rng.standard_normal(shape)).astype(np.complex64)
        want = np.asarray(ref.forward(jnp.asarray(x)))
        variants = [ParallelFFT(mesh, shape, grid, real=real,
                                method="pipelined", chunks=c) for c in (1, 2, 4)]
        variants.append(ParallelFFT(mesh, shape, grid, real=real,
                                    method="auto", tuner_cache=cache))
        for plan in variants:
            assert plan.output_pencil == ref.output_pencil   # identical pencils
            y = np.asarray(plan.forward(jnp.asarray(x)))
            np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)
            oracle = np.fft.rfftn(x) if real else np.fft.fftn(x)
            np.testing.assert_allclose(y, oracle, rtol=3e-4, atol=3e-3)
            back = np.asarray(plan.backward(jnp.asarray(y)))
            np.testing.assert_allclose(back, x, rtol=3e-4, atol=3e-3)
        print("ok", grid, real)
print("PFFT PIPELINED/AUTO OK")
""", ndev=8)


def test_pfft_comm_dtype_accuracy(subproc):
    """Compressed-exchange accuracy contract at the plan level, slab and
    pencil grids: comm_dtype=None/"complex64" is bit-identical to today's
    output for all three engines; "bf16" round-trips backward(forward(x))
    to < 1e-2 relative L2; "int8" to < 5e-2."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 20)
x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
for grid in (("p0",), ("p0", "p1")):
    ref = ParallelFFT(mesh, shape, grid)
    want = np.asarray(ref.forward(jnp.asarray(x)))
    for method in ("fused", "traditional", "pipelined"):
        for comm_dtype in (None, "complex64", "bf16", "int8"):
            plan = ParallelFFT(mesh, shape, grid, method=method, chunks=2,
                               comm_dtype=comm_dtype)
            y = plan.forward(jnp.asarray(x))
            back = np.asarray(plan.backward(y))
            if comm_dtype in (None, "complex64"):
                # lossless payload: bit-identical forward transform
                assert np.array_equal(np.asarray(y), want), (grid, method)
            rel = np.linalg.norm(back - x) / np.linalg.norm(x)
            bound = {None: 1e-5, "complex64": 1e-5, "bf16": 1e-2, "int8": 5e-2}[comm_dtype]
            assert rel < bound, (grid, method, comm_dtype, rel)
    print("ok", grid)
print("PFFT COMM DTYPE OK")
""", ndev=8)


def test_backward_consumes_reversed_tuned_schedule(subproc):
    """method="auto" backward pass: backward_padded must consume the tuned
    schedule in reversed stage order, and backward(forward(x)) must
    round-trip to the identity for a *mixed* per-stage schedule (different
    engine, chunks and comm_dtype per exchange)."""
    subproc("""
import json, tempfile
from pathlib import Path
import jax, jax.numpy as jnp, numpy as np
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ExchangeStage, ParallelFFT

cache = tempfile.mktemp(suffix=".json")
mesh = make_mesh((2, 4), ("p0", "p1"))
shape = (16, 12, 20)
plan = ParallelFFT(mesh, shape, ("p0", "p1"), method="auto",
                   comm_dtype="int8", tuner_cache=cache)
# seed the disk cache with a hand-mixed schedule BEFORE plan.schedule is
# first read: the plan must consume it instead of benchmarking
mixed = [["traditional", 1, "complex64"], ["pipelined", 2, "bf16"]]
Path(cache).write_text(json.dumps(
    {tuner.plan_key(plan): {"schedule": mixed, "timings": {}}}))
# legacy 3-field disk rows upgrade to full StageEntry rows on load
from repro.core.planconfig import as_schedule
assert plan.schedule == as_schedule(mixed)

# backward executor: same schedule, reversed stage order
bwd_sched = plan._backward_shard.keywords["schedule"]
assert bwd_sched == plan.schedule[::-1]
# and its exchange stages are the forward ones reversed with v/w swapped
fwd_ex = [s for s in plan.stages if isinstance(s, ExchangeStage)]
bwd_ex = [s for s in plan._backward_shard.keywords["stages"]
          if isinstance(s, ExchangeStage)]
assert [(s.v, s.w) for s in bwd_ex] == [(s.w, s.v) for s in reversed(fwd_ex)]

# mixed-schedule round trip: backward(forward(x)) ~= x (bf16-stage lossy)
rng = np.random.default_rng(0)
x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
back = np.asarray(plan.backward(plan.forward(jnp.asarray(x))))
rel = np.linalg.norm(back - x) / np.linalg.norm(x)
assert rel < 1e-2, rel
print("BACKWARD AUTO OK", rel)
""", ndev=8)


def test_r2c_backward_odd_trailing_extents(subproc):
    """real=True backward transforms with odd trailing extents: the c2r
    stage must irfft at the explicit logical length (n=), which the
    Hermitian-reduced extent alone cannot recover (n//2+1 maps both n and
    n-1 onto the same spectrum length).  Feeds np.fft.rfftn oracles
    straight into backward() on slab and pencil grids."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
# odd trailing extents, including odd == even+1 aliasing pairs (11 vs 10)
for shape in ((8, 6, 11), (12, 10, 9), (13, 9, 7)):
    for grid in (("p0",), ("p0", "p1")):
        plan = ParallelFFT(mesh, shape, grid, real=True)
        assert plan.output_pencil.logical[-1] == shape[-1] // 2 + 1
        x = rng.standard_normal(shape).astype(np.float32)
        # backward of the numpy oracle spectrum reproduces x: proves the
        # irfft ran at n=shape[-1], not 2*(n//2+1-1)
        back = np.asarray(plan.backward(jnp.asarray(np.fft.rfftn(x))))
        assert back.shape == shape
        np.testing.assert_allclose(back, x, rtol=3e-4, atol=3e-3)
        # and the plan's own spectrum round-trips too
        back2 = np.asarray(plan.backward(plan.forward(jnp.asarray(x))))
        np.testing.assert_allclose(back2, x, rtol=3e-4, atol=3e-3)
        print("ok", shape, grid)
print("R2C ODD BACKWARD OK")
""", ndev=8)


def test_model_flops_known_shapes():
    """Pin the 5 N log2 N accounting: c2c counts every stage at the full
    logical length; r2c halves the real stage and shrinks the Hermitian
    axis's contribution to later stages' batches."""
    from repro.core.meshutil import make_mesh

    mesh = make_mesh((1,), ("p0",))
    # c2c (8,8,8): 3 stages x 5*8*log2(8) * batch 64
    assert ParallelFFT(mesh, (8, 8, 8), ("p0",)).model_flops() == 3 * 5 * 8 * 3 * 64
    # r2c (8,8,8): r2c stage at half, then two c2c stages with the last
    # axis reduced to 8//2+1 = 5 in their batches
    want = 0.5 * 5 * 8 * 3 * 64 + 2 * (5 * 8 * 3 * (8 * 5))
    assert ParallelFFT(mesh, (8, 8, 8), ("p0",), real=True).model_flops() == want
    # non-power-of-two length uses log2 of the true logical n
    import math
    got = ParallelFFT(mesh, (6, 4), ("p0",)).model_flops()
    assert abs(got - (5 * 6 * math.log2(6) * 4 + 5 * 4 * 2 * 6)) < 1e-9


def test_pfft_matmul_impl(subproc):
    """Local FFT via the Pallas four-step matmul kernel inside the plan."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
mesh = make_mesh((4,), ("p0",))
rng = np.random.default_rng(0)
shape = (16, 8, 12)
plan = ParallelFFT(mesh, shape, ("p0",), impl="matmul")
x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
y = plan.forward(jnp.asarray(x))
np.testing.assert_allclose(np.asarray(y), np.fft.fftn(x), rtol=3e-4, atol=5e-3)
back = plan.backward(y)
np.testing.assert_allclose(np.asarray(back), x, rtol=3e-4, atol=5e-3)
print("PFFT MATMUL OK")
""", ndev=4)


@given(d=st.integers(2, 4), seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_plan_structure_properties(d, seed):
    """Plan invariants on a trivial 1-device mesh: d transforms, k exchanges,
    output pencil aligned in the axes the paper says (hypothesis over dims)."""
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ExchangeStage, FFTStage

    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(4, 10)) for _ in range(d))
    mesh = make_mesh((1,), ("p0",))
    plan = ParallelFFT(mesh, shape, ("p0",))
    ffts = [s for s in plan.stages if isinstance(s, FFTStage)]
    exs = [s for s in plan.stages if isinstance(s, ExchangeStage)]
    assert len(ffts) == d                      # d partial transforms
    assert len(exs) == 1                       # k = 1 redistribution (slab)
    assert {s.axis for s in ffts} == set(range(d))
    # paper Eq. 14: output is x-aligned (axis 0 local), axis 1 distributed
    assert plan.output_pencil.placement[0] is None
    assert plan.output_pencil.placement[1] == "p0" 
