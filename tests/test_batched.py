"""Batched multi-field plan execution: N fields per invocation, one
collective per exchange stage, bit-identical to the per-field loop for
lossless payloads, tuner batch dimension, batch-aware cost models."""


def test_forward_many_matches_per_field_loop(subproc):
    """forward_many/backward_many over N fields is bit-identical to an
    N-iteration per-field loop with the lossless payload, on slab and
    pencil grids, forward and backward, c2c and r2c specs, all three
    batch_fusion modes (issue acceptance criterion)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 20)
N = 3
cases = [
    (("p0",), dict()),                               # slab c2c
    (("p0", "p1"), dict()),                          # pencil c2c
    (("p0", "p1"), dict(real=True)),                 # pencil r2c spec
    (("p0", "p1"), dict(method="pipelined", chunks=2)),  # sliced exchange
]
for grid, kw in cases:
    for fusion in ("stacked", "pipelined-across-fields", "per-field"):
        plan = ParallelFFT(mesh, shape, grid, batch_fusion=fusion, **kw)
        x = rng.standard_normal((N, *shape)).astype(np.float32)
        if plan.input_dtype == jnp.complex64:
            x = (x + 1j * rng.standard_normal((N, *shape))).astype(np.complex64)
        xs = jnp.asarray(x)
        ref = jnp.stack([plan.forward(xs[i]) for i in range(N)])
        got = plan.forward_many(xs)
        assert jnp.array_equal(got, ref), (grid, kw, fusion, "forward")
        back_ref = jnp.stack([plan.backward(ref[i]) for i in range(N)])
        back = plan.backward_many(got)
        assert jnp.array_equal(back, back_ref), (grid, kw, fusion, "backward")
        np.testing.assert_allclose(np.asarray(back), x, rtol=3e-4, atol=3e-3)
    print("ok", grid, kw)

# pytree API mirrors structure; a d+1-dim forward() input routes batched
plan = ParallelFFT(mesh, shape, ("p0", "p1"))
x = (rng.standard_normal((N, *shape))
     + 1j * rng.standard_normal((N, *shape))).astype(np.complex64)
ref = plan.forward_many(jnp.asarray(x))
tree = plan.forward_many({"u": jnp.asarray(x[0]), "v": jnp.asarray(x[1]),
                          "w": jnp.asarray(x[2])})
assert set(tree) == {"u", "v", "w"}
for i, k in enumerate(sorted(("u", "v", "w"))):
    assert jnp.array_equal(tree[k], ref[i]), k
assert jnp.array_equal(plan.forward(jnp.asarray(x)), ref)
back_tree = plan.backward_many(tree)
assert set(back_tree) == {"u", "v", "w"}
print("BATCHED LOOP EQUIV OK")
""", ndev=8)


def test_batched_stacked_issues_one_collective_per_stage(subproc):
    """Acceptance criterion: the stacked batched path issues exactly one
    all-to-all per exchange stage for N fields (counted in the jaxpr),
    forward and backward; the per-field baseline pays N per stage."""
    subproc("""
import jax, jax.numpy as jnp
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
shape = (16, 12, 20)
N = 3
def count_a2a(fn, shape, dtype):
    return str(jax.make_jaxpr(fn)(jax.ShapeDtypeStruct(shape, dtype))).count("all_to_all")

for grid in (("p0",), ("p0", "p1")):
    plan = ParallelFFT(mesh, shape, grid)  # stacked default, lossless payload
    n_fwd = count_a2a(plan.forward_many_padded(N),
                      (N, *plan.input_pencil.physical), plan.input_dtype)
    assert n_fwd == plan.n_exchanges, (grid, n_fwd)
    n_bwd = count_a2a(plan.backward_many_padded(N),
                      (N, *plan.output_pencil.physical), plan.spectral_dtype)
    assert n_bwd == plan.n_exchanges, (grid, n_bwd)
    pf = ParallelFFT(mesh, shape, grid, batch_fusion="per-field")
    n_pf = count_a2a(pf.forward_many_padded(N),
                     (N, *pf.input_pencil.physical), pf.input_dtype)
    assert n_pf == N * pf.n_exchanges, (grid, n_pf)
    print("ok", grid, n_fwd, n_pf)
print("BATCHED COLLECTIVE COUNT OK")
""", ndev=8)


def test_exchange_nbatch_matches_per_field(subproc):
    """redistribute-level contract of the batched entry point: one
    ``exchange_shard(..., nbatch=1)`` over a stacked block equals the
    per-field loop bitwise for the lossless payload (all three engines,
    slab and pencil inputs, including traditional ``transposed_out``);
    lossy payloads stay within codec bounds per field even when one field
    is 1000x larger (per-(field, chunk) int8 scales)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.core.meshutil import make_mesh, shard_map
from repro.core.pencil import make_pencil, pad_global
from repro.core.redistribute import exchange_shard

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 10)
N = 3
cases = [
    ((None, "p1", None), (4, 4, 1), 0, 1),   # slab
    (("p0", "p1", None), (4, 4, 4), 2, 1),   # pencil, v trailing
]
for placement, divisors, v, w in cases:
    src = make_pencil(mesh, shape, placement, divisors=divisors)
    dst = src.exchanged(v, w)
    x = (rng.standard_normal((N, *shape))
         + 1j * rng.standard_normal((N, *shape))).astype(np.complex64)
    x[1] *= 1e3  # int8 scales must not let this field drown the others
    xs = jax.device_put(pad_global(jnp.asarray(x), src, nbatch=1),
                        src.batched_sharding())
    for method in ("fused", "traditional", "pipelined"):
        one = shard_map(partial(exchange_shard, v=v, w=w, group="p1",
                                method=method, chunks=2),
                        mesh=mesh, in_specs=src.spec, out_specs=dst.spec,
                        check_vma=False)
        want = jnp.stack([one(xs[i]) for i in range(N)])
        for comm_dtype in (None, "bf16", "int8"):
            many = shard_map(partial(exchange_shard, v=v, w=w, group="p1",
                                     method=method, chunks=2,
                                     comm_dtype=comm_dtype, nbatch=1),
                             mesh=mesh, in_specs=src.batched_spec(),
                             out_specs=dst.batched_spec(), check_vma=False)
            got = many(xs)
            if comm_dtype is None:
                assert jnp.array_equal(got, want), (placement, method)
            else:
                bound = 5e-3 if comm_dtype == "bf16" else 2e-2
                for f in range(N):
                    rel = (np.linalg.norm(np.asarray(got[f] - want[f]))
                           / np.linalg.norm(np.asarray(want[f])))
                    assert rel < bound, (placement, method, comm_dtype, f, rel)
    print("ok", placement)

# traditional transposed_out with a batch: chunk axis leads, batch follows
src = make_pencil(mesh, shape, (None, "p1", None), divisors=(4, 4, 1))
dst = src.exchanged(0, 1)
x = (rng.standard_normal((N, *shape))
     + 1j * rng.standard_normal((N, *shape))).astype(np.complex64)
xs = jax.device_put(pad_global(jnp.asarray(x), src, nbatch=1),
                    src.batched_sharding())
tspec1 = jax.sharding.PartitionSpec(None, *dst.spec)
one_t = shard_map(partial(exchange_shard, v=0, w=1, group="p1",
                          method="traditional", transposed_out=True),
                  mesh=mesh, in_specs=src.spec, out_specs=tspec1, check_vma=False)
want_t = jnp.stack([one_t(xs[i]) for i in range(N)], axis=1)  # (m, N, ...)
tspecN = jax.sharding.PartitionSpec(None, None, *dst.spec)
many_t = shard_map(partial(exchange_shard, v=0, w=1, group="p1",
                           method="traditional", transposed_out=True, nbatch=1),
                   mesh=mesh, in_specs=src.batched_spec(), out_specs=tspecN,
                   check_vma=False)
got_t = many_t(xs)
assert got_t.shape == want_t.shape and jnp.array_equal(got_t, want_t)
print("BATCHED EXCHANGE NBATCH OK")
""", ndev=8)


def test_batched_auto_tuner_schedule(subproc, tmp_path):
    """method="auto" with N fields tunes the 4-dimensional candidate space
    (engine x chunks x payload x batch_fusion), keys the cache on the batch
    size (schema v4), round-trips through disk into a fresh memo, and the
    tuned batched plan still matches the stacked reference bitwise."""
    cache = tmp_path / "fft_tuner.json"
    code = f"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

cache = {str(cache)!r}
mesh = make_mesh((2, 2), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto", tuner_cache=cache)
bs = plan.batched_schedule(3)
assert len(bs) == plan.n_exchanges == 2
for method, chunks, comm_dtype, impl, fusion in bs:
    assert method in ("fused", "traditional", "pipelined")
    assert comm_dtype == "complex64"  # lossless budget
    assert impl == "jnp"  # no pallas budget requested
    assert fusion in ("stacked", "pipelined-across-fields", "per-field")

disk = json.loads(open(cache).read())
bkey = tuner.plan_key(plan, nfields=3)
assert bkey in disk
decoded = json.loads(bkey)
assert decoded["schema"] == tuner.SCHEMA_VERSION and decoded["nfields"] == 3
want_tags = {{tuner._tag(c) for c in tuner.batched_candidates_for(None)}}
for per in disk[bkey]["timings"].values():
    assert {{k for k in per if ":" not in k}} == want_tags

# batch size is part of the key: 1-field and 3-field entries never collide
assert tuner.plan_key(plan, nfields=1) != bkey

# fresh memo must reload from disk, not re-benchmark
tuner._MEMO.clear()
tuner.tune_plan = None
plan2 = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto", tuner_cache=cache)
assert plan2.batched_schedule(3) == bs

rng = np.random.default_rng(0)
x = (rng.standard_normal((3, 16, 8, 8))
     + 1j * rng.standard_normal((3, 16, 8, 8))).astype(np.complex64)
ref = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1")).forward_many(jnp.asarray(x))
got = plan2.forward_many(jnp.asarray(x))
assert jnp.array_equal(got, ref)  # lossless budget: bit-identical to fused
back = plan2.backward_many(got)
np.testing.assert_allclose(np.asarray(back), x, rtol=3e-4, atol=3e-3)
print("BATCHED TUNER OK", json.dumps([list(s) for s in bs]))
"""
    out = subproc(code, ndev=4)
    assert "BATCHED TUNER OK" in out


def test_batched_models(subproc):
    """Batch-aware analytic models: flops and wire bytes scale linearly in
    nfields (int8 scale vectors included); the time model prices stacked
    below per-field (one collective latency instead of N) and
    pipelined-across-fields between them on compute-heavy stages."""
    code = """
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.redistribute import ICI_LATENCY_S, exchange_time_model

mesh = make_mesh((2, 2), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"))
assert plan.model_flops(nfields=3) == 3 * plan.model_flops()
assert plan.comm_bytes_per_device(8, nfields=3) == 3 * plan.comm_bytes_per_device(8)
b1 = plan.comm_bytes_per_device(8, comm_dtype="int8")
b3 = plan.comm_bytes_per_device(8, comm_dtype="int8", nfields=3)
assert b3 == 3 * b1  # per-(field, destination) scales scale with N too

t_st = plan.model_time_s(nfields=3, batch_fusion="stacked")
t_pl = plan.model_time_s(nfields=3, batch_fusion="pipelined-across-fields")
t_pf = plan.model_time_s(nfields=3, batch_fusion="per-field")
assert t_st < t_pf  # N-1 collective latencies saved
assert plan.model_time_s(nfields=1) < t_st

# stage-level: on a compute-heavy stage whose comm and FFT times are both
# large next to the collective latency, pipelined-across-fields hides
# (N-1) x max(comm, fft) and beats both stacked and per-field
from repro.core.pencil import make_pencil
src = make_pencil(mesh, (256, 256, 64), (None, "p1", "p0"))
args = dict(itemsize=8, overlap_compute_s=100e-6, nfields=4)
stacked = exchange_time_model(src, 0, 1, batch_fusion="stacked", **args)
across = exchange_time_model(src, 0, 1, batch_fusion="pipelined-across-fields", **args)
serial = exchange_time_model(src, 0, 1, batch_fusion="per-field", **args)
assert across < stacked < serial, (across, stacked, serial)
# and on a latency-bound stage (tiny block, no compute) stacked wins: one
# collective launch instead of N
tiny = make_pencil(mesh, (16, 8, 8), (None, "p1", "p0"))
args = dict(itemsize=8, overlap_compute_s=0.0, nfields=4)
t_tiny_st = exchange_time_model(tiny, 0, 1, batch_fusion="stacked", **args)
t_tiny_pf = exchange_time_model(tiny, 0, 1, batch_fusion="per-field", **args)
assert t_tiny_st < t_tiny_pf
assert ICI_LATENCY_S > 0
print("BATCHED MODELS OK")
"""
    out = subproc(code, ndev=4)
    assert "BATCHED MODELS OK" in out
