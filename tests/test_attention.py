"""Blockwise/decode attention vs a naive dense-softmax oracle."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention, decode_attention


def naive_attention(q, k, v, *, causal, q_offset=0, kv_len=None):
    B, Sq, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.astype(np.float32).reshape(B, Sq, Hkv, G, dh)
    s = np.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(np.float32)) / math.sqrt(dh)
    kv_pos = np.arange(k.shape[1])
    mask = np.ones((Sq, k.shape[1]), bool)
    if causal:
        q_pos = q_offset + np.arange(Sq)
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if kv_len is not None:
        mask &= kv_pos[None, :] < kv_len
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bhgqk,bkhd->bqhgd", p, v.astype(np.float32))
    return o.reshape(B, Sq, Hq, -1)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("Sq,Skv,qb", [(16, 16, 4), (32, 32, 32), (24, 24, 7),
                                       (8, 24, 4)])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])
def test_blockwise_matches_naive(causal, Sq, Skv, qb, Hq, Hkv):
    rng = np.random.default_rng(0)
    B, dh = 2, 16
    q = rng.standard_normal((B, Sq, Hq, dh)).astype(np.float32)
    k = rng.standard_normal((B, Skv, Hkv, dh)).astype(np.float32)
    v = rng.standard_normal((B, Skv, Hkv, dh)).astype(np.float32)
    off = Skv - Sq if causal else 0
    got = blockwise_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=causal, q_block=qb, q_offset=off)
    want = naive_attention(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_kv_len_masking():
    rng = np.random.default_rng(1)
    B, S, H, dh = 1, 16, 2, 8
    q = rng.standard_normal((B, S, H, dh)).astype(np.float32)
    k = rng.standard_normal((B, S, H, dh)).astype(np.float32)
    v = rng.standard_normal((B, S, H, dh)).astype(np.float32)
    got = blockwise_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=False, q_block=4, kv_len=jnp.int32(10))
    want = naive_attention(q, k[:, :10], v[:, :10], causal=False)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_decode_matches_naive():
    rng = np.random.default_rng(2)
    B, M, Hq, Hkv, dh = 3, 32, 8, 2, 16
    q = rng.standard_normal((B, 1, Hq, dh)).astype(np.float32)
    k = rng.standard_normal((B, M, Hkv, dh)).astype(np.float32)
    v = rng.standard_normal((B, M, Hkv, dh)).astype(np.float32)
    cur = 20
    got = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           jnp.int32(cur))
    want = naive_attention(q, k[:, :cur], v[:, :cur], causal=False)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_gradients_finite():
    rng = np.random.default_rng(3)
    B, S, H, dh = 2, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)

    def f(q):
        return jnp.sum(blockwise_attention(q, q, q, causal=True, q_block=4) ** 2)

    g = jax.grad(f)(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_ulysses_matches_blockwise(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.meshutil import make_mesh, set_mesh
from repro.models.attention import blockwise_attention, ulysses_attention
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, S, H, dh = 2, 32, 8, 16
q, k, v = (jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32) for _ in range(3))
with set_mesh(mesh):
    want = blockwise_attention(q, k, v, causal=True, q_block=8)
    got = jax.jit(lambda q, k, v: ulysses_attention(
        q, k, v, mesh, tp_axis="model", causal=True, q_block=8))(q, k, v)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
# GQA: kv heads fewer than tp -> replicated path
k2, v2 = k[:, :, :2], v[:, :, :2]
with set_mesh(mesh):
    want = blockwise_attention(q, k2, v2, causal=True, q_block=8)
    got = jax.jit(lambda q, k, v: ulysses_attention(
        q, k, v, mesh, tp_axis="model", causal=True, q_block=8))(q, k2, v2)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
print("ULYSSES OK")
""", ndev=8)


def test_triangular_matches_blockwise():
    from repro.models.attention import triangular_causal_attention
    rng = np.random.default_rng(7)
    for (S, qb, Hq, Hkv) in [(32, 8, 4, 2), (24, 7, 4, 4), (16, 16, 2, 1)]:
        q = jnp.asarray(rng.standard_normal((2, S, Hq, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, S, Hkv, 16)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, S, Hkv, 16)), jnp.float32)
        want = blockwise_attention(q, k, v, causal=True, q_block=qb)
        got = triangular_causal_attention(q, k, v, q_block=qb, bf16_compute=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_bf16_compute_close_to_fp32():
    rng = np.random.default_rng(8)
    B, S, H, dh = 2, 32, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.bfloat16)
    base = blockwise_attention(q, k, v, causal=True, q_block=8)
    opt = blockwise_attention(q, k, v, causal=True, q_block=8, bf16_compute=True)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), rtol=0.1, atol=0.05)
    d = decode_attention(q[:, :1], k, v, jnp.int32(S), bf16_compute=True)
    d0 = decode_attention(q[:, :1], k, v, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(d, np.float32),
                               np.asarray(d0, np.float32), rtol=0.1, atol=0.05)
