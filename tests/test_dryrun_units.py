"""Unit tests for dry-run plumbing that don't need 512 devices."""



def _collective_bytes(text):
    from repro.launch.dryrun_lib import collective_bytes
    return collective_bytes(text)


HLO = """
  %all-reduce.1 = f32[16,256]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%y), channel_id=2, replica_groups=[32,8]<=[256], dimensions={0}
  %rs = f32[8,16]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[64,4]<=[256], to_apply=%add
  %a2a = c64[32,32]{1,0} all-to-all(%w), channel_id=4, replica_groups=[16,16]<=[256]
  %cp = f32[10]{0} collective-permute(%v), channel_id=5
  %tuple_ar = (f32[4]{0}, f32[2]{0}) all-reduce(%a, %b), channel_id=6, replica_groups=[16,16]<=[256], to_apply=%add
  %fusion.1 = f32[16,256]{1,0} fusion(%all-reduce.1), kind=kLoop
"""


def test_collective_bytes_parser():
    out = _collective_bytes(HLO)
    assert out["all-reduce"] == 16 * 256 * 4 + (4 + 2) * 4
    assert out["all-gather"] == 64 * 128 * 2 // 8       # result / group
    assert out["reduce-scatter"] == 8 * 16 * 4 * 4      # result * group
    assert out["all-to-all"] == 32 * 32 * 8
    assert out["collective-permute"] == 10 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_async_pairs_not_double_counted():
    txt = """
  %s = f32[8]{0} all-reduce-start(%x), channel_id=1, replica_groups=[2,2]<=[4], to_apply=%add
  %d = f32[8]{0} all-reduce-done(%s)
"""
    out = _collective_bytes(txt)
    assert out["all-reduce"] == 8 * 4


def test_input_specs_shapes():
    from repro import configs
    from repro.launch.dryrun_lib import input_specs
    cfg = configs.get("llava_next_34b")
    batch, (B, S, kind) = input_specs(cfg, "train_4k")
    assert batch["tokens"].shape == (256, 4096)
    assert batch["frontend"].shape == (256, 2048, 7168)
    cfgA = configs.get("seamless_m4t_medium")
    batch, _ = input_specs(cfgA, "prefill_32k")
    assert set(batch) == {"tokens", "frontend"}
    assert batch["frontend"].shape == (32, 32768, 1024)


def test_all_cells_table():
    from repro import configs
    cells = configs.all_cells()
    assert len(cells) == 10 * 3 + 2  # 3 shapes everywhere + long_500k on 2 ssm archs
    assert ("falcon_mamba_7b", "long_500k") in cells
    assert ("qwen2_72b", "long_500k") not in cells


def test_model_flops_accounting():
    from repro import configs
    from repro.models.config import active_param_count, param_count
    ds = configs.get("deepseek_v2_lite_16b")
    n, na = param_count(ds), active_param_count(ds)
    assert 14e9 < n < 18e9, n            # ~15.7B published
    assert 2e9 < na < 4e9, na            # ~2.4B active published
    q = configs.get("qwen2_72b")
    assert 70e9 < param_count(q) < 75e9
    g = configs.get("glm4_9b")
    assert 8e9 < param_count(g) < 11e9
    z = configs.get("zamba2_2p7b")
    assert 2e9 < param_count(z) < 3.5e9
    f = configs.get("falcon_mamba_7b")
    assert 6e9 < param_count(f) < 8.5e9
