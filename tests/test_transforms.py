"""Per-axis TransformSpec plans (r2c / DCT / DST / pruned) — roundtrip and
scipy-reference correctness on slab and pencil grids, spec validation, and
the mixed-transform autotuner path (issue acceptance criteria)."""

import numpy as np
import pytest

from repro.core.fftcore import TransformSpec, as_spec, dealias_grid


# ---------------------------------------------------------------------------
# Unit tests (no devices)
# ---------------------------------------------------------------------------


def test_spec_parsing_and_tags():
    assert as_spec("c2c") == TransformSpec.c2c()
    assert as_spec("r2c") == TransformSpec.r2c()
    assert as_spec("dct2") == TransformSpec.dct(2)
    assert as_spec("dct3") == TransformSpec.dct(3)
    assert as_spec("dst2") == TransformSpec.dst(2)
    assert as_spec("dst3") == TransformSpec.dst(3)
    spec = TransformSpec.pruned(12)
    assert as_spec(spec) is spec
    assert spec.tag() == "c2c[12]"
    assert TransformSpec.r2c(n_keep=5).tag() == "r2c[5]"
    assert TransformSpec.dct(3).tag() == "dct3"
    with pytest.raises(ValueError):
        as_spec("dft")
    with pytest.raises(TypeError):
        as_spec(42)


def test_spec_validation():
    with pytest.raises(ValueError):
        TransformSpec("hartley")
    with pytest.raises(ValueError):
        TransformSpec.dct(1)  # only II/III supported
    with pytest.raises(ValueError):
        TransformSpec("dct", n_keep=4)  # pruning is c2c/r2c only
    with pytest.raises(ValueError):
        TransformSpec.pruned(0)
    with pytest.raises(ValueError):
        TransformSpec.pruned(9).spectral_extent(8)  # n_keep > spectrum
    assert TransformSpec.c2c().spectral_extent(8) == 8
    assert TransformSpec.r2c().spectral_extent(9) == 5
    assert TransformSpec.r2c(n_keep=3).spectral_extent(9) == 3
    assert TransformSpec.pruned(8).spectral_extent(12) == 8
    assert TransformSpec.dst().spectral_extent(7) == 7
    assert dealias_grid(32) == 48


def test_plan_transforms_validation():
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT

    mesh = make_mesh((1,), ("p0",))
    with pytest.raises(ValueError):  # wrong arity
        ParallelFFT(mesh, (8, 8, 8), ("p0",), transforms=("c2c", "c2c"))
    with pytest.raises(ValueError):  # real= and transforms= are exclusive
        ParallelFFT(mesh, (8, 8), ("p0",), real=True, transforms=("c2c", "r2c"))
    # r2c must be applied while the data is still real: every axis after it
    # (higher index, applied earlier) must be dct/dst
    with pytest.raises(ValueError):
        ParallelFFT(mesh, (8, 8), ("p0",), transforms=("r2c", "c2c"))
    with pytest.raises(ValueError):  # two r2c axes
        ParallelFFT(mesh, (8, 8, 8), ("p0",), transforms=("c2c", "r2c", "r2c"))
    # legal: r2c with trailing real-to-real axes, c2c applied after
    plan = ParallelFFT(mesh, (8, 8, 8), ("p0",), transforms=("c2c", "r2c", "dst2"))
    assert plan.output_pencil.logical == (8, 5, 8)
    # all-real plans keep a real spectral dtype end to end
    plan = ParallelFFT(mesh, (8, 8), ("p0",), transforms=("dct2", "dct2"))
    import jax.numpy as jnp

    assert plan.input_dtype == jnp.float32
    assert plan.spectral_dtype == jnp.float32


def test_pruned_plan_structure():
    """Pruned axes shrink the pencil trace (exchanges after a truncation
    carry only the retained modes) and real= sugar equals the spec form."""
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT

    mesh = make_mesh((1, 1), ("p0", "p1"))
    plan = ParallelFFT(mesh, (12, 12, 12), ("p0", "p1"),
                       transforms=(TransformSpec.pruned(8), TransformSpec.pruned(8),
                                   TransformSpec.r2c(n_keep=5)))
    assert plan.output_pencil.logical == (8, 8, 5)
    # dealiased exchanges move fewer elements than the full-spectrum plan:
    # every post-truncation pencil in the trace is elementwise smaller
    import numpy as np
    from repro.core.pfft import ExchangeStage

    full = ParallelFFT(mesh, (12, 12, 12), ("p0", "p1"), real=True)
    pruned_elems = sum(int(np.prod(p.logical)) for st, p in
                       zip(plan.stages, plan.pencil_trace)
                       if isinstance(st, ExchangeStage))
    full_elems = sum(int(np.prod(p.logical)) for st, p in
                     zip(full.stages, full.pencil_trace)
                     if isinstance(st, ExchangeStage))
    assert pruned_elems < full_elems
    sugar = ParallelFFT(mesh, (12, 12, 12), ("p0", "p1"), real=True)
    spec = ParallelFFT(mesh, (12, 12, 12), ("p0", "p1"),
                       transforms=("c2c", "c2c", "r2c"))
    assert sugar.transforms == spec.transforms
    assert sugar.output_pencil == spec.output_pencil


def test_trig_matrices_are_mutual_inverses():
    from repro.kernels.fft import ref

    for n in (5, 8, 16):
        c2, c3 = ref.dct_matrix(n, 2, np.float64), ref.dct_matrix(n, 3, np.float64)
        np.testing.assert_allclose(c3 @ c2, 2 * n * np.eye(n), atol=1e-9)
        s2, s3 = ref.dst_matrix(n, 2, np.float64), ref.dst_matrix(n, 3, np.float64)
        np.testing.assert_allclose(s3 @ s2, 2 * n * np.eye(n), atol=1e-9)


def test_local_trig_transforms_vs_scipy():
    """fftcore's FFT-trick DCT/DST and the kernels' matmul path both match
    scipy's unnormalized conventions, every type, both parities."""
    sf = pytest.importorskip("scipy.fft")
    import jax.numpy as jnp

    from repro.core import fftcore

    rng = np.random.default_rng(0)
    for n in (8, 9):
        x = rng.standard_normal((3, n)).astype(np.float32)
        for kind, sref in (("dct", sf.dct), ("dst", sf.dst)):
            for tt in (2, 3):
                spec = TransformSpec(kind, trig_type=tt)
                want = sref(x, type=tt, axis=1)
                for impl in ("jnp", "matmul"):
                    got = np.asarray(fftcore.local_transform(
                        jnp.asarray(x), 1, fftcore.FORWARD, spec, n=n, impl=impl))
                    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
                    back = np.asarray(fftcore.local_transform(
                        jnp.asarray(want), 1, fftcore.BACKWARD, spec, n=n, impl=impl))
                    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Distributed plans (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------


def test_transform_plans_vs_scipy(subproc):
    """Every TransformSpec kind in a distributed plan, slab and pencil
    grids: forward matches the scipy/np reference composition and
    backward(forward(x)) round-trips below 1e-5 relative L2."""
    pytest.importorskip("scipy.fft")
    subproc("""
import jax, jax.numpy as jnp, numpy as np
import scipy.fft as sf
from repro.core.fftcore import TransformSpec
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 20)

def ref_nd(x, specs):
    y = np.asarray(x, np.float64)
    for axis in range(len(specs) - 1, -1, -1):  # plan apply order
        sp = specs[axis]
        if sp.kind == "r2c":
            y = np.fft.rfft(y, axis=axis)
        elif sp.kind == "c2c":
            y = np.fft.fft(y, axis=axis)
        elif sp.kind == "dct":
            y = sf.dct(y.real, type=sp.trig_type, axis=axis) + (
                1j * sf.dct(y.imag, type=sp.trig_type, axis=axis)
                if np.iscomplexobj(y) else 0)
        else:
            y = sf.dst(y.real, type=sp.trig_type, axis=axis) + (
                1j * sf.dst(y.imag, type=sp.trig_type, axis=axis)
                if np.iscomplexobj(y) else 0)
    return y

cases = [
    ("dct2", "dct2", "dct2"),
    ("dst2", "dst2", "dst2"),
    ("dct3", "dst3", "dct2"),
    ("dct2", "c2c", "r2c"),      # the Chebyshev-Dirichlet Poisson layout
    ("c2c", "r2c", "dst2"),      # r2c mid-plan behind a trailing DST
]
for grid in (("p0",), ("p0", "p1")):
    for tags in cases:
        specs = tuple(TransformSpec(t[:3], trig_type=int(t[3])) if t[0] == "d"
                      else TransformSpec(t) for t in tags)
        plan = ParallelFFT(mesh, shape, grid, transforms=tags)
        x = rng.standard_normal(shape).astype(np.float32)
        y = np.asarray(plan.forward(jnp.asarray(x)))
        want = ref_nd(x, specs)
        scale = np.abs(want).max()
        np.testing.assert_allclose(y, want.astype(y.dtype), rtol=2e-4,
                                   atol=2e-5 * scale)
        back = np.asarray(plan.backward(jnp.asarray(y)))
        rel = np.linalg.norm(back - x) / np.linalg.norm(x)
        assert rel < 1e-5, (grid, tags, rel)
        print("ok", grid, tags)
print("TRANSFORM PLANS VS SCIPY OK")
""", ndev=8)


def test_pruned_dealias_plans(subproc):
    """Pruned/truncated axes (the fused 3/2-rule): forward equals
    truncate(fft_n(x)) with the centered keep, spectral round trip
    forward(backward(s)) == s below 1e-5, and backward+forward of a
    physical field equals the np dealiasing projection — slab and pencil."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.fftcore import TransformSpec, dealias_grid
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
N = 8
M = dealias_grid(N)  # 12
keep = np.r_[0:(N + 1) // 2, M - N // 2:M]

for grid in (("p0",), ("p0", "p1")):
    # pure c2c pruning: arbitrary complex spectra round-trip exactly
    plan = ParallelFFT(mesh, (M, M, M), grid,
                       transforms=(TransformSpec.pruned(N),) * 3)
    assert plan.output_pencil.logical == (N, N, N)
    x = (rng.standard_normal((M, M, M))
         + 1j * rng.standard_normal((M, M, M))).astype(np.complex64)
    y = np.asarray(plan.forward(jnp.asarray(x)))
    want = np.fft.fftn(x)[np.ix_(keep, keep, keep)]
    np.testing.assert_allclose(y, want, rtol=3e-4, atol=3e-3)
    s = (rng.standard_normal((N, N, N))
         + 1j * rng.standard_normal((N, N, N))).astype(np.complex64)
    rt = np.asarray(plan.forward(plan.backward(jnp.asarray(s))))
    rel = np.linalg.norm(rt - s) / np.linalg.norm(s)
    assert rel < 1e-5, (grid, rel)
    # backward o forward is the np dealiasing projection of the field
    proj = np.asarray(plan.backward(plan.forward(jnp.asarray(x))))
    full = np.fft.fftn(x)
    mask = np.zeros((M, M, M))
    mask[np.ix_(keep, keep, keep)] = 1.0
    np.testing.assert_allclose(proj, np.fft.ifftn(full * mask),
                               rtol=3e-4, atol=3e-3)

    # dealiased rfft pipeline (the navier_stokes layout): valid spectra
    # (unpaired -N/2 rows empty) round-trip below 1e-5
    plan = ParallelFFT(mesh, (M, M, M), grid,
                       transforms=(TransformSpec.pruned(N), TransformSpec.pruned(N),
                                   TransformSpec.r2c(n_keep=N // 2 + 1)))
    assert plan.output_pencil.logical == (N, N, N // 2 + 1)
    u = rng.standard_normal((M, M, M)).astype(np.float32)
    s = np.array(plan.forward(jnp.asarray(u)))
    s[N // 2, :, :] = 0
    s[:, N // 2, :] = 0
    rt = np.asarray(plan.forward(plan.backward(jnp.asarray(s))))
    rel = np.linalg.norm(rt - s) / np.linalg.norm(s)
    assert rel < 1e-5, (grid, rel)
    print("ok", grid)
print("PRUNED DEALIAS OK")
""", ndev=8)


def test_mixed_transform_auto_tuned(subproc, tmp_path):
    """method="auto" tunes a mixed-transform (pruned + r2c) plan end to
    end: the tuned schedule round-trips through the disk cache into a
    fresh-memo plan, and the transform stays correct under the tuned
    per-stage schedule (issue acceptance criterion)."""
    cache = tmp_path / "fft_tuner.json"
    subproc(f"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import tuner
from repro.core.fftcore import TransformSpec
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

cache = {str(cache)!r}
mesh = make_mesh((2, 2), ("p0", "p1"))
specs = (TransformSpec.pruned(8), TransformSpec.c2c(), TransformSpec.r2c())
plan = ParallelFFT(mesh, (12, 8, 8), ("p0", "p1"), transforms=specs,
                   method="auto", tuner_cache=cache)
sched = plan.schedule
assert len(sched) == plan.n_exchanges == 2

# the cache key must carry the per-axis transform tags (a pruned plan's
# stage shapes differ from the plain c2c plan of the same global shape)
disk = json.loads(open(cache).read())
key = tuner.plan_key(plan)
assert key in disk
assert json.loads(key)["transforms"] == ["c2c[8]", "c2c", "r2c"]

# fresh-memo reload must consume the cache, not re-benchmark
tuner._MEMO.clear()
tuner.tune_plan = None
plan2 = ParallelFFT(mesh, (12, 8, 8), ("p0", "p1"), transforms=specs,
                    method="auto", tuner_cache=cache)
assert plan2.schedule == sched

# and the tuned mixed-transform plan is still correct
rng = np.random.default_rng(0)
u = rng.standard_normal((12, 8, 8)).astype(np.float32)
fused = ParallelFFT(mesh, (12, 8, 8), ("p0", "p1"), transforms=specs)
np.testing.assert_allclose(np.asarray(plan2.forward(jnp.asarray(u))),
                           np.asarray(fused.forward(jnp.asarray(u))),
                           rtol=1e-5, atol=1e-5)
s = np.array(plan2.forward(jnp.asarray(u)))
s[4, :, :] = 0  # unpaired -4 row of the even pruned axis (see TransformSpec.pruned)
rt = np.asarray(plan2.forward(plan2.backward(jnp.asarray(s))))
rel = np.linalg.norm(rt - s) / np.linalg.norm(s)
assert rel < 1e-5, rel
print("MIXED AUTO OK", json.dumps([list(s) for s in sched]))
""", ndev=4)


def test_all_real_plan_exchanges_f32(subproc):
    """An all-DCT plan never goes complex: the spectral output is float32
    and the modeled wire bytes price f32 (4-byte) payloads — half the
    complex plan's traffic."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 12, 20), ("p0", "p1"),
                   transforms=("dct2", "dct2", "dct2"))
x = np.random.default_rng(0).standard_normal((16, 12, 20)).astype(np.float32)
y = plan.forward(jnp.asarray(x))
assert y.dtype == jnp.float32, y.dtype
assert all(dt == jnp.float32 for dt in plan.dtype_trace)
c2c = ParallelFFT(mesh, (16, 12, 20), ("p0", "p1"))
# auto itemsize: real exchanges at 4 bytes vs complex at 8
assert plan.comm_bytes_per_device() * 2 == c2c.comm_bytes_per_device()
assert plan.model_time_s() < c2c.model_time_s()
print("ALL REAL F32 OK")
""", ndev=8)
