"""Fault-tolerant runtime: train, checkpoint/restart resume, straggler log."""

import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.meshutil import make_mesh
from repro.data import SyntheticLMData
from repro.models.lm import LM
from repro.models.sharding import Axes
from repro.runtime import TrainConfig, Trainer


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = configs.smoke("glm4_9b")
    lm = LM(cfg, mesh, Axes(multi_pod=False), q_block=8, xent_chunks=2)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=16, global_batch=4)
    return mesh, lm, data, tmp_path_factory.mktemp("rt")


def test_train_reduces_loss(setup):
    mesh, lm, data, tmp = setup
    tc = TrainConfig(steps=30, ckpt_every=10, ckpt_dir=str(tmp / "run1"),
                     lr=3e-3, warmup=5)
    tr = Trainer(lm, data, tc)
    _, _, hist = tr.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)
    # heartbeat exists & has one record per step
    lines = (tmp / "run1" / "heartbeat.log").read_text().strip().splitlines()
    assert len(lines) >= tc.steps
    rec = json.loads(lines[0])
    assert "step" in rec and "t" in rec


def test_restart_resumes_from_checkpoint(setup):
    mesh, lm, data, tmp = setup
    ckpt = str(tmp / "run2")
    tc1 = TrainConfig(steps=10, ckpt_every=5, ckpt_dir=ckpt, lr=1e-3, warmup=2)
    t1 = Trainer(lm, data, tc1)
    _, _, h1 = t1.run()
    # second trainer with a longer horizon resumes at step 10, not 0
    tc2 = TrainConfig(steps=14, ckpt_every=5, ckpt_dir=ckpt, lr=1e-3, warmup=2)
    t2 = Trainer(lm, data, tc2)
    _, _, h2 = t2.run()
    assert h2[0]["step"] == 10 and h2[-1]["step"] == 13
    # deterministic data: the resumed stream must match a fresh 14-step run
    tc3 = TrainConfig(steps=14, ckpt_every=100, ckpt_dir=str(tmp / "run3"),
                      lr=1e-3, warmup=2)
    t3 = Trainer(lm, data, tc3)
    _, _, h3 = t3.run()
    np.testing.assert_allclose(h2[-1]["loss"], h3[-1]["loss"], rtol=2e-2)


def test_trainstep_donation_and_metrics(setup):
    mesh, lm, data, tmp = setup
    tc = TrainConfig(steps=2, ckpt_every=100, ckpt_dir=str(tmp / "run4"))
    tr = Trainer(lm, data, tc)
    params, opt_state, step = tr.init_state()
    p2, o2, m = tr.train_step(params, opt_state,
                              jax.device_put(data.host_local_batch(0), tr.bshard))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(o2.step) == 1
