"""Property tests for the Pencil alignment state (paper Secs. 3.4/3.5)."""

import pytest
from _hyp import given, settings, strategies as st

from repro.core.meshutil import make_mesh
from repro.core.pencil import group_size, make_pencil


def _mesh():
    return make_mesh((1, 1), ("p0", "p1"))  # trivial 1-device mesh: pure metadata


@given(n0=st.integers(1, 300), n1=st.integers(1, 300), n2=st.integers(1, 300),
       d0=st.integers(1, 8), d1=st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_make_pencil_divisibility(n0, n1, n2, d0, d1):
    mesh = _mesh()
    p = make_pencil(mesh, (n0, n1, n2), ("p0", "p1", None), divisors=(d0, d1, 1))
    for ext, log in zip(p.physical, p.logical):
        assert ext >= log
    assert p.physical[0] % d0 == 0 and p.physical[1] % d1 == 0
    assert p.local_shape == p.physical  # 1-device mesh: local == global


def test_exchanged_involution():
    mesh = _mesh()
    p = make_pencil(mesh, (8, 8, 8), ("p0", None, "p1"), divisors=(1, 1, 1))
    q = p.exchanged(1, 0)       # axis1 takes p0, axis0 aligned
    r = q.exchanged(0, 1)       # back
    assert r.placement == p.placement
    assert r.physical == p.physical


def test_exchanged_validation():
    mesh = _mesh()
    p = make_pencil(mesh, (8, 8), ("p0", None), divisors=(1, 1))
    with pytest.raises(ValueError):
        p.exchanged(0, 1)       # v must be aligned
    with pytest.raises(ValueError):
        p.exchanged(1, 1)       # w must be distributed


@given(v=st.integers(0, 2))
@settings(max_examples=10, deadline=None)
def test_with_axis_extent_repads(v):
    mesh = _mesh()
    p = make_pencil(mesh, (10, 12, 14), (None, "p0", "p1"), divisors=(1, 2, 2))
    q = p.with_axis_extent(v, 7)
    assert q.logical[v] == 7
    grp = q.placement[v]
    m = 1 if grp is None else group_size(mesh, grp)
    assert q.physical[v] % m == 0 and q.physical[v] >= 7
