"""Flash-attention Pallas kernel vs oracle (interpret mode), GQA + padding."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import attention_ref


def _oracle(q, k, v, causal):
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, dh).transpose(0, 2, 3, 1, 4).reshape(B * Hq, S, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh), G, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh), G, axis=0)
    want = attention_ref(qf, kf, vf, causal=causal)
    return want.reshape(B, Hkv, G, S, dh).transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, dh)


@pytest.mark.parametrize("B,S,Hq,Hkv,dh,bq,bk,causal", [
    (2, 64, 4, 2, 16, 16, 16, True),
    (1, 48, 2, 2, 8, 16, 16, True),
    (2, 32, 4, 1, 16, 8, 8, True),      # MQA
    (1, 64, 2, 2, 16, 32, 32, False),
    (1, 50, 2, 2, 16, 16, 16, True),    # ragged: q and kv padded
    (1, 64, 8, 2, 32, 64, 16, True),    # uneven blocks
])
def test_flash_matches_oracle(B, S, Hq, Hkv, dh, bq, bk, causal):
    rng = np.random.default_rng(B * 1000 + S)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(_oracle(q, k, v, causal)),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 32, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    want = _oracle(q, k, v, True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=0.1, atol=0.1)
