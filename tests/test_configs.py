"""Exactness of the 10 assigned architecture configs (deliverable f)."""


from repro import configs


def C(name):
    return configs.get(name)


def test_glm4_9b():
    c = C("glm4_9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (40, 4096, 32, 2)
    assert (c.d_ff, c.vocab) == (13696, 151552)
    assert c.family == "dense"


def test_stablelm_12b():
    c = C("stablelm_12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (40, 5120, 32, 8)
    assert (c.d_ff, c.vocab) == (13824, 100352)


def test_nemotron_4_15b():
    c = C("nemotron_4_15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (32, 6144, 48, 8)
    assert (c.d_ff, c.vocab) == (24576, 256000)
    assert c.mlp == "relu2"                      # squared-ReLU per assignment


def test_qwen2_72b():
    c = C("qwen2_72b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (80, 8192, 64, 8)
    assert (c.d_ff, c.vocab) == (29568, 152064)
    assert c.qkv_bias                            # QKV bias per assignment


def test_deepseek_v2_lite():
    c = C("deepseek_v2_lite_16b")
    assert (c.n_layers, c.d_model, c.n_heads) == (27, 2048, 16)
    assert c.vocab == 102400
    assert c.mla.kv_lora_rank == 512             # MLA kv_lora=512
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (64, 6, 2)
    assert c.moe.d_ff_expert == 1408


def test_phi35_moe():
    c = C("phi35_moe_42b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (32, 4096, 32, 8)
    assert (c.moe.n_experts, c.moe.top_k) == (16, 2)
    assert (c.d_ff, c.vocab) == (6400, 32064)


def test_seamless_m4t_medium():
    c = C("seamless_m4t_medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (12, 1024, 16, 16)
    assert (c.d_ff, c.vocab) == (4096, 256206)
    assert c.encdec and c.frontend == "audio"    # enc-dec, stub frontend


def test_llava_next_34b():
    c = C("llava_next_34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (60, 7168, 56, 8)
    assert (c.d_ff, c.vocab) == (20480, 64000)
    assert c.frontend == "vision" and c.n_frontend_tokens > 0


def test_zamba2():
    c = C("zamba2_2p7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (54, 2560, 32, 32)
    assert (c.d_ff, c.vocab) == (10240, 32000)
    assert c.ssm.kind == "mamba2" and c.ssm.d_state == 64
    assert c.subquadratic                        # long_500k runs


def test_falcon_mamba():
    c = C("falcon_mamba_7b")
    assert (c.n_layers, c.d_model) == (64, 4096)
    assert c.vocab == 65024 and c.d_ff == 0       # attention-free
    assert c.ssm.kind == "mamba1" and c.ssm.d_state == 16
    assert c.subquadratic


def test_smoke_reduction_preserves_family():
    for name in configs.ARCH_NAMES:
        full, small = configs.get(name), configs.smoke(name)
        assert small.family == full.family
        assert (small.moe is None) == (full.moe is None)
        assert (small.mla is None) == (full.mla is None)
        assert (small.ssm is None) == (full.ssm is None)
        assert small.d_model < full.d_model
