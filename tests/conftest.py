"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit tests see 1 device;
multi-device coverage runs in subprocesses (tests/_mp.py)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_devices(code: str, ndev: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a fresh python with ``ndev`` virtual host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-6000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_devices
