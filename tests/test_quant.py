"""Shared quantization core (core/quant.py): codec error bounds, dtype
canonicalization, wire ratios — single-device unit tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import quant


def test_canonical_comm_dtype():
    assert quant.canonical_comm_dtype(None) == "complex64"
    assert quant.canonical_comm_dtype("complex64") == "complex64"
    assert quant.canonical_comm_dtype("BF16") == "bf16"
    assert quant.canonical_comm_dtype("bfloat16") == "bf16"
    assert quant.canonical_comm_dtype("int8") == "int8"
    with pytest.raises(ValueError):
        quant.canonical_comm_dtype("fp8")


def test_wire_ratio():
    assert quant.wire_ratio(None) == 1
    assert quant.wire_ratio("complex64") == 1
    assert quant.wire_ratio("bf16") == 2
    assert quant.wire_ratio("int8") == 4


@given(scale=st.floats(1e-4, 1e3), seed=st.integers(0, 1000),
       block_axis=st.integers(0, 2))
@settings(max_examples=25, deadline=None)
def test_int8_per_block_error_bound(scale, seed, block_axis):
    """Round-trip error of the int8 codec is at most half a quantization
    step of each block's own max-abs (the per-block scale contract)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, 6, 8)) * scale, jnp.float32)
    q, s = quant.quantize_int8(x, block_axis=block_axis)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.shape == tuple(x.shape[i] if i == block_axis else 1 for i in range(3))
    back = np.asarray(quant.dequantize_int8(q, s))
    amax = np.max(np.abs(np.asarray(x)), axis=tuple(
        i for i in range(3) if i != block_axis), keepdims=True)
    assert np.all(np.abs(back - np.asarray(x)) <= amax / 127.0 + 1e-9)


def test_int8_zero_block_safe():
    """All-zero blocks (padding) must not divide by zero or emit NaN."""
    q, s = quant.quantize_int8(jnp.zeros((3, 5), jnp.float32), block_axis=0)
    out = np.asarray(quant.dequantize_int8(q, s))
    assert np.all(out == 0) and np.all(np.isfinite(out))


def test_bf16_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    back = np.asarray(quant.decode_bf16(quant.encode_bf16(x)))
    rel = np.linalg.norm(back - np.asarray(x)) / np.linalg.norm(np.asarray(x))
    assert rel < 5e-3  # 8 mantissa bits
    # exponent range is f32's: huge/tiny magnitudes survive
    big = jnp.asarray([1e30, -1e-30, 3e38], jnp.float32)
    assert np.allclose(np.asarray(quant.decode_bf16(quant.encode_bf16(big))),
                       np.asarray(big), rtol=1e-2)


def test_complex_planes_roundtrip():
    rng = np.random.default_rng(1)
    y = jnp.asarray((rng.standard_normal((3, 4)) +
                     1j * rng.standard_normal((3, 4))).astype(np.complex64))
    p = quant.complex_to_planes(y)
    assert p.shape == (2, 3, 4) and p.dtype == jnp.float32
    z = quant.planes_to_complex(p)
    assert z.dtype == jnp.complex64
    np.testing.assert_array_equal(np.asarray(z), np.asarray(y))


def test_compress_consumes_shared_core():
    """optim/compress must be a consumer of core/quant — exactly one
    quantization implementation in the repo."""
    from repro.optim import compress

    assert compress.quantize_int8 is quant.quantize_int8
    assert compress._dequant is quant.dequantize_int8
