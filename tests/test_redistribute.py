"""Multi-device correctness of the paper's exchange (fused vs traditional)."""


def test_exchange_all_pairs(subproc):
    """Every (v, w) exchange over slab + pencil subgroups, both methods,
    against the identity-on-global-array oracle (paper Eq. 20)."""
    subproc("""
import itertools, jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global, unpad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (8, 12, 10, 6)

for (v, w) in itertools.permutations(range(4), 2):
    for method in ("fused", "traditional"):
        placement = [None] * 4
        placement[w] = "p1"
        other = 0 if 0 not in (v, w) else (1 if 1 not in (v, w) else 2)
        placement[other] = "p0"
        divisors = [1] * 4
        divisors[v] = 4; divisors[w] = 4
        divisors[other] = 2
        src = make_pencil(mesh, shape, tuple(placement), divisors=tuple(divisors))
        x = rng.standard_normal(shape).astype(np.float32)
        xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
        y, dst = exchange(xs, src, v=v, w=w, method=method)
        assert dst.placement[v] == "p1" and dst.placement[w] is None
        got = unpad_global(np.asarray(y), dst)
        np.testing.assert_allclose(got, x, rtol=1e-6)
print("EXCHANGE ALL PAIRS OK")
""")


def test_exchange_roundtrip_and_composed_groups(subproc):
    """v->w then w->v is the identity; composed (tuple) subgroups work."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global, unpad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 2, 2), ("a", "b", "c"))
rng = np.random.default_rng(1)
shape = (8, 8, 8)
# composed subgroup ("a","b") acts as one size-4 group (paper Sec. 3.4)
src = make_pencil(mesh, shape, (("a", "b"), "c", None), divisors=(4, 4, 4))
x = rng.standard_normal(shape).astype(np.float32)
xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
y, mid = exchange(xs, src, v=2, w=1, method="fused")
z, back = exchange(y, mid, v=1, w=2, method="fused")
assert back.placement == src.placement
np.testing.assert_allclose(np.asarray(z), np.asarray(xs), rtol=1e-6)
print("ROUNDTRIP OK")
""")


def test_fused_traditional_hlo_divergence(subproc):
    """Structural claim of the paper: the fused path must contain NO
    transpose-of-payload copy before the all-to-all; the traditional path
    must contain one.  We check op counts in the optimized HLO."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, re
from functools import partial
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil
from repro.core.redistribute import exchange_shard
mesh = make_mesh((1, 8), ("data", "model"))
shape = (64, 64, 32)
src = make_pencil(mesh, shape, (None, "model", None), divisors=(8, 8, 1))

def run(method):
    fn = jax.shard_map(partial(exchange_shard, v=0, w=1, group="model", method=method),
                       mesh=mesh, in_specs=src.spec, out_specs=src.exchanged(0, 1).spec,
                       check_vma=False)
    x = jax.ShapeDtypeStruct(shape, jnp.float32)
    txt = jax.jit(fn).lower(x).compile().as_text()
    return txt

fused, trad = run("fused"), run("traditional")
# the traditional path materializes the payload transpose (copy-of-transpose);
# the fused path must not -- the layout change rides inside the all-to-all
n_mat_fused = len(re.findall(r"copy\\(%transpose", fused))
n_mat_trad = len(re.findall(r"copy\\(%transpose", trad))
assert "all-to-all" in fused and "all-to-all" in trad
assert n_mat_fused == 0, fused[:2000]
assert n_mat_trad >= 1, trad[:2000]
print("HLO DIVERGENCE OK", n_mat_fused, n_mat_trad)
""")
