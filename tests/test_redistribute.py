"""Multi-device correctness of the paper's exchange (fused vs traditional
vs pipelined), including reduced-precision comm_dtype wire payloads."""


def test_exchange_comm_dtype_payloads(subproc):
    """comm_dtype contract per engine: "complex64" (and None) is
    bit-identical to the uncompressed exchange; "bf16" and "int8" stay
    within their codec error bounds, for all three engines on slab and
    pencil inputs."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 10)
cases = [
    ((None, "p1", None), (4, 4, 1), 0, 1),           # slab
    ((None, ("p0", "p1"), None), (8, 8, 1), 0, 1),   # composed slab group
    (("p0", "p1", None), (4, 4, 4), 2, 1),           # pencil, v trailing
]
for placement, divisors, v, w in cases:
    src = make_pencil(mesh, shape, placement, divisors=divisors)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
    want, dst = exchange(xs, src, v=v, w=w, method="fused")
    want = np.asarray(want)
    nrm = np.linalg.norm(want)
    for method in ("fused", "traditional", "pipelined"):
        for comm_dtype in (None, "complex64", "bf16", "int8"):
            got, dst_c = exchange(xs, src, v=v, w=w, method=method, chunks=2,
                                  comm_dtype=comm_dtype)
            assert dst_c.placement == dst.placement
            got = np.asarray(got)
            if comm_dtype in (None, "complex64"):
                assert np.array_equal(got, want), (placement, method, comm_dtype)
            else:
                rel = np.linalg.norm(got - want) / nrm
                bound = 5e-3 if comm_dtype == "bf16" else 2e-2
                assert rel < bound, (placement, method, comm_dtype, rel)
print("EXCHANGE COMM DTYPE OK")
""")


def test_pipelined_equals_fused(subproc):
    """The sliced (pipelined) exchange must reproduce the fused exchange
    exactly — same pencil, bit-identical values — for slab and pencil
    decompositions and every chunk count (1 = degenerate single slice)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 12, 10)
cases = [
    # (placement, divisors, v, w)   slab-style and pencil-style inputs
    ((None, "p1", None), (4, 4, 1), 0, 1),
    ((None, ("p0", "p1"), None), (8, 8, 1), 0, 1),       # composed slab group
    (("p0", "p1", None), (4, 4, 4), 2, 1),               # pencil, v trailing
]
for placement, divisors, v, w in cases:
    src = make_pencil(mesh, shape, placement, divisors=divisors)
    x = rng.standard_normal(shape).astype(np.float32)
    xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
    want, dst_f = exchange(xs, src, v=v, w=w, method="fused")
    want = np.asarray(want)
    for chunks in (1, 2, 4):
        got, dst_p = exchange(xs, src, v=v, w=w, method="pipelined", chunks=chunks)
        assert dst_p.placement == dst_f.placement
        assert np.array_equal(np.asarray(got), want), (placement, v, w, chunks)
print("PIPELINED == FUSED OK")
""")


def test_traditional_transposed_out(subproc):
    """FFTW 'transposed out' (Eq. 19): the chunk-major output must equal the
    fused output after the explicit unpack (moveaxis chunk axis before w,
    merge (m, w_shard) -> w_full)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.core.meshutil import make_mesh, shard_map
from repro.core.pencil import make_pencil, pad_global
from repro.core.redistribute import exchange, exchange_shard

mesh = make_mesh((1, 8), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (16, 24, 6)
v, w, m = 0, 1, 8
src = make_pencil(mesh, shape, (None, "p1", None), divisors=(8, 8, 1))
dst = src.exchanged(v, w)
x = rng.standard_normal(shape).astype(np.float32)
xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
want, _ = exchange(xs, src, v=v, w=w, method="fused")

# chunk-major shard output: (m, ..., w_shard, ...) with the chunk axis leading
tspec = jax.sharding.PartitionSpec(None, *dst.spec)
fn = shard_map(partial(exchange_shard, v=v, w=w, group="p1",
                       method="traditional", transposed_out=True),
               mesh=mesh, in_specs=src.spec, out_specs=tspec, check_vma=False)
y = np.asarray(fn(xs))
assert y.shape[0] == m
# explicit unpack: move chunk axis before w, merge (m, w_shard) -> w_full
z = np.moveaxis(y, 0, w)
z = z.reshape(z.shape[:w] + (z.shape[w] * z.shape[w + 1],) + z.shape[w + 2:])
np.testing.assert_array_equal(z, np.asarray(want))
print("TRANSPOSED OUT OK")
""")


def test_traditional_transposed_out_r2c(subproc):
    """transposed_out combined with an r2c pipeline (previously untested):
    the exchange that follows the r2c stage carries a complex
    Hermitian-reduced, physically padded axis; the chunk-major output must
    still unpack to the fused result, and running the remaining FFT on the
    chunk-major layout (axis indices shifted by the leading chunk axis)
    gives the same spectrum as the standard plan."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.core.meshutil import make_mesh, shard_map
from repro.core.pencil import make_pencil, pad_global
from repro.core.redistribute import exchange, exchange_shard

mesh = make_mesh((1, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
n2 = 10  # odd-ish r2c extent: 10 -> 6 bins, padded to 8 (multiple of 4)
shape = (12, 8, n2)
x = rng.standard_normal(shape).astype(np.float32)

# r2c stage on the slab input (axis 0 distributed), spectrum padded so the
# Hermitian-reduced axis stays divisible by the subgroup it will take over
spec = np.fft.rfft(x, axis=2).astype(np.complex64)   # (12, 8, 6)
spec_pad = np.pad(spec, ((0, 0), (0, 0), (0, 2)))    # physical extent 8
src = make_pencil(mesh, spec_pad.shape, ("p1", None, None), divisors=(4, 1, 4))
xs = jax.device_put(pad_global(jnp.asarray(spec_pad), src), src.sharding)

v, w, m = 2, 0, 4
want, dst = exchange(xs, src, v=v, w=w, method="fused")
want = np.asarray(want)

tspec = jax.sharding.PartitionSpec(None, *dst.spec)
fn = shard_map(partial(exchange_shard, v=v, w=w, group="p1",
                       method="traditional", transposed_out=True),
               mesh=mesh, in_specs=src.spec, out_specs=tspec, check_vma=False)
y = np.asarray(fn(xs))
assert y.shape[0] == m and y.dtype == np.complex64
# explicit unpack: move chunk axis before w, merge (m, w_shard) -> w_full
z = np.moveaxis(y, 0, w)
z = z.reshape(z.shape[:w] + (z.shape[w] * z.shape[w + 1],) + z.shape[w + 2:])
np.testing.assert_array_equal(z, want)
# and against the paper's Eq. 20 oracle: a jit-level exchange leaves the
# global array unchanged, so the unpacked spectrum is the r2c input itself
np.testing.assert_array_equal(z, spec_pad)
print("TRANSPOSED OUT R2C OK")
""")


def test_exchange_all_pairs(subproc):
    """Every (v, w) exchange over slab + pencil subgroups, both methods,
    against the identity-on-global-array oracle (paper Eq. 20)."""
    subproc("""
import itertools, jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global, unpad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 4), ("p0", "p1"))
rng = np.random.default_rng(0)
shape = (8, 12, 10, 6)

for (v, w) in itertools.permutations(range(4), 2):
    for method in ("fused", "traditional"):
        placement = [None] * 4
        placement[w] = "p1"
        other = 0 if 0 not in (v, w) else (1 if 1 not in (v, w) else 2)
        placement[other] = "p0"
        divisors = [1] * 4
        divisors[v] = 4; divisors[w] = 4
        divisors[other] = 2
        src = make_pencil(mesh, shape, tuple(placement), divisors=tuple(divisors))
        x = rng.standard_normal(shape).astype(np.float32)
        xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
        y, dst = exchange(xs, src, v=v, w=w, method=method)
        assert dst.placement[v] == "p1" and dst.placement[w] is None
        got = unpad_global(np.asarray(y), dst)
        np.testing.assert_allclose(got, x, rtol=1e-6)
print("EXCHANGE ALL PAIRS OK")
""")


def test_exchange_roundtrip_and_composed_groups(subproc):
    """v->w then w->v is the identity; composed (tuple) subgroups work."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global, unpad_global
from repro.core.redistribute import exchange

mesh = make_mesh((2, 2, 2), ("a", "b", "c"))
rng = np.random.default_rng(1)
shape = (8, 8, 8)
# composed subgroup ("a","b") acts as one size-4 group (paper Sec. 3.4)
src = make_pencil(mesh, shape, (("a", "b"), "c", None), divisors=(4, 4, 4))
x = rng.standard_normal(shape).astype(np.float32)
xs = jax.device_put(pad_global(jnp.asarray(x), src), src.sharding)
y, mid = exchange(xs, src, v=2, w=1, method="fused")
z, back = exchange(y, mid, v=1, w=2, method="fused")
assert back.placement == src.placement
np.testing.assert_allclose(np.asarray(z), np.asarray(xs), rtol=1e-6)
print("ROUNDTRIP OK")
""")


def test_fused_traditional_hlo_divergence(subproc):
    """Structural claim of the paper: the traditional path pays extra
    materialized pack/unpack transposes on top of the collective; the fused
    path pushes the layout change into the all-to-all.  We count
    materialized-transpose ops in the optimized HLO — strictly more for
    traditional.  (Counted as copy-of-transpose plus loop fusions whose op
    metadata is a transpose: HLO text and the all_to_all lowering itself
    vary across jax versions — 0.4.x lowers even the fused collective via a
    transpose — so the invariant is the *difference*, not absolute zero.)"""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, re
from functools import partial
from repro.core.meshutil import make_mesh, shard_map
from repro.core.pencil import make_pencil
from repro.core.redistribute import exchange_shard
mesh = make_mesh((1, 8), ("data", "model"))
shape = (64, 64, 32)
src = make_pencil(mesh, shape, (None, "model", None), divisors=(8, 8, 1))

def run(method):
    fn = shard_map(partial(exchange_shard, v=0, w=1, group="model", method=method),
                       mesh=mesh, in_specs=src.spec, out_specs=src.exchanged(0, 1).spec,
                       check_vma=False)
    x = jax.ShapeDtypeStruct(shape, jnp.float32)
    txt = jax.jit(fn).lower(x).compile().as_text()
    return txt

def materialized_transposes(txt):
    return (len(re.findall(r"copy\\([^)]*%transpose", txt))
            + len(re.findall(r'fusion\\(.*op_name="[^"]*transpose', txt)))

fused, trad = run("fused"), run("traditional")
n_mat_fused = materialized_transposes(fused)
n_mat_trad = materialized_transposes(trad)
assert "all-to-all" in fused and "all-to-all" in trad
assert n_mat_trad > n_mat_fused, (n_mat_fused, n_mat_trad, trad[:2000])
print("HLO DIVERGENCE OK", n_mat_fused, n_mat_trad)
""")
