"""Optimizer unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, strategies as st

from repro.optim import AdamW, clip_by_global_norm, cosine_schedule


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - jnp.asarray([1.0, 1.0])))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


@given(scale=st.floats(1e-3, 1e3), max_norm=st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_clip_property(scale, max_norm):
    g = {"a": jnp.full((4,), scale), "b": jnp.full((3, 3), -scale)}
    clipped, gnorm = clip_by_global_norm(g, max_norm)
    got = float(jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(clipped))))
    assert got <= max_norm * 1.001 + 1e-6
    want = float(jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g))))
    np.testing.assert_allclose(float(gnorm), want, rtol=1e-5)
    if want <= max_norm:  # no-op below threshold
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 0.11
    assert float(lr(jnp.int32(100))) >= 0.099
    assert float(lr(jnp.int32(5))) < float(lr(jnp.int32(10)))


def test_bf16_params_fp32_moments():
    opt = AdamW(lr=1e-2)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    p2, s2, m = opt.update(g, state, params)
    assert p2["w"].dtype == jnp.bfloat16
    assert int(s2.step) == 1
