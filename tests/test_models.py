"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, shape + finiteness asserts; plus decode==forward
logit-consistency for representative families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.meshutil import make_mesh, set_mesh
from repro.models.config import param_count
from repro.models.lm import LM
from repro.models.sharding import Axes


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


AXES = Axes(multi_pod=False)


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["frontend"] = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model),
                                              jnp.bfloat16)
    if cfg.family == "audio":
        batch["frontend"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke_train_step(name, mesh):
    cfg = configs.smoke(name)
    lm = LM(cfg, mesh, AXES, q_block=8, xent_chunks=2)
    key = jax.random.PRNGKey(0)
    with set_mesh(mesh):
        params = lm.init_params(key)
        batch = _batch(cfg, key)
        (loss, metrics), grads = jax.jit(jax.value_and_grad(lm.loss, has_aux=True))(
            params, batch)
        assert np.isfinite(float(loss))
        assert np.isfinite(float(metrics["xent"]))
        for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            assert bool(jnp.all(jnp.isfinite(g))), (name, path)
        # output-shape asserts: logits path via prefill
        cur = 16 + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
        cache, logits = jax.jit(lambda p, b: lm.prefill(p, b, max_len=cur + 2))(
            params, batch)
        assert logits.shape == (2, 1, lm.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_full_config_registry(name):
    """The exact published config: field values as assigned."""
    cfg = configs.get(name)
    n = param_count(cfg)
    assert n > 1e8  # all assigned archs are >= 1B-ish; smoke guard on formula
    assert cfg.vocab > 0 and cfg.n_layers > 0
    cells = configs.cells(name)
    assert "train_4k" in cells
    assert ("long_500k" in cells) == cfg.subquadratic


@pytest.mark.parametrize("name", ["glm4_9b", "deepseek_v2_lite_16b",
                                  "falcon_mamba_7b", "zamba2_2p7b",
                                  "seamless_m4t_medium"])
def test_prefill_decode_matches_forward(name, mesh):
    """Teacher-forced decode must reproduce the full-sequence logits."""
    cfg = configs.smoke(name)
    lm = LM(cfg, mesh, AXES, q_block=4, xent_chunks=1)
    key = jax.random.PRNGKey(1)
    B, S = 2, 8
    with set_mesh(mesh):
        params = lm.init_params(key)
        toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab)
        batch_full = dict(_batch(cfg, key, B, S + 3), tokens=toks)
        batch_pre = dict(_batch(cfg, key, B, S), tokens=toks[:, :S])
        if "frontend" in batch_full:  # identical modality input for both passes
            batch_pre["frontend"] = batch_full["frontend"]
        off = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
        M = S + 3 + off
        _, logits_full = lm.prefill(params, batch_full, max_len=M)
        cache, logits = lm.prefill(params, batch_pre, max_len=M)
        cur = S + off
        for t in range(3):
            cache, logits = lm.decode_step(params, cache, toks[:, S + t], jnp.int32(cur))
            cur += 1
        _, want = lm.prefill(params, batch_full, max_len=M)
        got = np.asarray(logits, np.float32)
        np.testing.assert_allclose(got, np.asarray(want[:, 0], np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_moe_sharded_lowering(subproc):
    """MoE EP all-to-all path on a real (1, 4) mesh with 8 experts."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.core.meshutil import make_mesh, set_mesh
from repro.models.lm import LM
from repro.models.sharding import Axes
mesh = make_mesh((1, 4), ("data", "model"))
cfg = configs.smoke("phi35_moe_42b")
lm = LM(cfg, mesh, Axes(multi_pod=False), q_block=8, xent_chunks=2)
key = jax.random.PRNGKey(0)
with set_mesh(mesh):
    params = lm.init_params(key)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "mask": jnp.ones((B, S), jnp.float32)}
    loss, _ = jax.jit(lm.loss)(params, batch)
    assert np.isfinite(float(loss)), loss
print("MOE EP OK", float(loss))
""", ndev=4)


def test_prefill_decode_optimized_flags(mesh):
    """Decode consistency holds under the (CPU-executable) optimized flags:
    triangular prefill + dots remat + head-major cache."""
    from repro.models.lm import PerfFlags
    flags = PerfFlags(exact_causal_prefill=True, remat_policy="dots",
                      hmajor_cache=True)
    cfg = configs.smoke("glm4_9b")
    lm = LM(cfg, mesh, AXES, q_block=4, xent_chunks=1, perf=flags)
    key = jax.random.PRNGKey(1)
    B, S = 2, 8
    with set_mesh(mesh):
        params = lm.init_params(key)
        toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab)
        bf = {"tokens": toks, "targets": toks,
              "mask": jnp.ones((B, S + 3), jnp.float32)}
        M = S + 3
        _, want = lm.prefill(params, bf, max_len=M)
        cache, lg = lm.prefill(params, {"tokens": toks[:, :S]}, max_len=M)
        cur = S
        for t in range(3):
            cache, lg = lm.decode_step(params, cache, toks[:, S + t], jnp.int32(cur))
            cur += 1
        np.testing.assert_allclose(np.asarray(lg, np.float32),
                                   np.asarray(want[:, 0], np.float32),
                                   rtol=6e-2, atol=6e-2)
