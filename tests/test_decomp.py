"""Property tests for the paper's Alg. 1 (balanced block decomposition)."""


import pytest
from _hyp import given, settings, strategies as st

from repro.core.decomp import (AxisDecomp, decompose, local_lengths,
                               pad_to_multiple, start_indices)


@given(N=st.integers(0, 10_000), M=st.integers(1, 257))
@settings(max_examples=300, deadline=None)
def test_decompose_partition(N, M):
    ns = local_lengths(N, M)
    ss = start_indices(N, M)
    assert sum(ns) == N                       # covers exactly
    assert max(ns) - min(ns) <= 1             # balanced
    assert ss[0] == 0
    for p in range(1, M):
        assert ss[p] == ss[p - 1] + ns[p - 1]  # contiguous, ordered
    # paper Listing 1 formulas
    q, r = divmod(N, M)
    for p in range(M):
        assert ns[p] == q + (1 if r > p else 0)
        assert ss[p] == q * p + min(r, p)


@given(N=st.integers(0, 100_000), M=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_pad_to_multiple(N, M):
    P = pad_to_multiple(N, M)
    assert P % M == 0 and P >= N and P - N < M


@given(N=st.integers(1, 5_000), M=st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_axis_decomp_slices(N, M):
    ad = AxisDecomp(N, M)
    assert ad.shard * M == ad.padded
    phys = ad.owner_slices()
    assert phys[0].start == 0 and phys[-1].stop == ad.padded
    bal = ad.balanced_slices()
    covered = [i for s in bal for i in range(s.start, s.stop)]
    assert covered == list(range(N))


def test_decompose_validation():
    with pytest.raises(ValueError):
        decompose(-1, 4, 0)
    with pytest.raises(ValueError):
        decompose(10, 0, 0)
    with pytest.raises(ValueError):
        decompose(10, 4, 4)
