"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.kernels.fft import ops as fops
from repro.kernels.fft import ref as fref
from repro.kernels.transpose.ops import transpose01


# -- four-step factorization + reference ------------------------------------


@given(n=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_plan_factors(n):
    n1, n2 = fops.plan_factors(n)
    assert n1 * n2 == n and n1 >= n2 >= 1


@pytest.mark.parametrize("n1,n2", [(4, 4), (8, 4), (16, 16), (32, 8), (12, 5)])
def test_fourstep_ref_matches_fft(n1, n2):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((3, n1 * n2)) + 1j * rng.standard_normal((3, n1 * n2))
         ).astype(np.complex64)
    got = fref.fourstep_ref(jnp.asarray(x), n1, n2)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(x, axis=-1),
                               rtol=2e-3, atol=2e-3)


# -- Pallas kernel sweeps ------------------------------------------------------


@pytest.mark.parametrize("n", [8, 17, 96, 128, 384, 1024])  # prime + composite
@pytest.mark.parametrize("karatsuba", [True, False])
def test_fft_matmul_sweep(n, karatsuba):
    rng = np.random.default_rng(n)
    x = (rng.standard_normal((5, n)) + 1j * rng.standard_normal((5, n))).astype(np.complex64)
    got = fops.fft_matmul(jnp.asarray(x), karatsuba=karatsuba)
    tol = 2e-3 * max(1, n // 128)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(x, axis=-1),
                               rtol=tol, atol=tol * 10)
    inv = fops.fft_matmul(got, inverse=True, karatsuba=karatsuba)
    np.testing.assert_allclose(np.asarray(inv), x, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("axis", [0, 1, 2])
def test_fft_matmul_axes(axis):
    rng = np.random.default_rng(9)
    shape = (6, 10, 8)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
    got = fops.fft_matmul(jnp.asarray(x), axis=axis)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(x, axis=axis),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n", [16, 30, 256, 700])
def test_rfft_irfft_matmul(n):
    rng = np.random.default_rng(n)
    x = rng.standard_normal((4, n)).astype(np.float32)
    got = fops.rfft_matmul(jnp.asarray(x))
    tol = 3e-3 * max(1, n // 256)
    np.testing.assert_allclose(np.asarray(got), np.fft.rfft(x, axis=-1),
                               rtol=tol, atol=tol * 20)
    back = fops.irfft_matmul(jnp.asarray(np.fft.rfft(x).astype(np.complex64)), n=n)
    np.testing.assert_allclose(np.asarray(back), x, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("block_b", [1, 4, 16])
def test_fft_matmul_block_invariance(block_b):
    rng = np.random.default_rng(3)
    x = (rng.standard_normal((7, 64)) + 1j * rng.standard_normal((7, 64))).astype(np.complex64)
    got = fops.fft_matmul(jnp.asarray(x), block_b=block_b)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(x, axis=-1),
                               rtol=2e-3, atol=2e-3)


# -- transpose kernel ----------------------------------------------------------


@given(a=st.integers(1, 24), b=st.integers(1, 24), c=st.integers(1, 8),
       dt=st.sampled_from(["float32", "complex64"]))
@settings(max_examples=25, deadline=None)
def test_transpose01_sweep(a, b, c, dt):
    rng = np.random.default_rng(a * 100 + b)
    x = rng.standard_normal((a, b, c)).astype(dt)
    if dt == "complex64":
        x = (x + 1j * rng.standard_normal((a, b, c))).astype(dt)
    got = transpose01(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), x.swapaxes(0, 1))
