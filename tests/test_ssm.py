"""Selective-scan (Mamba1) and SSD (Mamba2) vs naive sequential recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import SSMConfig
from repro.models.ssm import (causal_conv, causal_conv_step, mamba1_apply,
                              mamba1_init, mamba2_apply, mamba2_init,
                              selective_scan, ssd_scan)


def naive_selective_scan(x, dt, A, Bm, Cm):
    B, T, Di = x.shape
    N = A.shape[-1]
    h = np.zeros((B, Di, N), np.float64)
    ys = np.zeros((B, T, Di), np.float64)
    for t in range(T):
        dA = np.exp(dt[:, t, :, None] * A)                     # (B, Di, N)
        dBx = dt[:, t, :, None] * Bm[:, t, None, :] * x[:, t, :, None]
        h = dA * h + dBx
        ys[:, t] = np.einsum("bin,bn->bi", h, Cm[:, t])
    return ys, h


@pytest.mark.parametrize("T,chunk", [(16, 4), (16, 16), (13, 5), (32, 8)])
def test_selective_scan_vs_naive(T, chunk):
    rng = np.random.default_rng(0)
    B, Di, N = 2, 6, 4
    x = rng.standard_normal((B, T, Di)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, T, Di)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (Di, N)).astype(np.float32)
    Bm = rng.standard_normal((B, T, N)).astype(np.float32)
    Cm = rng.standard_normal((B, T, N)).astype(np.float32)
    y, h = selective_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                          jnp.asarray(Bm), jnp.asarray(Cm), chunk=chunk)
    y_ref, h_ref = naive_selective_scan(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def naive_ssd(xh, dt, a_log, Bm, Cm):
    B, T, H, Pd = xh.shape
    N = Bm.shape[-1]
    s = np.zeros((B, H, Pd, N), np.float64)
    ys = np.zeros((B, T, H, Pd), np.float64)
    for t in range(T):
        a = np.exp(dt[:, t] * a_log)                           # (B, H)
        xb = xh[:, t] * dt[:, t, :, None]                      # (B, H, P)
        s = s * a[..., None, None] + np.einsum("bn,bhp->bhpn", Bm[:, t], xb)
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], s)
    return ys, s


@pytest.mark.parametrize("T,chunk", [(16, 4), (12, 12), (20, 7)])
def test_ssd_vs_naive(T, chunk):
    rng = np.random.default_rng(1)
    B, H, Pd, N = 2, 3, 4, 5
    xh = rng.standard_normal((B, T, H, Pd)).astype(np.float32)
    dt = rng.uniform(0.01, 0.3, (B, T, H)).astype(np.float32)
    a_log = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    Bm = rng.standard_normal((B, T, N)).astype(np.float32)
    Cm = rng.standard_normal((B, T, N)).astype(np.float32)
    y, s = ssd_scan(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(a_log),
                    jnp.asarray(Bm), jnp.asarray(Cm), chunk=chunk)
    y_ref, s_ref = naive_ssd(xh, dt, a_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=1e-4, atol=1e-4)


def test_chunk_invariance():
    """The chunked scans are exact — results must not depend on chunk size."""
    rng = np.random.default_rng(2)
    B, T, Di, N = 1, 24, 4, 3
    x = rng.standard_normal((B, T, Di)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (B, T, Di)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (Di, N)).astype(np.float32)
    Bm = rng.standard_normal((B, T, N)).astype(np.float32)
    Cm = rng.standard_normal((B, T, N)).astype(np.float32)
    outs = [np.asarray(selective_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                                      jnp.asarray(Bm), jnp.asarray(Cm), chunk=c)[0])
            for c in (3, 8, 24)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-5)


def test_causal_conv_and_step():
    rng = np.random.default_rng(3)
    B, T, C, K = 2, 10, 3, 4
    x = rng.standard_normal((B, T, C)).astype(np.float32)
    w = rng.standard_normal((K, C)).astype(np.float32)
    b = rng.standard_normal((C,)).astype(np.float32)
    y = np.asarray(causal_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)))
    # naive causal depthwise conv
    xp = np.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # newest input multiplies the LAST tap (torch conv1d layout)
    want = np.stack([sum(xp[:, t + k] * w[k] for k in range(K)) + b
                     for t in range(T)], axis=1)
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)
    # streaming step equivalence
    state = jnp.asarray(np.zeros((B, K - 1, C), np.float32))
    for t in range(T):
        state, yt = causal_conv_step(state, jnp.asarray(x[:, t]), jnp.asarray(w),
                                     jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(yt), want[:, t], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), x[:, T - (K - 1):], rtol=1e-6)


@pytest.mark.parametrize("kind", ["mamba1", "mamba2"])
def test_prefill_decode_consistency(kind):
    """Chunked prefill then step-decode == one long chunked pass."""
    cfg = SSMConfig(kind=kind, d_state=4, d_conv=4, expand=2, headdim=4, chunk=8)
    d = 8
    key = jax.random.PRNGKey(0)
    init = mamba1_init if kind == "mamba1" else mamba2_init
    apply = mamba1_apply if kind == "mamba1" else mamba2_apply
    p = init(key, d, cfg, jnp.float32)
    B, T = 2, 12
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T + 1, d), jnp.float32)
    full, _ = apply(p, u, cfg=cfg)
    pre, st = apply(p, u[:, :T], cfg=cfg)
    step, _ = apply(p, u[:, T:], cfg=cfg, state=st)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, T]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :T]),
                               rtol=2e-3, atol=2e-3)
