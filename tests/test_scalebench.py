"""Scaling-proof harness: model fitting, bench-v3, and the benchdiff gate.

Covers the collector side of ``benchmarks/scalebench.py`` without
launching sweep subprocesses (the fitter, the bench-v3 normalizer, the
regression differ are all pure python), plus subprocess checks that the
model hooks the fitter relies on — ``model_collective_launches`` and the
``ici_latency_s`` term of ``model_time_s`` — agree with each other, and
that armed model priors actually prune the tuner's candidate sweep.
"""

import json
import math
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import modelfit
from repro.core.redistribute import exchange_collective_launches

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))  # benchmarks/ is a top-level package

from benchmarks import scalebench  # noqa: E402
from benchmarks.benchdiff import diff_records, flatten_record  # noqa: E402
from benchmarks.benchdiff import main as benchdiff_main  # noqa: E402
from benchmarks.normalize_bench import normalize_scaling  # noqa: E402


def _synthetic_points(ici_bw=40e9, lat=2e-6, *, perturb=None):
    """A strong-scaling-shaped series whose measured times are EXACTLY the
    linear surrogate at (ici_bw, lat).  bytes and launches deliberately not
    proportional (pipelined chunks grow with ndev) so the fit can separate
    the two terms."""
    pts = []
    for ndev, chunks in ((2, 1), (4, 2), (8, 4), (16, 8)):
        wire = 4.2e6 / ndev
        launches = 2 * chunks
        compute = 3e-4 / ndev
        t = compute + wire / ici_bw + launches * lat
        if perturb:
            t *= perturb.get(ndev, 1.0)
        pts.append({"shape": [16 * ndev, 16, 16], "ndev": ndev, "best_s": t,
                    "model": {"time_s": t, "compute_s": compute,
                              "wire_bytes_per_dev": wire,
                              "launches": launches}})
    return pts


# -- modelfit ---------------------------------------------------------------


def test_fit_recovers_known_coefficients():
    fit = modelfit.fit_series(_synthetic_points(ici_bw=40e9, lat=2e-6))
    assert fit["ici_bw"] == pytest.approx(40e9, rel=1e-6)
    assert fit["ici_latency_s"] == pytest.approx(2e-6, rel=1e-6)
    assert not fit["misses"]
    assert fit["rmse_log"] == pytest.approx(0.0, abs=1e-9)
    for p in fit["points"]:
        assert p["residual"] == pytest.approx(1.0, rel=1e-9)


def test_fit_collinear_series_attributes_bandwidth_only():
    # launches exactly proportional to bytes: the two columns cannot be
    # separated, so the fit must attribute everything to bandwidth instead
    # of splitting by the minimum-norm accident
    pts = _synthetic_points()
    for p in pts:
        p["model"]["launches"] = p["model"]["wire_bytes_per_dev"] / 1e6
        p["best_s"] = (p["model"]["compute_s"]
                       + p["model"]["wire_bytes_per_dev"] / 40e9)
    fit = modelfit.fit_series(pts)
    assert math.isfinite(fit["ici_bw"])
    assert fit["ici_latency_s"] == 0.0
    assert all(p["residual"] == pytest.approx(1.0, rel=1e-6)
               for p in fit["points"])


def test_fit_flags_over_2x_model_miss():
    # one point 3x slower than the surrogate can explain -> flagged
    fit = modelfit.fit_series(_synthetic_points(perturb={8: 3.0}))
    assert fit["misses"], "3x-off point must be flagged"
    flagged = {m["ndev"] for m in fit["misses"]}
    assert 8 in flagged
    worst = next(m for m in fit["misses"] if m["ndev"] == 8)
    assert worst["residual"] > 2.0
    assert "underestimates" in worst["why"]


def test_fit_single_point_is_bandwidth_only():
    fit = modelfit.fit_series(_synthetic_points()[:1])
    assert fit["npoints"] == 1
    assert fit["ici_latency_s"] == 0.0
    assert math.isfinite(fit["ici_bw"]) and fit["ici_bw"] > 0


def test_fit_report_and_priors_roundtrip(tmp_path, monkeypatch):
    report = modelfit.fit_report(
        {"a": _synthetic_points(ici_bw=40e9, lat=2e-6),
         "b": _synthetic_points(ici_bw=60e9, lat=4e-6)},
        device_kind="cpu", backend="cpu")
    assert report["schema"] == "modelfit-v1"
    assert report["priors"]["ici_bw"] == pytest.approx(50e9, rel=1e-6)
    assert report["priors"]["ici_latency_s"] == pytest.approx(3e-6, rel=1e-6)

    path = tmp_path / "priors.json"
    modelfit.save_priors(report, path)
    loaded = modelfit.load_priors(path)
    assert loaded["ici_bw"] == pytest.approx(report["priors"]["ici_bw"])
    # non-fitted terms come back at reference values
    assert loaded["peak_flops"] == modelfit.REFERENCE_COEFFS["peak_flops"]

    # corrupt/missing files must be unusable-but-harmless, like the tuner cache
    (tmp_path / "bad.json").write_text("{not json")
    assert modelfit.load_priors(tmp_path / "bad.json") is None
    assert modelfit.load_priors(tmp_path / "absent.json") is None

    # priors arm ONLY via the env opt-in
    monkeypatch.delenv("REPRO_MODEL_PRIORS", raising=False)
    assert modelfit.active_priors() is None
    monkeypatch.setenv("REPRO_MODEL_PRIORS", str(path))
    assert modelfit.active_priors()["ici_bw"] == pytest.approx(
        report["priors"]["ici_bw"])


# -- launch accounting ------------------------------------------------------


def test_exchange_collective_launches_counting():
    args = (None, 0, 1)  # (src, v, w) are parity-only
    assert exchange_collective_launches(*args) == 1
    assert exchange_collective_launches(*args, method="pipelined", chunks=4) == 4
    assert exchange_collective_launches(*args, nfields=3,
                                        batch_fusion="stacked") == 1
    assert exchange_collective_launches(*args, nfields=3,
                                        batch_fusion="per-field") == 3
    assert exchange_collective_launches(*args, method="pipelined", chunks=2,
                                        nfields=3,
                                        batch_fusion="pipelined-across-fields") == 6
    with pytest.raises(ValueError):
        exchange_collective_launches(*args, nfields=2, batch_fusion="bogus")


def test_model_latency_term_matches_launch_count(subproc):
    # the fitter's surrogate assumes model_time_s is affine in the latency
    # coefficient with slope model_collective_launches — enforce exactly that
    subproc("""
from repro.core.meshutil import balanced_dims, make_mesh
from repro.core.pfft import ParallelFFT
for gridspec, shape in (("slab", (16, 16, 16)), ("pencil", (8, 16, 16))):
    if gridspec == "slab":
        mesh, grid = make_mesh((4,), ("p0",)), ("p0",)
    else:
        mesh, grid = make_mesh(balanced_dims(4), ("p0", "p1")), ("p0", "p1")
    plan = ParallelFFT(mesh, shape, grid)
    for nfields in (1, 3):
        launches = plan.model_collective_launches(nfields=nfields)
        assert launches > 0
        hi = plan.model_time_s(ici_bw=1e30, ici_latency_s=1e-3, nfields=nfields)
        lo = plan.model_time_s(ici_bw=1e30, ici_latency_s=0.0, nfields=nfields)
        got = (hi - lo) / 1e-3
        assert abs(got - launches) < 1e-6, (gridspec, nfields, got, launches)
print("LAUNCH PARITY OK")
""", ndev=4)


def test_tuner_prior_pruning_opt_in(subproc, tmp_path):
    # with REPRO_MODEL_PRIORS armed, the tuner micro-benchmarks only the
    # prior-ranked top-K candidates per stage and records the rest as
    # pruned: model estimates; without the env var every candidate is
    # timed (tests/test_tuner.py pins that contract)
    report = modelfit.fit_report({"s": _synthetic_points()})
    priors_path = tmp_path / "priors.json"
    modelfit.save_priors(report, priors_path)
    subproc(f"""
import os
os.environ["REPRO_MODEL_PRIORS"] = {str(priors_path)!r}
os.environ["REPRO_TUNER_PRIOR_TOPK"] = "3"
from repro.core.meshutil import balanced_dims, make_mesh
from repro.core.pfft import ExchangeStage, ParallelFFT
from repro.core import tuner
mesh = make_mesh(balanced_dims(4), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"))
schedule, timings = tuner.tune_plan(plan, repeats=1, inner=1)
assert len(schedule) == sum(isinstance(s, ExchangeStage) for s in plan.stages)
for stage, per in timings.items():
    timed = [t for t in per if not t.startswith("pruned:")]
    pruned = [t for t in per if t.startswith("pruned:")]
    assert len(timed) == 3, (stage, sorted(per))
    assert pruned, stage
    assert all(per[t] > 0 for t in pruned)
print("PRIOR PRUNING OK")
""", ndev=4)


# -- scalebench series bookkeeping ------------------------------------------


def test_series_name_and_point_shape():
    s = {"mode": "strong", "grid": "slab", "shape": (16, 16, 16),
         "method": "fused", "fields": 1}
    assert scalebench._series_name(s) == "strong@slab@16x16x16@fused@complex64@jnp"
    assert scalebench._point_shape(s, 4) == (16, 16, 16)
    w = {"mode": "weak", "grid": "pencil", "shape": (8, 16, 16),
         "method": "fused", "fields": 3, "comm_dtype": "bf16",
         "exchange_impl": "pallas"}
    assert scalebench._series_name(w) == "weak@pencil@loc8x16x16@fused@bf16@pallas@f3"
    assert scalebench._point_shape(w, 4) == (32, 16, 16)


def test_smoke_preset_shape():
    series = scalebench.preset_series("smoke")
    assert {s["grid"] for s in series} == {"slab", "pencil"}
    assert {s["mode"] for s in series} == {"strong", "weak"}
    assert any(s.get("fields", 1) > 1 for s in series)
    # the redistribution split is swept on at least one series per grid
    assert all(any(s.get("split") for s in series if s["grid"] == g)
               for g in ("slab", "pencil"))
    assert all(s["devices"] for s in series)
    with pytest.raises(SystemExit):
        scalebench.preset_series("bogus")


def _raw_sweep(perturb=None):
    pts = _synthetic_points(perturb=perturb)
    for p in pts:
        p.update(p50_s=p["best_s"] * 1.04, spread_frac=0.04,
                 device_kind="cpu", backend="cpu")
    redist = [dict(p, best_s=p["best_s"] * 0.4, p50_s=p["best_s"] * 0.42)
              for p in pts[:2]]
    return {"scalebench": True, "preset": "smoke", "inner": 1, "outer": 2,
            "series": [{
                "name": "strong@slab@16x16x16@fused@complex64@jnp",
                "mode": "strong", "grid": "slab", "method": "fused",
                "fields": 1, "base_shape": [16, 16, 16],
                "comm_dtype": None, "exchange_impl": "jnp",
                "points": pts, "redist_points": redist}]}


def test_normalize_scaling_bench_v3_roundtrip():
    bench = normalize_scaling(_raw_sweep(), pr=99)
    assert bench["schema"] == "bench-v3"
    assert bench["pr"] == 99
    assert bench["device_kind"] == "cpu"
    report = bench.pop("_fit_report")
    assert report["schema"] == "modelfit-v1"
    assert json.loads(json.dumps(bench)) == bench  # JSON-able

    series = bench["series"]["strong@slab@16x16x16@fused@complex64@jnp"]
    assert series["comm_dtype"] == "complex64"
    assert len(series["points"]) == 4
    for p in series["points"]:
        # the acceptance contract: measured time + model time + residual
        # on every committed point
        assert p["best_s"] > 0
        assert p["model_time_s"] > 0
        assert p["fit_time_s"] > 0
        assert p["residual"] == pytest.approx(1.0, rel=1e-6)
    assert series["fit"]["ici_bw"] == pytest.approx(40e9, rel=1e-6)
    assert len(series["redist"]["points"]) == 2
    # the redist sub-series got its own fit entry in the report
    assert any(k.endswith("#redist") for k in report["series"])


def test_benchdiff_v3_catches_synthetic_regression(tmp_path):
    old = normalize_scaling(_raw_sweep())
    old.pop("_fit_report")
    slowed = normalize_scaling(_raw_sweep(perturb={8: 1.9}))
    slowed.pop("_fit_report")

    rep = diff_records(old, slowed, min_time=0.0)
    bad = [r["key"] for r in rep["regressions"]]
    assert bad == ["strong@slab@16x16x16@fused@complex64@jnp#nd8"]
    assert not rep["advisory"]

    # the CLI gate exits nonzero on it (this is what CI runs)
    (tmp_path / "old.json").write_text(json.dumps(old))
    (tmp_path / "new.json").write_text(json.dumps(slowed))
    rc = benchdiff_main([str(tmp_path / "old.json"),
                         str(tmp_path / "new.json"),
                         "--min-time", "0",
                         "--out", str(tmp_path / "diff.json")])
    assert rc == 1
    out = json.loads((tmp_path / "diff.json").read_text())
    assert [r["key"] for r in out["regressions"]] == bad

    # ... and is clean on a no-change comparison
    assert benchdiff_main([str(tmp_path / "old.json"),
                           str(tmp_path / "old.json"),
                           "--min-time", "0"]) == 0


def test_benchdiff_noise_and_min_time_guards():
    old = normalize_scaling(_raw_sweep())
    old.pop("_fit_report")
    # a 30% slowdown with 20% measured spread on the new side stays inside
    # the widened threshold (0.25 + 1.0 * 0.20)
    noisy = normalize_scaling(_raw_sweep(perturb={8: 1.3}))
    noisy.pop("_fit_report")
    for p in noisy["series"]["strong@slab@16x16x16@fused@complex64@jnp"]["points"]:
        p["spread_frac"] = 0.20
    assert not diff_records(old, noisy, min_time=0.0)["regressions"]

    # sub-min-time keys are skipped entirely
    rep = diff_records(old, old, min_time=1e3)
    assert not rep["compared"] and len(rep["skipped"]) == rep["matched"]

    # different device_kind -> advisory, never enforced
    other = json.loads(json.dumps(old))
    other["device_kind"] = "TPU v5e"
    rep = diff_records(old, other, min_time=0.0)
    assert rep["advisory"] and "advisory_reason" in rep


def test_benchdiff_reads_committed_v1_v2_records():
    # the committed perf-trajectory records must keep flattening (BENCH_pr3
    # is bench-v1, pr4/7/8 bench-v2; pr9 is a serve-bench record with no
    # fftbench rows) and self-diff clean
    for name in ("BENCH_pr3.json", "BENCH_pr4.json", "BENCH_pr8.json"):
        rec = json.loads((REPO / "benchmarks" / name).read_text())
        rows = flatten_record(rec)
        assert rows, name
        assert all(r["best_s"] > 0 for r in rows.values()), name
        rep = diff_records(rec, rec)
        assert rep["matched"] == len(rows)
        assert not rep["regressions"] and not rep["improvements"]


def test_benchdiff_disjoint_records_warn_not_fail():
    v1 = json.loads((REPO / "benchmarks" / "BENCH_pr3.json").read_text())
    v3 = normalize_scaling(_raw_sweep())
    v3.pop("_fit_report")
    rep = diff_records(v1, v3)
    assert rep["matched"] == 0 and not rep["regressions"]


# -- figures ----------------------------------------------------------------


def test_render_scaling_figures(tmp_path):
    pytest.importorskip("matplotlib")
    from benchmarks.paperfigs import render_scaling_figures

    bench = normalize_scaling(_raw_sweep())
    bench.pop("_fit_report")
    paths = render_scaling_figures(bench, tmp_path)
    names = {p.name for p in paths}
    assert names == {"scaling_strong_slab.svg", "scaling_strong_slab.png",
                     "redistribution_split_slab.svg",
                     "redistribution_split_slab.png"}
    assert all(p.stat().st_size > 0 for p in paths)


def test_scalebench_one_real_point(subproc):
    # one end-to-end worker subprocess through scalebench.run_point: the
    # emitted blob must carry everything _series_point needs
    out = subprocess.run(
        [sys.executable, "-c", f"""
import sys
sys.path.insert(0, {str(REPO)!r})
import json
from benchmarks.scalebench import run_point
r = run_point((8, 8, 8), 2, grid="slab", method="fused", measure="total",
              inner=1, outer=2)
assert r["best_s"] > 0 and r["p50_s"] >= r["best_s"]
assert r["spread_frac"] >= 0
m = r["model"]
assert m["time_s"] > 0 and m["compute_s"] > 0
assert m["wire_bytes_per_dev"] > 0 and m["launches"] >= 1
print("POINT OK", json.dumps(m))
"""],
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "POINT OK" in out.stdout
