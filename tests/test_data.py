"""Data pipeline: determinism, shard partition, learnable structure."""

import numpy as np
from _hyp import given, settings, strategies as st

from repro.data import SyntheticLMData


def test_batch_determinism():
    d = SyntheticLMData(vocab=128, seq_len=16, global_batch=4, seed=3)
    b1, b2 = d.batch(7), d.batch(7)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))
    b3 = d.batch(8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


@given(pc=st.sampled_from([1, 2, 4]), step=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_host_shards_partition_batch(pc, step):
    d = SyntheticLMData(vocab=64, seq_len=8, global_batch=8, seed=0)
    full = d.batch(step)
    parts = [d.host_local_batch(step, process_index=i, process_count=pc)
             for i in range(pc)]
    got = np.concatenate([np.asarray(p["tokens"]) for p in parts], axis=0)
    np.testing.assert_array_equal(got, np.asarray(full["tokens"]))


def test_targets_are_next_token_predictable():
    """The bigram structure makes targets a function of (input, base):
    check targets stay in range and inputs are the shifted targets."""
    d = SyntheticLMData(vocab=97, seq_len=32, global_batch=2, seed=1)
    b = d.batch(0)
    toks, tgt = np.asarray(b["tokens"]), np.asarray(b["targets"])
    assert toks.min() >= 0 and toks.max() < 97
    np.testing.assert_array_equal(toks[:, 1:], tgt[:, :-1])
