"""Int8 gradient compression: quantization error bounds, error feedback,
multi-device compressed psum == exact psum (to quantization tolerance)."""

import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, strategies as st

from repro.optim.compress import ErrorFeedback, quantize_roundtrip


@given(scale=st.floats(1e-4, 1e3), seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_quant_relative_error(scale, seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((64,)) * scale, jnp.float32)}
    out = quantize_roundtrip(g)
    amax = float(jnp.max(jnp.abs(g["w"])))
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    assert err <= amax / 127.0 + 1e-9       # one quantization step


def test_error_feedback_unbiased_over_time():
    """With error feedback, the running SUM of sent grads tracks the running
    sum of true grads (residual stays bounded)."""
    rng = np.random.default_rng(0)
    err = ErrorFeedback.init({"w": jnp.zeros((32,), jnp.float32)})
    tot_true = np.zeros(32)
    tot_sent = np.zeros(32)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(32) * 0.01, jnp.float32)}
        sent, err = ErrorFeedback.apply(g, err, quantize_roundtrip)
        tot_true += np.asarray(g["w"])
        tot_sent += np.asarray(sent["w"])
    resid = np.abs(tot_true - tot_sent).max()
    assert resid <= np.abs(np.asarray(err["w"])).max() + 1e-6


def test_compressed_psum_matches_exact(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.core.meshutil import make_mesh, shard_map
from repro.optim.compress import compressed_psum
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
gs = {"a": jnp.asarray(rng.standard_normal((4, 33, 7)), jnp.float32),
      "b": jnp.asarray(rng.standard_normal((4, 130)), jnp.float32)}

def body(g):
    return compressed_psum(g, mesh, "data")

fn = shard_map(body, mesh=mesh,
                   in_specs=({"a": P("data", None, None), "b": P("data", None)},),
                   out_specs={"a": P("data", None, None), "b": P("data", None)},
                   check_vma=False)
out = fn(gs)
# every rank's output must equal the exact sum over ranks
for k in gs:
    want = np.asarray(gs[k]).sum(0)
    got = np.asarray(out[k])
    for r in range(4):
        amax = np.abs(want).max()
        np.testing.assert_allclose(got[r], want, atol=4 * amax / 127 + 1e-5)
print("COMPRESSED PSUM OK")
""", ndev=4)


def test_trainer_int8_compression_learns(subproc):
    """End-to-end: int8-compressed DP training still reduces the loss and
    stays close to the exact-gradient run."""
    subproc("""
import jax, numpy as np
from repro import configs
from repro.core.meshutil import make_mesh
from repro.data import SyntheticLMData
from repro.models.lm import LM
from repro.models.sharding import Axes
from repro.runtime import TrainConfig, Trainer
import tempfile

mesh = make_mesh((4, 1), ("data", "model"))
cfg = configs.smoke("glm4_9b")
lm = LM(cfg, mesh, Axes(multi_pod=False), q_block=8, xent_chunks=2)
data = SyntheticLMData(vocab=cfg.vocab, seq_len=16, global_batch=8)

losses = {}
for mode in ("none", "int8"):
    tc = TrainConfig(steps=25, ckpt_every=100, lr=3e-3, warmup=5,
                     ckpt_dir=tempfile.mkdtemp(), grad_compression=mode)
    _, _, hist = Trainer(lm, data, tc).run()
    losses[mode] = [h["loss"] for h in hist]
for mode, ls in losses.items():
    assert np.mean(ls[-5:]) < np.mean(ls[:5]), (mode, ls[:3], ls[-3:])
# compressed path tracks the exact path
assert abs(np.mean(losses["int8"][-5:]) - np.mean(losses["none"][-5:])) < 0.3
print("INT8 TRAINER OK", np.mean(losses["none"][-5:]), np.mean(losses["int8"][-5:]))
""", ndev=4)
