"""PlanConfig / StageEntry surface tests (repro.core.planconfig) and the
ParallelFFT legacy-kwarg deprecation shim.

These are pure construction/validation tests — 1 in-process device, no
collectives — so they pin the API contract cheaply: StageEntry.make's
legacy-tuple upgrades (including the 4-tuple impl-vs-batch_fusion
disambiguation), PlanConfig validation/canonicalization round-trips, and
the guarantee that a legacy-kwarg plan and its config= equivalent build
identical plans while warning exactly once per process.
"""

import warnings

import numpy as np
import pytest

from repro.core import pfft as pfft_mod
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig, StageEntry, as_schedule


# ---------------------------------------------------------------------------
# StageEntry
# ---------------------------------------------------------------------------

def test_stage_entry_make_all_forms():
    full = StageEntry("fused", 1, "bf16", "pallas", "per-field")
    assert StageEntry.make(full) == full
    # legacy 3-tuple: defaults fill in
    e = StageEntry.make(("traditional", 2, "int8"))
    assert e == ("traditional", 2, "int8", "jnp", "stacked")
    # 4-tuple disambiguation: the vocabularies are disjoint
    e = StageEntry.make(("fused", 1, "bf16", "pipelined-across-fields"))
    assert (e.impl, e.batch_fusion) == ("jnp", "pipelined-across-fields")
    e = StageEntry.make(("fused", 1, "bf16", "pallas"))
    assert (e.impl, e.batch_fusion) == ("pallas", "stacked")
    # 5-tuple passes straight through
    e = StageEntry.make(("pipelined", 4, "int8", "pallas", "stacked"))
    assert e == full._replace(method="pipelined", chunks=4, comm_dtype="int8",
                              batch_fusion="stacked")


def test_stage_entry_indexing_contract():
    """entry[2] is the comm_dtype everywhere it always was; the new fields
    sit behind it so index-based consumers (health, planlint) still work."""
    e = StageEntry("fused", 1, "int8", "pallas")
    assert e[0] == "fused" and e[1] == 1 and e[2] == "int8"
    assert e[3] == "pallas" and e[4] == "stacked"
    m, c, d, i, f = e
    assert (m, c, d, i, f) == ("fused", 1, "int8", "pallas", "stacked")
    # equality against the equivalent plain tuple (NamedTuple semantics)
    assert e == ("fused", 1, "int8", "pallas", "stacked")


def test_stage_entry_validation_and_canonicalization():
    # comm_dtype canonicalizes (None -> complex64) through validate()
    assert StageEntry.make(("fused", 1, None)).comm_dtype == "complex64"
    with pytest.raises(ValueError, match="unknown method"):
        StageEntry.make(("auto", 1, "bf16"))  # "auto" is plan-level only
    with pytest.raises(ValueError, match="chunks"):
        StageEntry.make(("fused", 0, "bf16"))
    with pytest.raises(ValueError, match="exchange impl"):
        StageEntry.make(("fused", 1, "bf16", "cuda", "stacked"))
    with pytest.raises(ValueError, match="batch_fusion"):
        StageEntry.make(("fused", 1, "bf16", "jnp", "interleaved"))
    with pytest.raises(ValueError, match="3-5"):
        StageEntry.make(("fused", 1))
    with pytest.raises(ValueError, match="3-5"):
        StageEntry.make(("fused", 1, "bf16", "jnp", "stacked", "extra"))


def test_as_schedule_normalizes_mixed_forms():
    sched = as_schedule([("fused", 1, "bf16"),
                         ("pipelined", 4, "int8", "pallas"),
                         StageEntry("traditional", 1, "complex64")])
    assert all(isinstance(e, StageEntry) and len(e) == 5 for e in sched)
    assert [e.impl for e in sched] == ["jnp", "pallas", "jnp"]


# ---------------------------------------------------------------------------
# PlanConfig
# ---------------------------------------------------------------------------

def test_planconfig_roundtrip_and_replace():
    cfg = PlanConfig(method="pipelined", chunks=3, comm_dtype="int8",
                     exchange_impl="pallas", guard="degrade")
    assert PlanConfig(**cfg.to_dict()) == cfg
    # replace() re-validates and re-canonicalizes
    assert cfg.replace(comm_dtype=None).comm_dtype == "complex64"
    with pytest.raises(ValueError, match="unknown exchange_impl"):
        cfg.replace(exchange_impl="cuda")
    # frozen: attribute assignment is an error
    with pytest.raises(AttributeError):
        cfg.method = "fused"


def test_planconfig_validation_errors():
    for bad in (dict(method="bogus"), dict(impl="fftw"),
                dict(exchange_impl="triton"), dict(chunks=0),
                dict(batch_fusion="zipped"), dict(guard="maybe")):
        with pytest.raises(ValueError):
            PlanConfig(**bad)


def test_planconfig_stage_entry():
    # chunks collapse to 1 unless the engine actually pipelines
    e = PlanConfig(method="fused", chunks=4, comm_dtype="bf16",
                   exchange_impl="pallas").stage_entry()
    assert e == ("fused", 1, "bf16", "pallas", "stacked")
    e = PlanConfig(method="pipelined", chunks=4, comm_dtype="int8").stage_entry()
    assert e == ("pipelined", 4, "int8", "jnp", "stacked")


def test_from_legacy_kwargs_drops_nones():
    cfg = PlanConfig.from_legacy_kwargs(method="traditional", impl=None,
                                        chunks=None, comm_dtype="bf16")
    assert (cfg.method, cfg.impl, cfg.chunks) == ("traditional", "jnp", 4)
    assert cfg.comm_dtype == "bf16"


# ---------------------------------------------------------------------------
# ParallelFFT shim: legacy kwargs == config=, warn once, conflict errors
# ---------------------------------------------------------------------------

MESH = make_mesh((1,), ("p0",))


def _reset_warn_flags():
    pfft_mod._legacy_kwargs_warned = False
    pfft_mod._real_kwarg_warned = False


def test_legacy_kwargs_equivalent_and_warn_once():
    _reset_warn_flags()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = ParallelFFT(MESH, (8, 6, 4), ("p0",), method="pipelined",
                             chunks=2, comm_dtype="bf16")
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1 and "config=PlanConfig" in str(dep[0].message)
        # second legacy construction: silent (once per process)
        ParallelFFT(MESH, (8, 6, 4), ("p0",), method="pipelined", chunks=2,
                    comm_dtype="bf16")
        assert sum(issubclass(w.category, DeprecationWarning) for w in rec) == 1
    cfg = ParallelFFT(MESH, (8, 6, 4), ("p0",),
                      config=PlanConfig(method="pipelined", chunks=2,
                                        comm_dtype="bf16"))
    assert legacy.config == cfg.config
    assert legacy.schedule == cfg.schedule
    x = (np.arange(8 * 6 * 4).reshape(8, 6, 4) % 7 + 1j).astype(np.complex64)
    np.testing.assert_array_equal(np.asarray(legacy.forward(x)),
                                  np.asarray(cfg.forward(x)))


def test_config_plus_legacy_kwarg_conflict():
    with pytest.raises(ValueError, match="not both"):
        ParallelFFT(MESH, (8, 6, 4), ("p0",), config=PlanConfig(),
                    method="fused")


def test_real_kwarg_deprecated_but_equivalent():
    _reset_warn_flags()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = ParallelFFT(MESH, (8, 6, 4), ("p0",), real=True)
        assert any(issubclass(w.category, DeprecationWarning)
                   and "transforms=" in str(w.message) for w in rec)
    new = ParallelFFT(MESH, (8, 6, 4), ("p0",),
                      transforms=("c2c", "c2c", "r2c"))
    assert [s.kind for s in legacy.transforms] == [s.kind for s in new.transforms]
    x = np.arange(8 * 6 * 4, dtype=np.float32).reshape(8, 6, 4) % 5
    np.testing.assert_array_equal(np.asarray(legacy.forward(x)),
                                  np.asarray(new.forward(x)))
    with pytest.raises(ValueError, match="not both"):
        ParallelFFT(MESH, (8, 6, 4), ("p0",), real=True,
                    transforms=("c2c", "c2c", "r2c"))


def test_plan_mirrors_config():
    plan = ParallelFFT(MESH, (8, 6, 4), ("p0",),
                       config=PlanConfig(method="traditional",
                                         comm_dtype="int8",
                                         exchange_impl="pallas",
                                         guard="off"))
    assert plan.method == "traditional"
    assert plan.comm_dtype == "int8"
    assert plan.exchange_impl == "pallas"
    assert plan.guard == "off"
    assert plan.config.stage_entry().impl == "pallas"
