"""Exchange-schedule autotuner: candidate sweep (engines × comm_dtype
payloads × exchange impls × batch fusions), schema-v6 disk cache
round-trip, stale-cache migration, atomic merge writes, quarantine
marks."""

import json
import threading

import pytest

from repro.core import tuner


def test_tuner_cache_roundtrip(subproc, tmp_path):
    """Tuning writes the schedule+timings to disk; a fresh plan (fresh
    process, empty memo) must reload it instead of re-benchmarking."""
    cache = tmp_path / "tune" / "fft_tuner.json"
    code = f"""
import json
import jax, numpy as np
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

cache = {str(cache)!r}
mesh = make_mesh((2, 2), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto", tuner_cache=cache)
sched = plan.schedule
assert len(sched) == plan.n_exchanges == 2
for method, chunks, comm_dtype, impl, fusion in sched:
    assert method in ("fused", "traditional", "pipelined")
    assert chunks >= 1
    # default accuracy budget is lossless: only complex64 may be picked
    assert comm_dtype == "complex64"
    # no pallas budget requested: every entry runs the jnp reference impl
    assert impl == "jnp" and fusion == "stacked"

disk = json.loads(open(cache).read())
key = tuner.plan_key(plan)
assert key in disk
assert json.loads(key)["schema"] == tuner.SCHEMA_VERSION
assert "device_kind" in json.loads(key)
assert [tuple(s) for s in disk[key]["schedule"]] == list(sched)
# every candidate was timed for both exchange stages
stages = disk[key]["timings"]
assert len(stages) == 2
for per in stages.values():
    timed = {{k: v for k, v in per.items() if ":" not in k}}  # drop error notes
    assert set(timed) == {{tuner._tag(c) for c in tuner.DEFAULT_CANDIDATES}}
    assert all(t > 0 for t in timed.values())

# fresh-memo reload: poison tune_plan; a cache hit must not call it
tuner._MEMO.clear()
def boom(*a, **k):
    raise AssertionError("cache miss: tune_plan re-ran")
tuner.tune_plan = boom
plan2 = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto", tuner_cache=cache)
assert plan2.schedule == sched
print("TUNER CACHE OK", json.dumps([list(s) for s in sched]))
"""
    out = subproc(code, ndev=4)
    assert "TUNER CACHE OK" in out


def test_tuner_comm_dtype_budget_cache_roundtrip(subproc, tmp_path):
    """An int8 accuracy budget widens the sweep to engines × {complex64,
    bf16, int8}; per-stage comm_dtype choices round-trip through the disk
    cache into a fresh process (issue acceptance criterion)."""
    cache = tmp_path / "fft_tuner.json"
    code = f"""
import json
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

cache = {str(cache)!r}
mesh = make_mesh((2, 2), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto",
                   comm_dtype="int8", tuner_cache=cache)
sched = plan.schedule
assert len(sched) == 2
for method, chunks, comm_dtype, impl, fusion in sched:
    assert comm_dtype in ("complex64", "bf16", "int8")

disk = json.loads(open(cache).read())
key = tuner.plan_key(plan)
want_tags = {{tuner._tag(c) for c in tuner.candidates_for("int8")}}
for per in disk[key]["timings"].values():
    assert {{k for k in per if ":" not in k}} == want_tags

# a fresh process (memo empty) must reload the same schedule
tuner._MEMO.clear()
tuner.tune_plan = None  # cache hit must not benchmark
plan2 = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto",
                    comm_dtype="int8", tuner_cache=cache)
assert plan2.schedule == sched
print("BUDGET CACHE OK", json.dumps([list(s) for s in sched]))
"""
    out = subproc(code, ndev=4)
    assert "BUDGET CACHE OK" in out


def test_stale_or_corrupt_cache_ignored_and_rewritten(subproc, tmp_path):
    """Cache migration (PR 4 satellite): a stale-schema (or corrupt) cache
    file dropped in the cache path before ``method="auto"`` must be
    silently ignored and rewritten with a valid current-schema entry —
    never raise.  Covers: invalid JSON, a JSON non-dict, a stale v3-style
    entry set, and a matching current key whose entry body is malformed."""
    cache = tmp_path / "fft_tuner.json"
    code = f"""
import json
from pathlib import Path
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

cache = Path({str(cache)!r})
mesh = make_mesh((2, 2), ("p0", "p1"))
stale_payloads = [
    '{{ not json',                                     # corrupt bytes
    '[1, 2, 3]',                                       # valid JSON, wrong container
    json.dumps({{'{{"schema": 3, "mesh": []}}':        # v3-era entry set
                 {{"schedule": [["fused", 1, "complex64"]], "timings": {{}}}}}}),
]
for payload in stale_payloads:
    cache.write_text(payload)
    tuner._MEMO.clear()
    plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto",
                       tuner_cache=str(cache))
    sched = plan.schedule  # must tune and rewrite, not raise
    assert len(sched) == plan.n_exchanges == 2
    disk = json.loads(cache.read_text())  # rewritten as valid JSON
    key = tuner.plan_key(plan)
    assert key in disk
    assert json.loads(key)["schema"] == tuner.SCHEMA_VERSION == 6
    print("ok", payload[:30])

# a *matching* v4 key whose entry body is junk must also fall back to
# retuning instead of raising or replaying garbage
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto",
                   tuner_cache=str(cache))
key = tuner.plan_key(plan)
for bad_entry in ("garbage", {{"schedule": "garbage"}}, {{"schedule": [["x"]]}},
                  {{"schedule": [["fused", 1, "complex64"]]}},  # wrong stage count
                  # structurally valid but unknown engine / payload values:
                  # must retune, not raise later inside the executor
                  {{"schedule": [["bogus", 1, "complex64"],
                                 ["fused", 1, "complex64"]]}},
                  {{"schedule": [["fused", 1, "float8"],
                                 ["fused", 1, "complex64"]]}}):
    cache.write_text(json.dumps({{key: bad_entry}}))
    tuner._MEMO.clear()
    p = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto",
                    tuner_cache=str(cache))
    sched = p.schedule
    assert len(sched) == 2 and all(len(e) == 5 for e in sched)
    disk = json.loads(cache.read_text())
    assert [tuple(s) for s in disk[key]["schedule"]] == list(sched)

# entries that parse fine but name candidates OUTSIDE the live sweep (a
# cache written by a different build, or hand-edited) must retune too —
# replaying them would execute a schedule the tuner never timed
for poisoned in ([["pipelined", 16, "complex64"], ["fused", 1, "complex64"]],
                 [["fused", 1, "int8"], ["fused", 1, "complex64"]]):
    cache.write_text(json.dumps({{key: {{"schedule": poisoned, "timings": {{}}}}}}))
    tuner._MEMO.clear()
    p = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto",
                    tuner_cache=str(cache))
    sched = p.schedule
    live = set(tuner.candidates_for(None))
    assert all(tuple(e) in live for e in sched), (poisoned, sched)
    assert list(map(list, sched)) != poisoned
print("STALE CACHE MIGRATION OK")
"""
    out = subproc(code, ndev=4)
    assert "STALE CACHE MIGRATION OK" in out


def test_plan_key_discriminates():
    """Key must change with anything that changes stage shapes/engines."""
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT

    mesh = make_mesh((1, 1), ("p0", "p1"))
    base = ParallelFFT(mesh, (8, 8, 8), ("p0",), method="auto")
    keys = {tuner.plan_key(base)}
    for plan in (
        ParallelFFT(mesh, (8, 8, 16), ("p0",), method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0", "p1"), method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0",), real=True, method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0",), impl="matmul", method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0",), method="auto", comm_dtype="bf16"),
        ParallelFFT(mesh, (8, 8, 8), ("p0",), method="auto", comm_dtype="int8"),
    ):
        keys.add(tuner.plan_key(plan))
    assert len(keys) == 7
    # batch size is part of the key: 1-field and N-field schedules never collide
    keys.add(tuner.plan_key(base, nfields=3))
    keys.add(tuner.plan_key(base, nfields=8))
    assert len(keys) == 9
    # keys are deterministic and json-round-trippable
    assert tuner.plan_key(base) == tuner.plan_key(base)
    decoded = json.loads(tuner.plan_key(base))
    assert decoded["shape"] == [8, 8, 8]
    # hardware identity: timings from different device generations under
    # the same backend string must not collide
    assert decoded["schema"] == tuner.SCHEMA_VERSION
    assert decoded["device_kind"]
    assert decoded["backend"]


def test_candidates_cover_issue_matrix():
    assert ("fused", 1) in tuner.ENGINE_CANDIDATES
    assert ("traditional", 1) in tuner.ENGINE_CANDIDATES
    for c in (2, 4, 8):
        assert ("pipelined", c) in tuner.ENGINE_CANDIDATES
    # default budget is lossless
    assert set(e.comm_dtype for e in tuner.DEFAULT_CANDIDATES) == {"complex64"}
    # the ladder is monotone: each budget adds payloads, never drops them
    assert set(tuner.candidates_for("bf16")) > set(tuner.candidates_for(None))
    assert set(tuner.candidates_for("int8")) > set(tuner.candidates_for("bf16"))
    for e in tuner.candidates_for("int8"):
        assert (e.method, e.chunks) in tuner.ENGINE_CANDIDATES
        assert e.comm_dtype in ("complex64", "bf16", "int8")
        assert e.impl == "jnp"  # no pallas budget requested
    # a pallas budget adds fused-kernel candidates for every lossy payload
    pall = tuner.candidates_for("int8", "pallas")
    assert set(pall) > set(tuner.candidates_for("int8"))
    extra = set(pall) - set(tuner.candidates_for("int8"))
    assert extra and all(e.impl == "pallas" and e.comm_dtype != "complex64"
                         for e in extra)
    # batched candidates: every single-field candidate x every fusion mode
    batched = tuner.batched_candidates_for("bf16")
    assert len(batched) == 3 * len(tuner.candidates_for("bf16"))
    assert {e.batch_fusion for e in batched} == {
        "stacked", "pipelined-across-fields", "per-field"}
    assert {e._replace(batch_fusion="stacked") for e in batched} == set(
        tuner.candidates_for("bf16"))


def test_save_cache_atomic(tmp_path):
    """save_cache must never leave a partially-written cache visible: the
    final file is always complete JSON and no temp droppings remain."""
    path = tmp_path / "sub" / "cache.json"
    data = {"k": {"schedule": [["fused", 1, "complex64"]], "timings": {}}}
    assert tuner.save_cache(path, data)
    assert json.loads(path.read_text()) == data
    # overwrite with concurrent writers: every reader observes valid JSON
    errs = []

    def writer(i):
        try:
            assert tuner.save_cache(path, {f"key{i}": i})
            json.loads(path.read_text())
        except Exception as e:  # pragma: no cover - failure reporting
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    json.loads(path.read_text())  # final state is one writer's full payload
    # no temp files left behind (the advisory .lock file is expected)
    leftovers = [p for p in path.parent.iterdir()
                 if p.name not in (path.name, path.name + ".lock")]
    assert leftovers == []
    # merge semantics: every writer's key survived (the in-process flock
    # serialized the read-merge-write cycles)
    final = json.loads(path.read_text())
    assert {f"key{i}" for i in range(8)} <= set(final)


def test_v5_entry_migrates_without_retune(subproc, tmp_path):
    """A healthy schema-5 cache entry (3-field jnp rows) must be *migrated*
    to v6 — upgraded through StageEntry.make and re-saved under the v6 key
    with ``migrated_from_schema: 5`` — never re-benchmarked: the jnp-only
    candidate space is unchanged, so the v5 timings stay valid.  An
    ``exchange_impl="pallas"`` budget must refuse the migration (its v6
    candidate set sweeps kernels the v5 run never measured) and retune."""
    cache = tmp_path / "fft_tuner.json"
    code = f"""
import json
from pathlib import Path
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

cache = Path({str(cache)!r})
mesh = make_mesh((2, 2), ("p0", "p1"))
mk = lambda **kw: ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"),
                              config=PlanConfig(method="auto",
                                                tuner_cache=str(cache), **kw))
plan = mk()

# hand-build the v5 cache file: schema-5 key, 3-field schedule rows, the
# legacy jnp-only candidate tags
fields = tuner._key_fields(plan, 1)
fields["schema"] = 5
fields["candidates"] = sorted(
    tuner._tag(c) for c in tuner._legacy_v5_candidates(plan, 1))
legacy_key = json.dumps(fields, sort_keys=True, default=str)
v5_sched = [["fused", 1, "complex64"], ["traditional", 1, "complex64"]]
v5_timings = {{"stage1": {{"fused@1@complex64": 1e-4}}}}
cache.write_text(json.dumps(
    {{legacy_key: {{"schedule": v5_sched, "timings": v5_timings}}}}))

# poison tune_plan: a migration that falls back to benchmarking is a bug
real_tune = tuner.tune_plan
def boom(*a, **k):
    raise AssertionError("v5 migration fell back to retuning")
tuner.tune_plan = boom
tuner._MEMO.clear()
sched = mk().schedule
assert [list(s) for s in sched] == [s + ["jnp", "stacked"] for s in v5_sched]

disk = json.loads(cache.read_text())
v6_key = tuner.plan_key(plan)
assert v6_key in disk and legacy_key in disk  # migrated copy, original kept
assert disk[v6_key]["migrated_from_schema"] == 5
assert disk[v6_key]["timings"] == v5_timings  # timings carried over
assert [tuple(s) for s in disk[v6_key]["schedule"]] == list(sched)

# a quarantined v5 entry must NOT migrate (the mark is the whole point)
cache.write_text(json.dumps({{legacy_key: {{
    "schedule": v5_sched, "timings": {{}}, "bad": {{"reason": "x"}}}}}}))
tuner._MEMO.clear()
try:
    mk().schedule
    raise SystemExit("quarantined v5 entry was replayed")
except AssertionError as e:
    assert "retuning" in str(e)

# pallas budget: v5 never measured the kernel candidates -> must retune
cache.write_text(json.dumps(
    {{legacy_key: {{"schedule": v5_sched, "timings": v5_timings}}}}))
tuner._MEMO.clear()
try:
    mk(exchange_impl="pallas").schedule
    raise SystemExit("pallas budget migrated a jnp-only v5 entry")
except AssertionError as e:
    assert "retuning" in str(e)
tuner.tune_plan = real_tune
print("V5 MIGRATION OK")
"""
    out = subproc(code, ndev=4)
    assert "V5 MIGRATION OK" in out


def test_committed_v5_fixture_migrates(subproc, tmp_path):
    """The committed v5 cache fixture (tests/data/fft_tuner_v5.json,
    generated on the cpu backend the CI matrix runs) must resolve its
    plan's schedule by migration alone — tune_plan poisoned — proving old
    user caches survive the v6 schema bump without a retune."""
    import shutil
    from pathlib import Path

    fixture = Path(__file__).parent / "data" / "fft_tuner_v5.json"
    cache = tmp_path / "fft_tuner.json"
    shutil.copy(fixture, cache)
    code = f"""
import json
from pathlib import Path
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT
from repro.core.planconfig import PlanConfig

cache = Path({str(cache)!r})
def boom(*a, **k):
    raise AssertionError("committed v5 cache was not migrated: tune_plan ran")
tuner.tune_plan = boom
mesh = make_mesh((2, 2), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"),
                   config=PlanConfig(method="auto", tuner_cache=str(cache)))
sched = plan.schedule
assert [list(s) for s in sched] == [["fused", 1, "complex64", "jnp", "stacked"],
                                    ["traditional", 1, "complex64", "jnp", "stacked"]]
disk = json.loads(cache.read_text())
v6 = disk[tuner.plan_key(plan)]
assert v6["migrated_from_schema"] == 5 and v6["timings"]
print("COMMITTED V5 FIXTURE OK")
"""
    out = subproc(code, ndev=4)
    assert "COMMITTED V5 FIXTURE OK" in out


def test_quarantine_locks_without_self_deadlock(tmp_path):
    """quarantine holds the cross-process file lock across its whole
    read-bump-write and must not re-acquire it from a second fd inside
    save_cache (flock is per open-file-description: that would deadlock).
    Regression: this call simply has to return."""
    path = tmp_path / "cache.json"
    tuner.save_cache(path, {"k": {"schedule": [["fused", 1, "complex64"]],
                                  "timings": {}}})
    assert tuner.quarantine(path, "k", "boom") == 1
    assert tuner.quarantine(path, "k", "boom again") == 2
    entry = json.loads(path.read_text())["k"]
    assert entry["bad"]["reason"] == "boom again"
    assert entry["quarantines"] == 2


def test_save_cache_cross_process_lock(tmp_path):
    """Concurrent *processes* merging disjoint keys into one cache must not
    lose updates: the fcntl.flock around the read-merge-write cycle closes
    the interleave where two writers read the same snapshot and the later
    os.replace drops the earlier writer's keys."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    fcntl = pytest.importorskip("fcntl")
    assert fcntl  # the lock is a no-op without it; nothing to test then
    path = tmp_path / "shared.json"
    nproc, nkeys = 4, 12
    code = """
import sys
from repro.core import tuner
path, wid = sys.argv[1], int(sys.argv[2])
for j in range({nkeys}):
    assert tuner.save_cache(path, {{"w%d-k%d" % (wid, j): {{"v": wid}}}})
print("WRITER-DONE")
""".format(nkeys=nkeys)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    procs = [subprocess.Popen([sys.executable, "-c", code, str(path), str(i)],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for i in range(nproc)]
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err[-2000:]
        assert "WRITER-DONE" in out
    final = json.loads(path.read_text())
    expect = {f"w{i}-k{j}" for i in range(nproc) for j in range(nkeys)}
    missing = expect - set(final)
    assert not missing, f"lost {len(missing)} updates: {sorted(missing)[:5]}"
