"""Exchange-schedule autotuner: candidate sweep, disk cache round-trip."""

import json

from repro.core import tuner


def test_tuner_cache_roundtrip(subproc, tmp_path):
    """Tuning writes the schedule+timings to disk; a fresh plan (fresh
    process, empty memo) must reload it instead of re-benchmarking."""
    cache = tmp_path / "tune" / "fft_tuner.json"
    code = f"""
import json
import jax, numpy as np
from repro.core import tuner
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

cache = {str(cache)!r}
mesh = make_mesh((2, 2), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto", tuner_cache=cache)
sched = plan.schedule
assert len(sched) == plan.n_exchanges == 2
for method, chunks in sched:
    assert method in ("fused", "traditional", "pipelined")
    assert chunks >= 1

disk = json.loads(open(cache).read())
key = tuner.plan_key(plan)
assert key in disk
assert [tuple(s) for s in disk[key]["schedule"]] == list(sched)
# every candidate was timed for both exchange stages
stages = disk[key]["timings"]
assert len(stages) == 2
for per in stages.values():
    timed = {{k: v for k, v in per.items() if ":" not in k}}  # drop error notes
    assert set(timed) == {{f"{{m}}@{{c}}" for m, c in tuner.DEFAULT_CANDIDATES}}
    assert all(t > 0 for t in timed.values())

# fresh-memo reload: poison tune_plan; a cache hit must not call it
tuner._MEMO.clear()
def boom(*a, **k):
    raise AssertionError("cache miss: tune_plan re-ran")
tuner.tune_plan = boom
plan2 = ParallelFFT(mesh, (16, 8, 8), ("p0", "p1"), method="auto", tuner_cache=cache)
assert plan2.schedule == sched
print("TUNER CACHE OK", json.dumps([list(s) for s in sched]))
"""
    out = subproc(code, ndev=4)
    assert "TUNER CACHE OK" in out


def test_plan_key_discriminates():
    """Key must change with anything that changes stage shapes/engines."""
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT

    mesh = make_mesh((1, 1), ("p0", "p1"))
    base = ParallelFFT(mesh, (8, 8, 8), ("p0",), method="auto")
    keys = {tuner.plan_key(base)}
    for plan in (
        ParallelFFT(mesh, (8, 8, 16), ("p0",), method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0", "p1"), method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0",), real=True, method="auto"),
        ParallelFFT(mesh, (8, 8, 8), ("p0",), impl="matmul", method="auto"),
    ):
        keys.add(tuner.plan_key(plan))
    assert len(keys) == 5
    # keys are deterministic and json-round-trippable
    assert tuner.plan_key(base) == tuner.plan_key(base)
    assert json.loads(tuner.plan_key(base))["shape"] == [8, 8, 8]


def test_default_candidates_cover_issue_matrix():
    assert ("fused", 1) in tuner.DEFAULT_CANDIDATES
    assert ("traditional", 1) in tuner.DEFAULT_CANDIDATES
    for c in (2, 4, 8):
        assert ("pipelined", c) in tuner.DEFAULT_CANDIDATES
