"""Hypothesis shim: property tests degrade to a fixed seed-case sweep when
``hypothesis`` is not installed (it is a dev-only dependency, see
requirements-dev.txt).

Usage in tests (drop-in for the real import)::

    from _hyp import given, settings, strategies as st

With hypothesis installed this re-exports the real thing.  Without it,
``given`` runs the test once per deterministic example: the strategy
bounds (both endpoints) plus seeded random draws — far weaker than real
property testing, but it keeps every test module collectable and the
checked invariants exercised on a dependency-light CPU container.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import math

    import numpy as np

    #: examples per test in fallback mode (bounds + random draws)
    FALLBACK_MAX_EXAMPLES = 12

    class _Strategy:
        def example(self, rng):
            raise NotImplementedError

        def bounds(self):
            return []

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

        def bounds(self):
            return [self.lo, self.hi]

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def example(self, rng):
            # log-uniform when the range spans decades (matches how the
            # tests use floats: scales, norms)
            if self.lo > 0 and self.hi / self.lo > 100:
                return float(math.exp(rng.uniform(math.log(self.lo), math.log(self.hi))))
            return float(rng.uniform(self.lo, self.hi))

        def bounds(self):
            return [self.lo, self.hi]

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return self.elements[int(rng.integers(len(self.elements)))]

        def bounds(self):
            return [self.elements[0], self.elements[-1]]

    class strategies:  # noqa: N801 — mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    def settings(*, max_examples=None, deadline=None, **_ignored):
        """Records max_examples on the test for ``given`` to cap against."""

        def deco(fn):
            if max_examples is not None:
                fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            n = min(getattr(fn, "_fallback_max_examples", FALLBACK_MAX_EXAMPLES),
                    FALLBACK_MAX_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                names = sorted(strats)
                cases = []
                # both bounds of every strategy first (the classic bug homes)
                width = max(len(strats[k].bounds()) for k in names)
                for i in range(width):
                    case = {}
                    for k in names:
                        b = strats[k].bounds()
                        case[k] = b[min(i, len(b) - 1)] if b else strats[k].example(rng)
                    cases.append(case)
                while len(cases) < max(n, width):
                    cases.append({k: strats[k].example(rng) for k in names})
                for case in cases[: max(n, width)]:
                    try:
                        fn(*args, **case, **kwargs)
                    except Exception:
                        print(f"falsifying example ({fn.__name__}): {case}")
                        raise

            # hide the wrapped signature: pytest must not treat the strategy
            # parameters as fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco
