"""Checkpoint store: atomicity, integrity, async, elastic re-shard."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": jnp.int32(7)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    out, manifest = load_checkpoint(tmp_path, t)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_tmp_ignored(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate a torn write: stale .tmp dir with garbage
    bad = tmp_path / "step_0000000002.tmp"
    bad.mkdir()
    (bad / "junk.npy").write_bytes(b"broken")
    assert latest_step(tmp_path) == 1
    out, manifest = load_checkpoint(tmp_path, t)
    assert manifest["step"] == 1


def test_checksum_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(tmp_path, 1, t)
    target = next(p for p in path.glob("*.npy") if "a" in p.name)
    arr = np.load(target)
    arr = arr + 1
    np.save(target, arr)
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(tmp_path, t)


def test_gc_keeps_last_k(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(tmp_path, s, t, keep=3)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4, 5]


def test_async_manager(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save_async(10, t)
    mgr.save_async(20, t)  # waits for 10 internally
    mgr.wait()
    assert mgr.latest_step() == 20


def test_elastic_reshard(subproc):
    """Save params sharded on mesh (2, 4); restore onto mesh (4, 2)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.meshutil import make_mesh
from repro.checkpoint import save_checkpoint, load_checkpoint

tmp = tempfile.mkdtemp()
m1 = make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(m1, P("data", "model")))
save_checkpoint(tmp, 5, {"w": xs})

m2 = make_mesh((4, 2), ("data", "model"))
tgt_shard = {"w": NamedSharding(m2, P("data", "model"))}
out, manifest = load_checkpoint(tmp, {"w": x}, shardings=tgt_shard)
assert out["w"].sharding.mesh.shape["data"] == 4
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
print("ELASTIC OK")
""", ndev=8)


def test_async_save_failure_surfaces_on_wait(tmp_path, monkeypatch):
    """A save_async worker-thread exception must not pass silently: the
    next wait() raises AsyncCheckpointError carrying the failing step and
    chaining the original exception."""
    import repro.checkpoint.store as store
    from repro.checkpoint.store import AsyncCheckpointError

    mgr = CheckpointManager(tmp_path)

    def boom(*a, **k):
        raise OSError("disk on fire")

    monkeypatch.setattr(store, "save_checkpoint", boom)
    mgr.save_async(7, _tree())
    with pytest.raises(AsyncCheckpointError) as ei:
        mgr.wait()
    assert ei.value.step == 7
    assert isinstance(ei.value.__cause__, OSError)
    mgr.wait()  # surfaced exactly once; manager is reusable afterwards


def test_async_save_failure_surfaces_on_next_save(tmp_path, monkeypatch):
    """save_async itself must surface the previous write's failure before
    admitting a new one (a training loop that never calls wait() between
    saves still cannot lose a failed checkpoint silently)."""
    import repro.checkpoint.store as store
    from repro.checkpoint.store import AsyncCheckpointError

    mgr = CheckpointManager(tmp_path)
    real = store.save_checkpoint

    def boom(*a, **k):
        raise RuntimeError("transient writer death")

    monkeypatch.setattr(store, "save_checkpoint", boom)
    mgr.save_async(1, _tree())
    monkeypatch.setattr(store, "save_checkpoint", real)
    with pytest.raises(AsyncCheckpointError) as ei:
        mgr.save_async(2, _tree())
    assert ei.value.step == 1
    # the failure was surfaced (and cleared): the retry goes through
    mgr.save_async(2, _tree())
    mgr.wait()
    assert mgr.latest_step() == 2
