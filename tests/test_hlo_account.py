"""Trip-count-aware HLO accounting vs known-cost programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_account import account, execution_counts, parse


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_trip_aware():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    out = account(txt)
    assert out["flops"] == pytest.approx(2 * 128**3 * 10, rel=1e-6)
    assert out["unknown_trip_whiles"] == 0


def test_nested_scan_flops():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c2, _ = jax.lax.scan(inner, c, None, length=4)
        return c2, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert account(txt)["flops"] == pytest.approx(2 * 64**3 * 20, rel=1e-6)


def test_scan_cache_update_not_charged_in_full():
    """A scan that dynamic-update-slices one row per step must NOT be charged
    the full buffer every step (the bug class this module exists to avoid)."""
    N, D = 64, 256
    buf = jax.ShapeDtypeStruct((N, D), jnp.float32)

    def f(buf):
        def body(b, i):
            row = jnp.full((1, D), i, jnp.float32)
            return jax.lax.dynamic_update_slice(b, row, (i, 0)), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(N))
        return out

    txt = _compile_text(f, buf)
    hbm = account(txt)["hbm_bytes"]
    full_every_step = N * (N * D * 4)          # the naive overcount
    assert hbm < full_every_step / 4, (hbm, full_every_step)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    txt = _compile_text(f, jax.ShapeDtypeStruct((4, 32, 16), jnp.float32),
                        jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
    # 2 * B*M*N*K
    assert account(txt)["flops"] == pytest.approx(2 * 4 * 32 * 8 * 16, rel=1e-6)


def test_parse_computations():
    hlo = """
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8]) -> f32[] {
  %x = f32[8]{0} parameter(0)
  %c = f32[] constant(0)
  ROOT %red = f32[] reduce(%x, %c), dimensions={0}, to_apply=%add
}
"""
    comps = parse(hlo)
    assert set(comps) == {"add", "main"}
    mult = execution_counts(comps, hlo)
    assert mult["main"] == 1.0 and mult["add"] == 1.0
