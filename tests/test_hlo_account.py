"""Trip-count-aware HLO accounting vs known-cost programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_account import account, execution_counts, parse


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_trip_aware():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    out = account(txt)
    assert out["flops"] == pytest.approx(2 * 128**3 * 10, rel=1e-6)
    assert out["unknown_trip_whiles"] == 0


def test_nested_scan_flops():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c2, _ = jax.lax.scan(inner, c, None, length=4)
        return c2, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert account(txt)["flops"] == pytest.approx(2 * 64**3 * 20, rel=1e-6)


def test_scan_cache_update_not_charged_in_full():
    """A scan that dynamic-update-slices one row per step must NOT be charged
    the full buffer every step (the bug class this module exists to avoid)."""
    N, D = 64, 256
    buf = jax.ShapeDtypeStruct((N, D), jnp.float32)

    def f(buf):
        def body(b, i):
            row = jnp.full((1, D), i, jnp.float32)
            return jax.lax.dynamic_update_slice(b, row, (i, 0)), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(N))
        return out

    txt = _compile_text(f, buf)
    hbm = account(txt)["hbm_bytes"]
    full_every_step = N * (N * D * 4)          # the naive overcount
    assert hbm < full_every_step / 4, (hbm, full_every_step)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    txt = _compile_text(f, jax.ShapeDtypeStruct((4, 32, 16), jnp.float32),
                        jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
    # 2 * B*M*N*K
    assert account(txt)["flops"] == pytest.approx(2 * 4 * 32 * 8 * 16, rel=1e-6)


def test_collective_instrs_payload_pricing():
    """Per-instruction collective records price wire bytes by replica-group
    size: all-to-all ships (G-1)/G of its result, all-gather one shard,
    reduce-scatter reads G shards — on both replica_groups encodings."""
    from repro.launch.hlo_account import collective_instrs

    hlo = """
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,4]) -> f32[8,4] {
  %x = f32[8,4]{1,0} parameter(0)
  %a2a = f32[8,4]{1,0} all-to-all(%x), replica_groups={{0,1},{2,3}}, dimensions={0}
  %ag = f32[8,4]{1,0} all-gather(%a2a), replica_groups=[2,2]<=[4], dimensions={0}
  ROOT %rs = f32[8,4]{1,0} reduce-scatter(%ag), replica_groups={{0,1},{2,3}}, to_apply=%add
}
"""
    recs = {r["kind"]: r for r in collective_instrs(hlo)}
    assert set(recs) == {"all-to-all", "all-gather", "reduce-scatter"}
    assert all(r["group_size"] == 2 and r["result_bytes"] == 128
               and r["mult"] == 1.0 for r in recs.values())
    assert recs["all-to-all"]["payload_bytes"] == 128 * (2 - 1) // 2
    assert recs["all-gather"]["payload_bytes"] == 128 // 2
    assert recs["reduce-scatter"]["payload_bytes"] == 128 * 2
    assert recs["all-to-all"]["dtypes"] == ["f32"]
    # account() totals agree with the per-instruction view
    coll = account(hlo)["collectives"]
    assert coll["all-to-all"] == recs["all-to-all"]["payload_bytes"]
    assert coll["total"] == sum(r["payload_bytes"] for r in recs.values())


def test_group_size_tuple_operand_fallback():
    """The CPU backend's decomposed all-to-all carries no usable
    replica_groups annotation; group size falls back to the operand count."""
    from repro.launch.hlo_account import collective_instrs

    hlo = """
ENTRY %main (a: f32[4], b: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %b = f32[4]{0} parameter(1)
  ROOT %t = (f32[4]{0}, f32[4]{0}) all-to-all(%a, %b), dimensions={0}
}
"""
    (rec,) = collective_instrs(hlo)
    assert rec["group_size"] == 2
    assert rec["result_bytes"] == 32          # tuple of two f32[4]
    assert rec["payload_bytes"] == 32 * (2 - 1) // 2


def test_unknown_dtype_warned_once():
    """Shapes whose dtype is missing from _DTYPE_BYTES must warn (once per
    dtype, process-wide) instead of silently vanishing from byte totals."""
    import warnings

    from repro.launch import hlo_account

    hlo_account._WARNED_DTYPES.discard("f8e3m4")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert hlo_account._types_bytes("f8e3m4[16] f32[2]") == 8
        assert hlo_account._types_bytes("f8e3m4[16]") == 0  # second: silent
    assert len(w) == 1 and "f8e3m4" in str(w[0].message)


def test_parse_computations():
    hlo = """
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8]) -> f32[] {
  %x = f32[8]{0} parameter(0)
  %c = f32[] constant(0)
  ROOT %red = f32[] reduce(%x, %c), dimensions={0}, to_apply=%add
}
"""
    comps = parse(hlo)
    assert set(comps) == {"add", "main"}
    mult = execution_counts(comps, hlo)
    assert mult["main"] == 1.0 and mult["add"] == 1.0
