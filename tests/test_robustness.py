"""Guarded execution: degradation ladder, fused guard stats, the fault
matrix, tuner-cache merge semantics, checkpoint fallback, and adversarial
codec properties.

Unit tests run on the default single device.  Anything needing a real
process mesh goes through ``subproc`` (fresh interpreter, 8 virtual
devices).  The full injector x {strict, degrade} matrix is marked
``faults`` — CI runs it as its own chaos job (``pytest -m faults``).
"""

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import quant, tuner
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.robustness import FaultPlan, faults, health
from repro.robustness.runner import degrade_entry, degrade_schedule


# ---------------------------------------------------------------------------
# degradation ladder (pure host logic)
# ---------------------------------------------------------------------------


def test_degrade_entry_walks_payload_then_impl_then_engine():
    """int8 -> bf16 -> complex64, then pallas -> jnp, then pipelined ->
    fused -> traditional (chunks collapse to 1 with the engine), then the
    bottom (None)."""
    e = ("pipelined", 4, "int8", "pallas", "stacked")
    seen = []
    while e is not None:
        seen.append(tuple(e))
        e = degrade_entry(e)
    assert seen == [
        ("pipelined", 4, "int8", "pallas", "stacked"),
        ("pipelined", 4, "bf16", "pallas", "stacked"),
        ("pipelined", 4, "complex64", "pallas", "stacked"),
        ("pipelined", 4, "complex64", "jnp", "stacked"),
        ("fused", 1, "complex64", "jnp", "stacked"),
        ("traditional", 1, "complex64", "jnp", "stacked"),
    ]
    # legacy 4-tuple entries upgrade in place (jnp impl) and walk the
    # same ladder
    assert tuple(degrade_entry(("pipelined", 4, "int8", "stacked"))) == (
        "pipelined", 4, "bf16", "jnp", "stacked")


def test_degrade_schedule_targets_only_named_stages():
    sched = (("fused", 1, "int8", "stacked"), ("fused", 1, "int8", "stacked"))
    new = degrade_schedule(sched, stages=(1,))
    # untargeted entries pass through as-is; degraded ones come back as
    # full 5-field StageEntry rows
    assert new == (("fused", 1, "int8", "stacked"),
                   ("fused", 1, "bf16", "jnp", "stacked"))


def test_degrade_schedule_exhaustion():
    bottom = (("traditional", 1, "complex64", "stacked"),)
    assert degrade_schedule(bottom) is None
    mixed = (("traditional", 1, "complex64", "stacked"),
             ("fused", 1, "int8", "stacked"))
    # the targeted stage has no rung left -> exhausted, even though the
    # untargeted one does
    assert degrade_schedule(mixed, stages=(0,)) is None
    assert degrade_schedule(mixed) == (
        ("traditional", 1, "complex64", "stacked"),
        ("fused", 1, "bf16", "jnp", "stacked"))


def test_guard_mode_validated():
    from jax.sharding import Mesh

    from repro.core.pfft import ParallelFFT

    mesh = Mesh(np.array(jax.devices()[:1]), ("p0",))
    with pytest.raises(ValueError, match="unknown guard"):
        ParallelFFT(mesh, (4, 4), grid=("p0",), guard="paranoid")


# ---------------------------------------------------------------------------
# packed guard stats (the no-collective wire format)
# ---------------------------------------------------------------------------


def _shard_vec(e_in, e_out, probe, nf, sat):
    return health.pack_stats(
        [{"nonfinite": jnp.float32(a), "saturated": jnp.float32(b)}
         for a, b in zip(nf, sat)],
        jnp.float32(e_in), jnp.float32(e_out), jnp.float32(probe))


def test_pack_unpack_partials_sums_shards():
    raw = jnp.concatenate([_shard_vec(1.0, 2.0, 0.0, [3, 0], [0, 5]),
                           _shard_vec(0.5, 1.5, 0.0, [1, 2], [4, 0])])
    stats = health.unpack_partials(np.asarray(raw), nstages=2)
    assert stats["energy_in"] == pytest.approx(1.5)
    assert stats["energy_out"] == pytest.approx(3.5)
    np.testing.assert_allclose(stats["nonfinite"], [4, 2])
    np.testing.assert_allclose(stats["saturated"], [4, 5])


def test_unpack_partials_propagates_nonfinite():
    """A NaN probe on any one shard must survive the host-side sum."""
    a = np.array([0.0, 0.0, np.nan], np.float32)
    b = np.zeros(3, np.float32)
    stats = health.unpack_partials(np.concatenate([a, b]), nstages=0)
    assert math.isnan(stats["probe"])
    assert stats["energy_in"] == 0.0


def test_pack_stats_lossless_is_just_the_triple():
    raw = health.pack_stats([], jnp.float32(1), jnp.float32(2), jnp.float32(3))
    assert raw.shape == (3,)
    np.testing.assert_allclose(np.asarray(raw), [1, 2, 3])


def test_output_probe_flags_nonfinite():
    x = jnp.ones((4, 6), jnp.complex64)
    assert math.isfinite(float(health.output_probe(x, 1)))
    bad = x.at[2, 0].set(jnp.nan + 0j)  # sits on the index-0 plane of axis 1
    assert not math.isfinite(float(health.output_probe(bad, 1)))
    assert not math.isfinite(float(health.output_probe(bad, None)))
    imag_bad = x.at[1, 0].set(1.0 + 1j * jnp.inf)  # imaginary part counts too
    assert not math.isfinite(float(health.output_probe(imag_bad, 1)))


def test_block_energy_matches_numpy():
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((5, 7)) + 1j * rng.standard_normal((5, 7)))
    got = float(health.block_energy(jnp.asarray(x, jnp.complex64)))
    assert got == pytest.approx(float(np.sum(np.abs(x) ** 2)), rel=1e-5)
    r = rng.standard_normal(9).astype(np.float32)
    assert float(health.block_energy(jnp.asarray(r))) == pytest.approx(
        float(np.sum(r * r)), rel=1e-5)


def test_schedule_is_lossy():
    assert not health.schedule_is_lossy([("fused", 1, "complex64", "stacked")])
    assert health.schedule_is_lossy([("fused", 1, "complex64", "stacked"),
                                     ("pipelined", 2, "int8", "stacked")])


# ---------------------------------------------------------------------------
# fault harness (matching + unarmed no-op contract)
# ---------------------------------------------------------------------------


def test_fault_taps_are_noops_when_unarmed():
    x = jnp.arange(4.0)
    assert faults.tap_wire(x) is x
    assert faults.tap_stage_input(x) is x
    assert faults.scale_div() is None


def test_fault_matching_respects_context():
    fp = FaultPlan().corrupt_wire(stage=1, engine="fused", codec="bf16")
    with fp:
        with pytest.raises(RuntimeError, match="already active"):
            FaultPlan().__enter__()
        with faults.stage_context(0, "fused", "bf16"):
            assert faults._matching("corrupt_wire", "payload") == []
        with faults.stage_context(1, "pipelined", "bf16"):
            assert faults._matching("corrupt_wire", "payload") == []
        with faults.stage_context(1, "fused", "bf16"):
            assert len(faults._matching("corrupt_wire", "payload")) == 1
    assert faults._ACTIVE is None


def test_wire_burst_poisons_float_payloads():
    with FaultPlan().corrupt_wire():
        with faults.stage_context(0, "fused", "complex64"):
            y = faults.tap_wire(jnp.ones((3, 3), jnp.complex64))
            assert not bool(jnp.isfinite(jnp.real(y)).all())
            f = faults.tap_wire(jnp.ones(8, jnp.float32))
            assert not bool(jnp.isfinite(f).all())
            # int8 payloads get a bounded magnitude bit flip, never garbage
            q = faults.tap_wire(jnp.zeros(8, jnp.int8))
            assert int(np.abs(np.asarray(q)).max()) == 0x40


# ---------------------------------------------------------------------------
# report classification on a real plan (synthetic stats, subprocess mesh)
# ---------------------------------------------------------------------------

_REPORT_SCRIPT = """
import json, math
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.pfft import ParallelFFT
from repro.robustness import health

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("p0", "p1"))
plan = ParallelFFT(mesh, (16, 8, 8), grid=("p0", "p1"), method="fused")
S = plan.n_exchanges
N = math.prod(plan.shape)

def stats(e_in=1.0, e_out=None, probe=0.0, nonfinite=None, saturated=None):
    return {"energy_in": e_in,
            "energy_out": (N * e_in) if e_out is None else e_out,
            "probe": probe,
            "nonfinite": np.array(nonfinite if nonfinite else [0.0] * S),
            "saturated": np.array(saturated if saturated else [0.0] * S)}

def report(schedule, st):
    return health.build_report(plan, direction="forward", nfields=1,
                               schedule=schedule, stats=st, guard="strict")

lossless = tuple(("fused", 1, "complex64") for _ in range(S))
lossy = tuple(("fused", 1, "int8") for _ in range(S))
out = {"S": S}

r = report(lossless, stats())
out["clean_lossless"] = {"ok": r.ok, "energy_in": r.energy_in,
                         "rel_err": r.parseval_rel_err}
r = report(lossless, stats(probe=float("nan")))
out["probe_nan"] = {"tripped": list(r.tripped), "global": r.has_global_trip}
r = report(lossy, stats())
out["clean_lossy"] = {"ok": r.ok, "rel_err": r.parseval_rel_err,
                      "tol": r.parseval_tol, "energy_in": r.energy_in}
r = report(lossy, stats(e_out=1.0))
out["parseval"] = {"tripped": list(r.tripped)}
r = report(lossy, stats(e_in=float("nan"), e_out=float("nan")))
out["nan_energy"] = {"tripped": list(r.tripped)}
elems1 = r.stages[1].elems
r = report(lossy, stats(saturated=[0.0, 0.10 * elems1]))
out["saturation"] = {"tripped": list(r.tripped),
                     "idx": list(r.tripped_stage_indices()),
                     "global": r.has_global_trip,
                     "sat_fraction": r.stages[1].sat_fraction}
r = report(lossy, stats(nonfinite=[2.0, 0.0]))
out["stage_nonfinite"] = {"tripped": list(r.tripped),
                          "idx": list(r.tripped_stage_indices())}
print("REPORT=" + json.dumps(out))
"""


def test_build_report_classification(subproc):
    out = json.loads(subproc(_REPORT_SCRIPT).split("REPORT=")[1])
    assert out["S"] == 2
    c = out["clean_lossless"]
    # lossless schedules pay no energy bracket: probe-only, energies None
    assert c["ok"] and c["energy_in"] is None and c["rel_err"] is None
    p = out["probe_nan"]
    assert p["tripped"] == ["output:nonfinite"] and p["global"]
    cl = out["clean_lossy"]
    assert cl["ok"] and cl["energy_in"] == 1.0
    assert cl["rel_err"] == pytest.approx(0.0) and cl["tol"] >= 2 * 2e-1
    assert "parseval" in out["parseval"]["tripped"]
    assert {"input:nonfinite", "output:nonfinite"} <= set(
        out["nan_energy"]["tripped"])
    s = out["saturation"]
    assert s["tripped"] == ["stage1:saturation"] and s["idx"] == [1]
    # StageHealth stores integral counts, so the fraction floors slightly
    assert not s["global"] and s["sat_fraction"] == pytest.approx(0.10, rel=0.05)
    n = out["stage_nonfinite"]
    assert "stage0:nonfinite" in n["tripped"] and n["idx"] == [0]


# ---------------------------------------------------------------------------
# PLAN008: guard="off" artifacts carry zero guard eqns
# ---------------------------------------------------------------------------

_PLAN008_SCRIPT = """
import json
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.pfft import ParallelFFT
from repro.analysis import planlint

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("p0", "p1"))
def mk(guard):
    return ParallelFFT(mesh, (16, 8, 8), grid=("p0", "p1"), method="fused",
                       guard=guard)
a_off = planlint.audit_plan(mk("off"))
a_on = planlint.audit_plan(mk("strict"))
print("PLAN008=" + json.dumps({
    "off": {"ok": a_off.ok, "guard_eqns": a_off.observed["guard_eqns"],
            "codes": sorted({v.code for v in a_off.violations})},
    "on": {"ok": a_on.ok, "guard_eqns": a_on.observed["guard_eqns"],
           "codes": sorted({v.code for v in a_on.violations})},
}))
"""


def test_plan008_guard_presence(subproc):
    out = json.loads(subproc(_PLAN008_SCRIPT).split("PLAN008=")[1])
    # guard="off" compiles with zero robustness/-attributed eqns (the
    # bit-identical contract) and still satisfies every plan contract
    assert out["off"]["ok"], out["off"]["codes"]
    assert out["off"]["guard_eqns"] == 0
    # a guarded plan carries guard eqns yet keeps the same contracts
    # (no realignment pass, same collective count and wire bytes)
    assert out["on"]["ok"], out["on"]["codes"]
    assert out["on"]["guard_eqns"] > 0


# ---------------------------------------------------------------------------
# the fault matrix: every injector x {strict, degrade} (chaos CI job)
# ---------------------------------------------------------------------------

_MATRIX_SCRIPT = """
import json, pathlib, tempfile
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.pfft import ParallelFFT
from repro.robustness import FaultPlan
from repro.robustness.runner import GuardError

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("p0", "p1"))
SHAPE, GRID = (16, 8, 8), ("p0", "p1")
rng = np.random.default_rng(0)
x = jnp.asarray((rng.standard_normal(SHAPE)
                 + 1j * rng.standard_normal(SHAPE)).astype(np.complex64))
base = ParallelFFT(mesh, SHAPE, grid=GRID, method="fused")
y_ref = base.forward(x)
x_back_ref = base.backward(y_ref)

def plan(**kw):
    kw.setdefault("method", "fused")
    return ParallelFFT(mesh, SHAPE, grid=GRID, **kw)

def rel(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.linalg.norm(a - b) / np.linalg.norm(b))

def strict_case(fp, **kw):
    with fp:
        try:
            plan(guard="strict", **kw).forward(x)
            return {"raised": False}
        except GuardError as e:
            return {"raised": True,
                    "tripped": list(e.report.tripped) if e.report else []}

def degrade_case(fp, **kw):
    with fp:
        y, rep = plan(guard="degrade", **kw).forward(x)
    return {"ok": rep.ok, "kinds": [t["kind"] for t in rep.transitions],
            "attempts": rep.attempts,
            "schedule": [list(e) for e in rep.schedule],
            "rel": rel(y, y_ref)}

out = {}

y, rep = plan(guard="strict").forward(x)
out["clean_strict"] = {"ok": rep.ok, "energy_in": rep.energy_in,
                       "rel_err": rep.parseval_rel_err, "rel": rel(y, y_ref)}

y, rep = plan(guard="strict", comm_dtype="bf16").forward(x)
out["clean_bf16"] = {"ok": rep.ok, "rel_err": rep.parseval_rel_err,
                     "tol": rep.parseval_tol,
                     "has_energy": rep.energy_in is not None}

c64_burst = lambda: FaultPlan().corrupt_wire(engine="fused", codec="complex64")
out["wire_c64_strict"] = strict_case(c64_burst())
out["wire_c64_degrade"] = degrade_case(c64_burst())

nan_in = lambda: FaultPlan().nan_input(stage=0, engine="fused")
out["nan_input_strict"] = strict_case(nan_in())
out["nan_input_degrade"] = degrade_case(nan_in())

bf16_burst = lambda: FaultPlan().corrupt_wire(engine="fused", codec="bf16")
out["wire_bf16_strict"] = strict_case(bf16_burst(), comm_dtype="bf16")
out["wire_bf16_degrade"] = degrade_case(bf16_burst(), comm_dtype="bf16")

out["int8_scale_degrade"] = degrade_case(
    FaultPlan().corrupt_wire(engine="fused", codec="int8", label="scale"),
    comm_dtype="int8")

sat = lambda: FaultPlan().saturate(engine="fused")
out["saturate_strict"] = strict_case(sat(), comm_dtype="int8")
out["saturate_degrade"] = degrade_case(sat(), comm_dtype="int8")

with sat():
    xb, rep = plan(guard="degrade", comm_dtype="int8").backward(y_ref)
out["saturate_backward"] = {"ok": rep.ok, "direction": rep.direction,
                            "kinds": [t["kind"] for t in rep.transitions],
                            "rel": rel(xb, x_back_ref)}

cache = pathlib.Path(tempfile.mkdtemp()) / "tuner.json"
p = plan(method="auto", guard="degrade", tuner_cache=str(cache))
# must be inside the live candidate set or the entry is rejected as
# malformed (and simply retuned) instead of replayed and quarantined
poisoned = tuple(("pipelined", 2, "complex64") for _ in range(p.n_exchanges))
FaultPlan.poison_cache(cache, p, poisoned)
with FaultPlan().fail_compile(engine="pipelined"):
    y, rep = p.forward(x)
from repro.core import tuner as _tuner
disk = _tuner.load_cache(cache)
qcounts = [e.get("quarantines") for e in disk.values()
           if isinstance(e, dict) and e.get("quarantines")]
out["poison_auto"] = {"ok": rep.ok,
                      "kinds": [t["kind"] for t in rep.transitions],
                      "rel": rel(y, y_ref), "quarantines": qcounts,
                      "fired": sorted({f["kind"] for f in rep.fired_faults})}

with FaultPlan().nan_input():  # wildcard: no ladder rung escapes it
    try:
        plan(guard="degrade").forward(x)
        out["exhausted"] = {"raised": False}
    except GuardError:
        out["exhausted"] = {"raised": True}

xs = jnp.stack([x, 2 * x, x - 1])
ys, rep = plan(guard="strict").forward_many(xs)
out["batched_clean"] = {"ok": rep.ok, "nfields": rep.nfields,
                        "rel": rel(ys[1], 2 * np.asarray(y_ref))}

print("MATRIX=" + json.dumps(out))
"""


@pytest.mark.faults
def test_fault_matrix(subproc):
    """Every injector under strict (structured GuardError, never a silent
    bad spectrum) and degrade (ladder moves off the faulted config and the
    recovered result matches the healthy plan)."""
    out = json.loads(subproc(_MATRIX_SCRIPT).split("MATRIX=")[1])

    c = out["clean_strict"]
    assert c["ok"] and c["rel"] < 1e-5
    assert c["energy_in"] is None and c["rel_err"] is None

    b = out["clean_bf16"]
    assert b["ok"] and b["has_energy"] and b["rel_err"] < b["tol"]

    s = out["wire_c64_strict"]
    assert s["raised"] and "output:nonfinite" in s["tripped"]
    d = out["wire_c64_degrade"]
    assert d["ok"] and d["kinds"] and d["rel"] < 1e-5
    assert any(e[0] != "fused" for e in d["schedule"])  # engine rung moved

    assert out["nan_input_strict"]["raised"]
    d = out["nan_input_degrade"]
    assert d["ok"] and d["kinds"] and d["rel"] < 1e-5

    s = out["wire_bf16_strict"]
    assert s["raised"] and any("nonfinite" in t for t in s["tripped"])
    d = out["wire_bf16_degrade"]
    assert d["ok"] and d["kinds"] and d["rel"] < 1e-4
    assert any(e[2] == "complex64" for e in d["schedule"])  # payload widened

    d = out["int8_scale_degrade"]
    assert d["ok"] and d["kinds"] and d["rel"] < 0.05
    assert any(e[2] != "int8" for e in d["schedule"])

    s = out["saturate_strict"]
    assert s["raised"] and any("saturation" in t for t in s["tripped"])
    d = out["saturate_degrade"]
    assert d["ok"] and d["kinds"] and d["rel"] < 0.05

    d = out["saturate_backward"]
    assert d["ok"] and d["direction"] == "backward" and d["rel"] < 0.05

    d = out["poison_auto"]
    assert d["ok"] and "retune" in d["kinds"] and d["rel"] < 1e-4
    assert d["quarantines"] and "compile_fail" in d["fired"]

    assert out["exhausted"]["raised"]  # zero silent-corruption outcomes

    bc = out["batched_clean"]
    assert bc["ok"] and bc["nfields"] == 3 and bc["rel"] < 1e-5


# ---------------------------------------------------------------------------
# tuner cache: merge-on-save closes the concurrent-writer lost update
# ---------------------------------------------------------------------------


def _entry(method):
    return {"schedule": [[method, 1, "complex64"]], "timings": {}}


def test_save_cache_merge_keeps_concurrent_writer_keys(tmp_path):
    """The stale-read race: worker A read the cache before worker B wrote
    plan B's entry; A's delta write must overlay, not clobber."""
    path = tmp_path / "cache.json"
    assert tuner.save_cache(path, {"plan-b": _entry("traditional")})
    # A writes only its own key, computed against a pre-B view
    assert tuner.save_cache(path, {"plan-a": _entry("fused")})
    disk = tuner.load_cache(path)
    assert set(disk) == {"plan-a", "plan-b"}
    assert disk["plan-b"] == _entry("traditional")
    tuner.save_cache(path, {"only": _entry("fused")}, merge=False)
    assert set(tuner.load_cache(path)) == {"only"}


def test_quarantine_mark_survives_concurrent_merge(tmp_path):
    path = tmp_path / "cache.json"
    tuner.save_cache(path, {"plan-a": _entry("fused")})
    assert tuner.quarantine(path, "plan-a", "injected failure") == 1
    tuner.save_cache(path, {"plan-b": _entry("traditional")})
    disk = tuner.load_cache(path)
    assert disk["plan-a"]["bad"]["reason"] == "injected failure"
    assert "plan-b" in disk
    # the lifetime count keeps climbing toward the runner's retune cap
    assert tuner.quarantine(path, "plan-a", "again") == 2


def test_save_cache_atomic_never_partial(tmp_path):
    """Readers racing a save see either the old or the new file, never a
    truncated one — the write goes through a same-dir temp + os.replace."""
    path = tmp_path / "cache.json"
    tuner.save_cache(path, {f"k{i}": _entry("fused") for i in range(50)})
    assert json.loads(path.read_text())  # well-formed at rest
    assert not list(tmp_path.glob("*.tmp"))  # no temp droppings


# ---------------------------------------------------------------------------
# checkpoint fallback (the guarded-pipeline restart path)
# ---------------------------------------------------------------------------


def _ck_tree():
    return {"a": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(5, jnp.float32)}


def _corrupt_leaf(step_dir, key="a"):
    target = step_dir / f"{key}.npy"
    np.save(target, np.load(target) + 1)


def test_load_checkpoint_falls_back_past_corruption(tmp_path):
    t = _ck_tree()
    save_checkpoint(tmp_path, 1, t)
    t2 = {"a": t["a"] * 2, "b": t["b"] * 2}
    _corrupt_leaf(save_checkpoint(tmp_path, 2, t2))
    with pytest.warns(UserWarning, match="skipping corrupt checkpoint step 2"):
        out, manifest = load_checkpoint(tmp_path, t)
    assert manifest["step"] == 1
    assert [d["step"] for d in manifest["skipped_steps"]] == [2]
    assert "checksum" in manifest["skipped_steps"][0]["error"]
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
    # an explicit step= keeps the old fail-fast contract
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(tmp_path, t, step=2)


def test_load_checkpoint_all_corrupt_raises_with_detail(tmp_path):
    t = _ck_tree()
    for s in (1, 2):
        _corrupt_leaf(save_checkpoint(tmp_path, s, t))
    with pytest.warns(UserWarning):
        with pytest.raises(IOError, match="every checkpoint"):
            load_checkpoint(tmp_path, t)


# ---------------------------------------------------------------------------
# adversarial codec properties (hypothesis, or the _hyp fallback sweep)
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(log_scale=st.floats(-30, 30), n=st.integers(1, 64))
def test_int8_roundtrip_error_bounded(log_scale, n):
    """Round-trip error stays within half a quantization step per element
    across ~60 decades of input magnitude (denormal-adjacent to near-f32
    overflow)."""
    rng = np.random.default_rng(n)
    x = (rng.standard_normal((2, n)) * 10.0 ** log_scale).astype(np.float32)
    q, scale = quant.quantize_int8(jnp.asarray(x), block_axis=0)
    back = np.asarray(quant.dequantize_int8(q, scale))
    bound = np.broadcast_to(np.asarray(scale), x.shape) * 0.5
    assert np.all(np.abs(back - x) <= bound * 1.01 + 1e-38)


@settings(max_examples=16, deadline=None)
@given(nbad=st.integers(1, 5), kind=st.sampled_from(["nan", "inf", "-inf"]))
def test_int8_nonfinite_sanitized_and_counted(nbad, kind):
    """NaN/Inf inputs must not poison the block scale: bad elements
    quantize to 0, everything decodes finite, and the stats hook reports
    the exact count."""
    rng = np.random.default_rng(nbad)
    x = rng.standard_normal((3, 32)).astype(np.float32)
    bad = rng.choice(x.size, size=nbad, replace=False)
    x.reshape(-1)[bad] = {"nan": np.nan, "inf": np.inf, "-inf": -np.inf}[kind]
    q, scale, stats = quant.quantize_int8(jnp.asarray(x), block_axis=0,
                                          with_stats=True)
    assert np.all(np.isfinite(np.asarray(scale)))
    assert np.all(np.isfinite(np.asarray(quant.dequantize_int8(q, scale))))
    assert float(stats["nonfinite"]) == nbad
    np.testing.assert_array_equal(np.asarray(q).reshape(-1)[bad], 0)


def test_int8_all_zero_block():
    q, scale = quant.quantize_int8(jnp.zeros((2, 8)), block_axis=0)
    s = np.asarray(scale)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(s)) and np.all(s > 0)
    assert np.all(np.asarray(quant.dequantize_int8(q, scale)) == 0)


@settings(max_examples=12, deadline=None)
@given(ratio=st.floats(1.0, 1e8))
def test_int8_tuple_block_axis_isolates_field_scales(ratio):
    """Stacked fields of wildly different magnitude: per-(field, chunk)
    blocks mean the small field's error is set by its own max-abs, not the
    big field's (the batched-exchange payload contract)."""
    rng = np.random.default_rng(3)
    small = rng.standard_normal((4, 16)).astype(np.float32)
    big = (rng.standard_normal((4, 16)) * ratio).astype(np.float32)
    x = jnp.asarray(np.stack([small, big]))  # (field, chunk, n)
    q, scale = quant.quantize_int8(x, block_axis=(0, 1))
    back = np.asarray(quant.dequantize_int8(q, scale))
    err_small = float(np.max(np.abs(back[0] - small)))
    assert err_small <= float(np.abs(small).max()) / 127 * 0.5 * 1.01 + 1e-12


@settings(max_examples=12, deadline=None)
@given(log_scale=st.floats(-20, 20))
def test_bf16_roundtrip_relative_error(log_scale):
    rng = np.random.default_rng(7)
    x = (rng.standard_normal(256) * 10.0 ** log_scale).astype(np.float32)
    back = np.asarray(quant.decode_bf16(quant.encode_bf16(jnp.asarray(x))))
    # round-to-nearest-even on an 8-bit significand: rel err <= 2^-9 + slack
    assert np.all(np.abs(back - x) <= np.abs(x) * 2.0 ** -8 + 1e-38)


def test_complex_planes_roundtrip_exact():
    rng = np.random.default_rng(5)
    x = (rng.standard_normal((3, 4))
         + 1j * rng.standard_normal((3, 4))).astype(np.complex64)
    back = np.asarray(quant.planes_to_complex(
        quant.complex_to_planes(jnp.asarray(x))))
    np.testing.assert_array_equal(back, x)
