"""MoE dispatch correctness: EP all-to-all path vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.meshutil import make_mesh, set_mesh
from repro.models.config import MoEConfig
from repro.models.moe import moe_apply_a2a, moe_init, route


def dense_moe_oracle(p, x, cfg, _mlp_kind="swiglu"):
    """Every token through its top-k experts, no capacity limit."""
    N, D = x.reshape(-1, x.shape[-1]).shape
    xt = np.asarray(x, np.float32).reshape(N, D)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    idx = np.argsort(-probs, axis=-1)[:, :cfg.top_k]
    gates = np.take_along_axis(probs, idx, axis=-1)
    gates /= gates.sum(-1, keepdims=True)
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)

    def silu(a):
        return a / (1 + np.exp(-a))

    y = np.zeros((N, D), np.float32)
    for n in range(N):
        for j in range(cfg.top_k):
            e = idx[n, j]
            h = silu(xt[n] @ wg[e]) * (xt[n] @ wu[e])
            y[n] += gates[n, j] * (h @ wd[e])
    return y.reshape(x.shape)


@pytest.mark.parametrize("path", ["a2a", "local"])
def test_moe_matches_dense_oracle(path, subproc):
    subproc(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.meshutil import make_mesh, set_mesh
from repro.models.config import MoEConfig
from repro.models.moe import moe_apply_a2a, moe_apply_local, moe_init

mesh = make_mesh((1, 4), ("data", "model"))
cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = moe_init(key, 12, cfg, "swiglu", jnp.float32)
B, S, D = 2, 8, 12
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
fn = moe_apply_{'a2a' if path == 'a2a' else 'local'}
with set_mesh(mesh):
    y, aux, z = jax.jit(lambda p, x: fn(p, x, mesh, cfg=cfg, mlp_kind="swiglu",
                                        dp_axes=("data",), ep_axis="model"))(p, x)
assert np.isfinite(float(aux)) and np.isfinite(float(z))

# dense oracle (no drops at cf=8)
import sys
sys.path.insert(0, "tests")
xt = np.asarray(x, np.float32).reshape(-1, D)
logits = xt @ np.asarray(p["router"], np.float32)
probs = np.exp(logits - logits.max(-1, keepdims=True)); probs /= probs.sum(-1, keepdims=True)
idx = np.argsort(-probs, axis=-1)[:, :cfg.top_k]
gates = np.take_along_axis(probs, idx, axis=-1); gates /= gates.sum(-1, keepdims=True)
wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("w_gate", "w_up", "w_down"))
silu = lambda a: a / (1 + np.exp(-a))
want = np.zeros_like(xt)
for n in range(xt.shape[0]):
    for j in range(cfg.top_k):
        e = idx[n, j]
        want[n] += gates[n, j] * ((silu(xt[n] @ wg[e]) * (xt[n] @ wu[e])) @ wd[e])
np.testing.assert_allclose(np.asarray(y).reshape(-1, D), want, rtol=2e-3, atol=2e-3)
print("MOE {path} ORACLE OK")
""", ndev=4)


def test_route_properties():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (16, 8), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    gates, idx, aux, z = route(w, x, 3)
    assert gates.shape == (32, 3) and idx.shape == (32, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert bool(jnp.all(gates >= 0))
    assert bool(jnp.all((idx >= 0) & (idx < 8)))
    assert float(aux) >= 1.0 - 1e-5  # E * sum f_e P_e >= 1 (Cauchy-Schwarz-ish)


def test_capacity_dropping():
    """With capacity_factor -> tiny, outputs shrink but stay finite."""
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=0.1)
    p = moe_init(jax.random.PRNGKey(0), 8, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8), jnp.float32)
    with set_mesh(mesh):
        y, aux, z = moe_apply_a2a(p, x, mesh, cfg=cfg, mlp_kind="swiglu",
                                  dp_axes=("data",), ep_axis="model")
    assert bool(jnp.all(jnp.isfinite(y)))
