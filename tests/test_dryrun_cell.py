"""End-to-end dry-run coverage: lower+compile real cells on the production
mesh inside a 512-device subprocess, and validate the artifact schema."""

import json


def test_lower_cell_end_to_end(subproc):
    out = subproc("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_cell

# cheapest train cell and a decode cell (covers cache specs + donation)
rec, _ = lower_cell("seamless_m4t_medium", "decode_32k", multi_pod=False)
a = rec["acct"]
assert rec["chips"] == 256
assert a["flops_per_device"] > 0
assert a["hbm_bytes_per_device"] > 0
assert a["collectives_per_device"]["total"] > 0
assert a["unknown_trip_whiles"] == 0, a
print("CELL1", json.dumps({k: rec[k] for k in ("arch", "shape", "kind", "chips")}))

rec2, _ = lower_cell("seamless_m4t_medium", "train_4k", multi_pod=True)
assert rec2["chips"] == 512
assert rec2["acct"]["collectives_per_device"]["total"] > 0
print("CELL2 OK")
""", ndev=512, timeout=1200)
    assert "CELL2 OK" in out
    rec = json.loads(out.splitlines()[0].split("CELL1 ")[1])
    assert rec == {"arch": "seamless_m4t_medium", "shape": "decode_32k",
                   "kind": "decode", "chips": 256}
