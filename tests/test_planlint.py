"""planlint contract suite.

Positive side: the auditor certifies every engine x transform spec x wire
payload x batch fusion on slab and pencil meshes, agrees with the analytic
``comm_bytes_per_device``/``model_time_s`` models, and the fused engine
shows **zero** engine realignment ops (the paper's no-realignment
invariant, machine-checked).  Negative side: deliberately mis-claimed
schedules (a traditional plan claiming fused, a quantized plan claiming
lossless, ...) must each be caught with the right violation code.

Multi-device audits run in subprocesses (conftest.run_devices); the
srclint checks are pure AST and run in-process on fabricated sources.
"""

import json
from pathlib import Path

from repro.analysis.srclint import lint_paths

REPO = Path(__file__).resolve().parent.parent

_PRELUDE = """
import json
from repro.analysis.planlint import audit_plan
from repro.core.meshutil import make_mesh
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 2), ("p0", "p1"))
PENCIL, SLAB = ("p0", "p1"), ("p0",)

def codes(rep):
    return sorted({v.code for v in rep.violations})
"""


def test_audit_engines_specs_directions(subproc):
    """Every engine x {c2c, r2c, mixed} on the pencil mesh (plus a slab
    fused run) audits clean, forward and backward; fused shows zero engine
    realignment ops and traditional exactly its documented copies."""
    code = _PRELUDE + """
SPECS = {"c2c": None, "r2c": ("c2c", "c2c", "r2c"),
         "mixed": ("dct2", "c2c", "r2c")}
for method in ("fused", "traditional", "pipelined"):
    for sname, transforms in SPECS.items():
        plan = ParallelFFT(mesh, (8, 8, 8), PENCIL, method=method, chunks=2,
                           transforms=transforms)
        rep = audit_plan(plan, label=f"{method}/{sname}")
        assert rep.ok, (method, sname, codes(rep), rep.violations)
        if method == "fused":
            # the no-realignment invariant, observed in the artifact
            assert rep.observed["engine_transposes"] == 0
            assert rep.observed["engine_concats"] == 0
        elif method == "traditional":
            assert (rep.observed["engine_transposes"]
                    == rep.expected["engine_transposes"] > 0)
        else:  # pipelined: one launch per slice, slices reassembled
            assert (rep.observed["jaxpr_all_to_alls"]
                    == rep.expected["launches"] > plan.n_exchanges)
            assert rep.observed["engine_concats"] == rep.expected["engine_concats"]
        json.dumps(rep.to_dict(), default=str)  # report is serializable
        s = rep.summary()
        assert s["ok"] and s["violations"] == []
        assert s["wire_bytes"] == rep.expected["wire_bytes"]

# backward direction walks the reversed plan
for method in ("fused", "traditional"):
    plan = ParallelFFT(mesh, (8, 8, 8), PENCIL, method=method,
                       transforms=("dct2", "c2c", "r2c"))
    rep = audit_plan(plan, direction="backward")
    assert rep.ok, (method, codes(rep))
    if method == "fused":
        assert rep.observed["engine_transposes"] == 0

# slab decomposition: one exchange stage
slab = ParallelFFT(mesh, (8, 8, 8), SLAB, method="fused")
rep = audit_plan(slab)
assert rep.ok and slab.n_exchanges == 1
assert rep.observed["jaxpr_all_to_alls"] == 1

# check_hlo=False skips compilation but keeps the jaxpr-level invariants
rep = audit_plan(slab, check_hlo=False)
assert rep.ok and "hlo_all_to_alls" not in rep.observed
# the ParallelFFT.audit convenience wrapper returns the same report type
assert slab.audit().ok
print("ENGINES SPECS OK")
"""
    assert "ENGINES SPECS OK" in subproc(code, ndev=4)


def test_audit_wire_bytes_match_models(subproc):
    """For every engine x comm_dtype on slab and pencil 8^3, the audited
    HLO payload bytes equal the ``exchange_wire_bytes`` model (exactly for
    complex64/int8; at the flagged CPU f32 widening for bf16), and
    ``comm_bytes_per_device``/``model_time_s`` are consistent with it."""
    code = _PRELUDE + """
BW = 1e9
for grid in (PENCIL, SLAB):
    for method in ("fused", "traditional", "pipelined"):
        for cd in (None, "bf16", "int8"):
            plan = ParallelFFT(mesh, (8, 8, 8), grid, method=method,
                               chunks=2, comm_dtype=cd)
            rep = audit_plan(plan, label=f"{grid}/{method}/{cd}")
            assert rep.ok, (grid, method, cd, codes(rep), rep.violations)
            wire = rep.expected["wire_bytes"]
            assert wire == sum(rep.expected["payload_bytes"])
            assert wire == plan.comm_bytes_per_device()
            hlo = rep.observed["hlo_all_to_all_bytes"]
            if cd == "bf16":
                # single-host CPU XLA hoists the rounding convert across
                # the collective: exact widened multiset, and flagged
                assert rep.observed["backend_widened_wire"]
                assert hlo == sum(rep.expected["payload_bytes_widened"]) == 2 * wire
            else:
                assert hlo == wire, (grid, method, cd, hlo, wire)
            # time model lower-bounded by the audited wire term
            t = plan.model_time_s(ici_bw=BW, peak_flops=1e30, hbm_bw=1e30)
            assert t * BW >= 0.99 * wire, (grid, method, cd, t * BW, wire)
print("WIRE MODEL OK")
"""
    assert "WIRE MODEL OK" in subproc(code, ndev=4, timeout=1200)


def test_audit_batched_fusions(subproc):
    """nfields=3 under each batch fusion mode: stacked keeps one collective
    per exchange; per-field / pipelined-across-fields launch per field and
    restack with exactly one engine concatenate per stage."""
    code = _PRELUDE + """
for fusion in ("stacked", "per-field", "pipelined-across-fields"):
    plan = ParallelFFT(mesh, (8, 8, 8), PENCIL, method="fused",
                       batch_fusion=fusion)
    rep = audit_plan(plan, nfields=3, label=f"fused/{fusion}")
    assert rep.ok, (fusion, codes(rep), rep.violations)
    want = plan.n_exchanges if fusion == "stacked" else plan.n_exchanges * 3
    assert rep.observed["jaxpr_all_to_alls"] == want
    if fusion == "stacked":
        assert rep.observed["engine_concats"] == 0
    else:
        assert rep.observed["engine_concats"] == plan.n_exchanges

# traditional batched: per-field pack/unpack copies scale with nfields
plan = ParallelFFT(mesh, (8, 8, 8), PENCIL, method="traditional",
                   batch_fusion="per-field")
rep = audit_plan(plan, nfields=3)
assert rep.ok, (codes(rep), rep.violations)
assert rep.observed["engine_transposes"] == rep.expected["engine_transposes"] > 0

# batched backward + a narrowed batched payload
plan = ParallelFFT(mesh, (8, 8, 8), PENCIL, method="fused", comm_dtype="bf16")
for direction in ("forward", "backward"):
    rep = audit_plan(plan, nfields=3, direction=direction)
    assert rep.ok, (direction, codes(rep), rep.violations)
print("BATCHED OK")
"""
    assert "BATCHED OK" in subproc(code, ndev=4, timeout=1200)


def test_audit_negative_claims(subproc):
    """The auditor must reject artifacts whose claimed schedule lies: each
    mis-claim is caught with the violation code that names the lie."""
    code = _PRELUDE + """
SCHED_FUSED = (("fused", 1, "complex64"),) * 2
SCHED_BF16 = (("fused", 1, "bf16"),) * 2

# 1) traditional artifact claiming fused: realignment transposes appear
rep = audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL, method="traditional"),
                 schedule=SCHED_FUSED)
assert "PLAN003" in codes(rep), codes(rep)

# 2) pipelined artifact claiming fused: launch count betrays the slices
rep = audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL, method="pipelined",
                             chunks=2), schedule=SCHED_FUSED)
assert "PLAN001" in codes(rep), codes(rep)

# 3) lossless artifact claiming bf16: no quantize converts in the jaxpr
#    (the CPU widening acceptance must NOT let this one through)
rep = audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL, method="fused"),
                 schedule=SCHED_BF16)
assert "PLAN006" in codes(rep), codes(rep)

# 4) bf16 artifact claiming lossless: converts present but unclaimed
rep = audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL, method="fused",
                             comm_dtype="bf16"), schedule=SCHED_FUSED)
assert "PLAN006" in codes(rep), codes(rep)

# 5) int8 artifact claiming lossless: scale exchanges double the launch
#    count and the payload bytes shrink 4x
rep = audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL, method="fused",
                             comm_dtype="int8"), schedule=SCHED_FUSED)
got = set(codes(rep))
assert {"PLAN001", "PLAN006"} <= got, got
json.dumps(rep.to_dict(), default=str)  # failing reports serialize too

# 6) jnp artifact claiming the fused pallas kernels: zero kernel
#    launches in the artifact betray the claim
SCHED_PALLAS = (("fused", 1, "int8", "pallas"),) * 2
rep = audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL, method="fused",
                             comm_dtype="int8"), schedule=SCHED_PALLAS)
assert "PLAN009" in codes(rep), codes(rep)

# a claimed schedule with the wrong stage count is a usage error
try:
    audit_plan(ParallelFFT(mesh, (8, 8, 8), PENCIL),
               schedule=(("fused", 1, "complex64"),))
except ValueError as e:
    assert "exchange stages" in str(e)
else:
    raise AssertionError("wrong-length schedule not rejected")
print("NEGATIVE CLAIMS OK")
"""
    assert "NEGATIVE CLAIMS OK" in subproc(code, ndev=4, timeout=1200)


def test_audit_pallas_impl(subproc):
    """An ``exchange_impl="pallas"`` plan audits clean: the expected number
    of fused-kernel launches appear attributed to kernels/exchange/, and no
    codec eqns leak outside them (PLAN009 both ways)."""
    code = _PRELUDE + """
from repro.core.planconfig import PlanConfig

for method, cd in (("fused", "int8"), ("traditional", "bf16"),
                   ("pipelined", "int8")):
    plan = ParallelFFT(mesh, (8, 8, 8), PENCIL,
                       config=PlanConfig(method=method, chunks=2,
                                         comm_dtype=cd,
                                         exchange_impl="pallas"))
    rep = audit_plan(plan, label=f"pallas/{method}/{cd}")
    assert rep.ok, (method, cd, codes(rep), rep.violations)
    assert (rep.observed["exchange_pallas_calls"]
            == rep.expected["pallas_calls"] > 0)
    # codec math must live inside the kernels, not core/quant.py
    assert rep.observed["quant_eqns"] == 0

# a lossless pallas config is a no-op: jnp reference path, zero launches
plan = ParallelFFT(mesh, (8, 8, 8), PENCIL,
                   config=PlanConfig(method="fused", exchange_impl="pallas"))
rep = audit_plan(plan)
assert rep.ok and rep.observed["exchange_pallas_calls"] == 0
print("PALLAS IMPL OK")
"""
    assert "PALLAS IMPL OK" in subproc(code, ndev=4, timeout=1200)


def test_audit_auto_schedule_and_cli(subproc, tmp_path):
    """A tuned (method="auto") plan audits clean against its own resolved
    per-stage schedule, and the ``python -m repro.analysis.planlint`` CLI
    writes a JSON report with the documented shape and exits 0."""
    cache = tmp_path / "fft_tuner.json"
    report = tmp_path / "plan_audit.json"
    code = _PRELUDE + f"""
cache = {str(cache)!r}
plan = ParallelFFT(mesh, (8, 8, 8), PENCIL, method="auto", comm_dtype="bf16",
                   tuner_cache=cache)
sched = plan.schedule  # resolves via the tuner sweep
rep = audit_plan(plan, label="auto")
assert rep.ok, (sched, codes(rep), rep.violations)
assert [tuple(e) for e in rep.schedule] == [tuple(s) for s in sched]

from repro.analysis import planlint
rc = planlint.main(["--out", {str(report)!r}, "--only", "poisson"])
assert rc == 0, rc
payload = json.loads(open({str(report)!r}).read())
assert payload["ok"] is True
assert set(payload["plans"]) == {{"poisson"}}
pr = payload["plans"]["poisson"]
assert pr["ok"] and pr["violations"] == []
assert pr["observed"]["engine_transposes"] == 0  # fused example: invariant
assert isinstance(payload["srclint"], list)
print("AUTO AND CLI OK")
"""
    assert "AUTO AND CLI OK" in subproc(code, ndev=4, timeout=1200)


# ---------------------------------------------------------------------------
# srclint: pure-AST unit tests on fabricated sources (no jax, no subprocess)
# ---------------------------------------------------------------------------


def _lint(tmp_path, **files):
    for name, src in files.items():
        (tmp_path / name).write_text(src)
    return lint_paths([str(tmp_path)])


def test_srclint_collective_reachability(tmp_path):
    """A collective in a helper reached from a shard_map body is fine; the
    same collective in an orphan function is SRC101."""
    findings = _lint(tmp_path, **{"mod.py": """
from jax import lax
from jax.experimental.shard_map import shard_map

def helper(x):
    return lax.psum(x, "p0")

def mapped(x):
    return helper(x)

def build(mesh):
    return shard_map(mapped, mesh=mesh, in_specs=(None,), out_specs=None)

def orphan(x):
    return lax.all_gather(x, "p0")
"""})
    assert [f.code for f in findings] == ["SRC101"]
    assert "all_gather" in findings[0].message and "orphan" in findings[0].message


def test_srclint_alias_import_reaches_across_files(tmp_path):
    """Reachability follows ``from m import f as g`` aliases project-wide
    (the false positive that bit repro.core.meshutil.axis_size)."""
    findings = _lint(tmp_path, **{
        "a.py": """
from jax import lax

def axis_size(mesh, name):
    return lax.psum(1, name)
""",
        "b.py": """
from a import axis_size as _mesh_axis_size
from jax.experimental.shard_map import shard_map

def body(x):
    return _mesh_axis_size(None, "p0") * x

def build(mesh):
    return shard_map(body, mesh=mesh, in_specs=(None,), out_specs=None)
"""})
    assert findings == []


def test_srclint_undeclared_axis_name(tmp_path):
    """An axis literal outside every declared mesh axis tuple is SRC102 —
    but only when the tree declares literal axis names at all."""
    findings = _lint(tmp_path, **{"mod.py": """
from jax import lax
from jax.sharding import Mesh
from jax.experimental.shard_map import shard_map

def body(x):
    return lax.psum(x, "rows")

def build(devices):
    mesh = Mesh(devices, ("p0", "p1"))
    return shard_map(body, mesh=mesh, in_specs=(None,), out_specs=None)
"""})
    assert [f.code for f in findings] == ["SRC102"]
    assert "'rows'" in findings[0].message
    # no mesh ctor in the tree: axis names may flow in as parameters, skip
    sub = tmp_path / "sub2"
    sub.mkdir()
    findings = _lint(sub, **{"mod.py": """
from jax import lax
from jax.experimental.shard_map import shard_map

def body(x):
    return lax.psum(x, "rows")

def build(mesh):
    return shard_map(body, mesh=mesh, in_specs=(None,), out_specs=None)
"""})
    assert findings == []


def test_srclint_in_specs_arity(tmp_path):
    """in_specs tuple length outside the mapped function's positional arity
    range is SRC103; defaulted params widen the accepted range."""
    findings = _lint(tmp_path, **{"mod.py": """
def body2(a, b):
    return a

def body_opt(a, b=None):
    return a

def build(mesh):
    shard_map(body2, mesh=mesh, in_specs=(None,), out_specs=None)
    shard_map(body_opt, mesh=mesh, in_specs=(None,), out_specs=None)
    shard_map(body_opt, mesh=mesh, in_specs=(None, None), out_specs=None)
"""})
    assert [f.code for f in findings] == ["SRC103"]
    assert "body2" in findings[0].message


def test_srclint_cache_key_hazards(tmp_path):
    findings = _lint(tmp_path, **{"mod.py": """
import json

def make_key(d):
    return json.dumps(d)

def make_key_sorted(d):
    return json.dumps(d, sort_keys=True)

def lookup(cache):
    return cache[{"a": 1}]
"""})
    assert [f.code for f in findings] == ["SRC104", "SRC104"]
    assert any("sort_keys" in f.message for f in findings)
    assert any("unhashable" in f.message for f in findings)


def test_srclint_unparseable_file(tmp_path):
    findings = _lint(tmp_path, **{"bad.py": "def broken(:\n"})
    assert [f.code for f in findings] == ["SRC100"]
    json.dumps([f.to_dict() for f in findings])


def test_srclint_repo_src_is_clean():
    """The repo's own src/ tree must stay lint-clean (CI runs the same
    check through the planlint CLI)."""
    assert lint_paths([str(REPO / "src")]) == []
