"""Benchmark driver: one entry per paper table/figure + the roofline tables.

  python -m benchmarks.run            # everything, container-scaled
  python -m benchmarks.run figs       # only wall-time figure benches (6-9,11)
  python -m benchmarks.run roofline   # only LM roofline tables (needs dry-run)
  python -m benchmarks.run fft        # only production FFT roofline (10/11)

REPRO_BENCH_SCALE=paper switches to the paper's global sizes (hours).
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _sub(mod, *args, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + str(REPO)
    if extra_env:
        env.update(extra_env)
    print(f"\n===== {mod} {' '.join(args)} =====", flush=True)
    r = subprocess.run([sys.executable, "-m", mod, *args], env=env, cwd=REPO)
    if r.returncode != 0:
        raise SystemExit(f"{mod} failed rc={r.returncode}")


def main(argv=None):
    which = set((argv if argv is not None else sys.argv[1:]) or
                ["figs", "fft", "roofline"])
    if "figs" in which:
        _sub("benchmarks.paperfigs")
    if "fft" in which:
        _sub("benchmarks.fft_roofline")
    if "roofline" in which:
        art = REPO / "benchmarks" / "artifacts" / "dryrun"
        if not any(art.glob("*single.json")):
            print("(dry-run artifacts missing; generating single-pod set — slow)")
            _sub("repro.launch.dryrun", "--all", "--mesh", "single")
        _sub("benchmarks.roofline", "single")
        if any(art.glob("*multi.json")):
            _sub("benchmarks.roofline", "multi")
    print("\nBENCHMARKS COMPLETE")


if __name__ == "__main__":
    main()
