"""Serve-path benchmark: coalesced batched dispatch vs a per-request loop.

The serving engine's throughput claim is that coalescing N concurrent
same-shape requests into one ``forward_many`` invocation (one collective
per exchange stage for the whole group, one trace/dispatch instead of N)
beats dispatching the same N requests one at a time.  This script measures
exactly that on the clean path: the *same* :class:`SpectralServer`, same
plan, same request stream — once with ``max_batch=N`` (coalesced) and once
with ``max_batch=1`` (per-request loop) — reporting best-of-``--repeats``
wall time from first submit to last resolved future (the paper's
fastest-of-outers convention).

Writes a ``serve-bench-v1`` record (git SHA + device provenance stamped):

    python -m benchmarks.servebench --ndev 8 --shape 32,32,32 \
        --requests 6 --out benchmarks/BENCH_pr9.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _measure(srv, xs, *, deadline_s: float):
    t0 = time.perf_counter()
    futs = [srv.submit(x, deadline_s=deadline_s) for x in xs]
    outs = [f.result(grace=5.0) for f in futs]
    dt = time.perf_counter() - t0
    bad = [o.status for o in outs if o.status != "ok"]
    if bad:
        raise RuntimeError(f"clean-path bench saw non-ok outcomes: {bad}")
    return dt, outs


def bench(shape, grid, requests, repeats, deadline_s):
    import numpy as np

    from repro.core.meshutil import balanced_dims, make_mesh
    from repro.core.planconfig import PlanConfig
    from repro.serve import ServeConfig, SpectralServer

    import jax

    ndev = len(jax.devices())
    if grid == "slab":
        mesh, mgrid = make_mesh((ndev,), ("p0",)), ("p0",)
    else:
        mesh = make_mesh(balanced_dims(ndev), ("p0", "p1"))
        mgrid = ("p0", "p1")
    pc = PlanConfig(method="fused", guard="degrade")
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(shape).astype(np.float32)
          for _ in range(requests)]

    results = {}
    for label, max_batch in (("coalesced", requests), ("per_request", 1)):
        sc = ServeConfig(deadline_s=deadline_s, max_batch=max_batch,
                         max_queue=4 * requests)
        with SpectralServer(mesh, mgrid, plan_config=pc, config=sc) as srv:
            _measure(srv, xs, deadline_s=deadline_s)  # warm compile both paths
            best, batched = None, 0
            for _ in range(repeats):
                dt, outs = _measure(srv, xs, deadline_s=deadline_s)
                if best is None or dt < best:
                    best = dt
                    batched = max(o.batched for o in outs)
            stats = srv.stats()
        results[label] = {
            "best_wall_s": best,
            "req_per_s": requests / best,
            "max_group": batched,
            "coalesced_batches": stats["coalesced_batches"],
        }
    return ndev, results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", default="32,32,32")
    ap.add_argument("--grid", choices=["slab", "pencil"], default="slab")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--deadline", type=float, default=300.0)
    ap.add_argument("--ndev", type=int, default=8,
                    help="virtual host devices (sets XLA_FLAGS if unset)")
    ap.add_argument("--pr", type=int, default=9)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.ndev}")

    shape = tuple(int(s) for s in args.shape.split(","))
    ndev, results = bench(shape, args.grid, args.requests, args.repeats,
                          args.deadline)

    import jax

    from benchmarks.normalize_bench import git_sha

    speedup = (results["per_request"]["best_wall_s"]
               / results["coalesced"]["best_wall_s"])
    record = {
        "schema": "serve-bench-v1",
        "pr": args.pr,
        "git_sha": git_sha(),
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "ndev": ndev,
        "shape": list(shape),
        "grid": args.grid,
        "requests": args.requests,
        "repeats": args.repeats,
        "guard_mode": "degrade",
        "coalesced": results["coalesced"],
        "per_request": results["per_request"],
        "coalesced_speedup": speedup,
    }
    blob = json.dumps(record, indent=1, sort_keys=True)
    print(blob)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
    # acceptance: coalesced batched throughput >= the per-request loop
    if speedup < 1.0:
        print(f"WARNING: coalesced path slower than per-request loop "
              f"(speedup {speedup:.3f})", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
