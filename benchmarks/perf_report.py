"""§Perf comparison report: baseline vs flagged variants per cell.

Reads dry-run artifacts and prints, for every (arch, shape) with variants,
the three roofline terms per flag set and the delta vs baseline — the
measured half of the hypothesis→change→measure log in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ART = REPO / "benchmarks" / "artifacts" / "dryrun"
PEAK, HBM, ICI = 197e12, 819e9, 50e9


def terms(rec):
    a = rec["acct"]
    return {
        "compute_s": a["flops_per_device"] / PEAK,
        "memory_s": a["hbm_bytes_per_device"] / HBM,
        "collective_s": a["collectives_per_device"].get("total", 0.0) / ICI,
    }


def main():
    cells: dict[tuple, dict[str, dict]] = {}
    for path in sorted(ART.glob("*__single*.json")):
        rec = json.loads(path.read_text())
        key = (rec["arch"], rec["shape"])
        variant = rec.get("flags") or ("opt" if rec.get("opt") else "baseline")
        if rec.get("sp_mode", "none") != "none":
            variant = rec["sp_mode"]
        cells.setdefault(key, {})[variant or "baseline"] = rec

    rows = []
    for (arch, shape), variants in sorted(cells.items()):
        if len(variants) < 2 or "baseline" not in variants:
            continue
        base = terms(variants["baseline"])
        print(f"\n## {arch} x {shape}")
        print("| variant | compute s | memory s | collective s | Δcompute | Δmemory | Δcollective |")
        print("|---|---|---|---|---|---|---|")
        print(f"| baseline | {base['compute_s']:.3e} | {base['memory_s']:.3e} "
              f"| {base['collective_s']:.3e} | — | — | — |")
        for name, rec in sorted(variants.items()):
            if name == "baseline":
                continue
            t = terms(rec)
            deltas = {k: (t[k] / base[k] - 1.0) * 100 if base[k] else 0.0
                      for k in t}
            print(f"| {name} | {t['compute_s']:.3e} | {t['memory_s']:.3e} "
                  f"| {t['collective_s']:.3e} | {deltas['compute_s']:+.1f}% "
                  f"| {deltas['memory_s']:+.1f}% | {deltas['collective_s']:+.1f}% |")
            rows.append({"arch": arch, "shape": shape, "variant": name,
                         **t, "base": base})
    out = REPO / "benchmarks" / "artifacts" / "perf_report.json"
    out.write_text(json.dumps(rows, indent=1, default=str))
    print(f"\n-> {out}")


if __name__ == "__main__":
    main()
