"""One benchmark per paper table/figure (Figs. 6-11), container-scaled.

The paper measures wall-time on a Cray XC40 up to 4096 cores; this container
has one CPU core exposing N virtual XLA host devices.  What IS meaningful
here and what we report:

* fused-vs-traditional *relative* cost at fixed device count (the paper's
  core claim) — the traditional path pays a real, measurable local
  transpose on every exchange;
* scaling *structure* (communication volume per device, redistribution
  count) via the analytic model attached to every point;
* absolute wall-times are single-core multi-threaded and are labelled as
  such (they must NOT be read as distributed scaling).

Figs 10-11 at production scale are dry-run/roofline artifacts, produced by
``benchmarks.fft_roofline`` on the 16x16 (and 2x16x16) mesh.

Output: CSV rows ``fig,series,ndev,time_s,...`` to stdout and
``benchmarks/artifacts/figs/*.json``.

:func:`render_scaling_figures` (used by ``benchmarks.scalebench
--figures``) renders a bench-v3 record into the paper-style figures:
log-log time-vs-devices strong/weak scaling charts (measured solid,
fitted model dashed, ideal-scaling guide) and a redistribution-vs-compute
split bar chart, saved as SVG+PNG.  Needs matplotlib, which the CI
container ships but requirements.txt deliberately omits — the import is
guarded so the core package never depends on it.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ART = REPO / "benchmarks" / "artifacts" / "figs"

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
# container-scaled stand-ins for the paper's global sizes
SIZES = {
    "small": {
        "fig6_shape": (72, 72, 72),       # paper: 700^3 slab strong
        "fig7_shape": (64, 64, 64),       # paper: 512^3 pencil strong
        "weak_local": (32, 32, 32),       # paper: 64^2*128 per core
        "fig11_shape": (16, 16, 16, 16),  # paper: 128^4, 3-D grid
        "devs": (1, 2, 4, 8),
        "outer": 5,
    },
    "paper": {
        "fig6_shape": (700, 700, 700),
        "fig7_shape": (512, 512, 512),
        "weak_local": (64, 64, 128),
        "fig11_shape": (128, 128, 128, 128),
        "devs": (1, 2, 4, 8, 16, 32),
        "outer": 50,
    },
}[SCALE]


def run_point(shape, grid, method, ndev, *, real=True, measure="total",
              outer=None, inner=3):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + str(REPO)
    cmd = [sys.executable, "-m", "benchmarks.fftbench",
           "--shape", ",".join(map(str, shape)), "--grid", grid,
           "--method", method, "--measure", measure,
           "--inner", str(inner), "--outer", str(outer or SIZES["outer"])]
    if real:
        cmd.append("--real")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"bench point failed: {cmd}\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _sweep(fig, shape_fn, grid, devs, *, methods=("fused", "traditional"),
           measures=("total", "redistribution")):
    rows = []
    for ndev in devs:
        for method in methods:
            for measure in measures:
                if ndev == 1 and measure == "redistribution":
                    continue
                r = run_point(shape_fn(ndev), grid, method, ndev, measure=measure)
                r["fig"] = fig
                rows.append(r)
                print(f"{fig},{method},{measure},ndev={ndev},"
                      f"shape={r['shape']},t={r['best_s']:.4f}s", flush=True)
    return rows


def fig6_slab_strong():
    shape = SIZES["fig6_shape"]
    return _sweep("fig6", lambda n: shape, "slab", SIZES["devs"])


def fig7_pencil_strong():
    shape = SIZES["fig7_shape"]
    devs = [d for d in SIZES["devs"] if d >= 2]
    return _sweep("fig7", lambda n: shape, "pencil", devs)


def fig8_slab_weak():
    lx, ly, lz = SIZES["weak_local"]
    return _sweep("fig8", lambda n: (lx * n, ly, lz), "slab", SIZES["devs"])


def fig9_pencil_weak():
    lx, ly, lz = SIZES["weak_local"]
    devs = [d for d in SIZES["devs"] if d >= 2]
    return _sweep("fig9", lambda n: (lx * n, ly, lz), "pencil", devs)


def fig11_fft4d():
    shape = SIZES["fig11_shape"]
    devs = [d for d in SIZES["devs"] if d >= 8]
    return _sweep("fig11", lambda n: shape, "grid3", devs or [8],
                  measures=("total",))


ALL = {
    "fig6": fig6_slab_strong,
    "fig7": fig7_pencil_strong,
    "fig8": fig8_slab_weak,
    "fig9": fig9_pencil_weak,
    "fig11": fig11_fft4d,
}


# ---------------------------------------------------------------------------
# bench-v3 figure rendering (scalebench --figures)
#
# Categorical palette in fixed slot order (validated set: adjacent-pair
# CVD dE >= 8 and normal-vision dE >= 15 on the light surface); chart
# chrome stays in the neutral ink/grid tokens so text never wears a
# series color.
_PALETTE = ["#2a78d6", "#eb6834", "#1baf7a", "#eda100",
            "#e87ba4", "#008300", "#4a3aa7", "#e34948"]
_SURFACE, _INK, _INK2 = "#fcfcfb", "#0b0b0b", "#52514e"
_MUTED, _GRIDLINE, _AXISLINE = "#898781", "#e1e0d9", "#c3c2b7"


def _mpl():
    try:
        import matplotlib
    except ImportError as e:  # requirements.txt omits matplotlib on purpose
        raise ImportError(
            "render_scaling_figures needs matplotlib (present in the CI "
            "image, intentionally not in requirements.txt); install it or "
            "drop --figures") from e
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
    return plt


def _style_axes(ax):
    ax.set_facecolor(_SURFACE)
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("left", "bottom"):
        ax.spines[side].set_color(_AXISLINE)
    ax.grid(True, which="major", color=_GRIDLINE, linewidth=0.8)
    ax.set_axisbelow(True)
    ax.tick_params(colors=_MUTED, labelsize=8, labelcolor=_INK2)


def _series_label(s: dict) -> str:
    shape = "x".join(map(str, s.get("base_shape") or ()))
    if s.get("mode") == "weak":
        shape += "/dev"
    label = f"{s.get('method')} {shape}"
    if (s.get("comm_dtype") or "complex64") != "complex64":
        label += f" {s['comm_dtype']}"
    if (s.get("exchange_impl") or "jnp") != "jnp":
        label += f" {s['exchange_impl']}"
    if (s.get("fields") or 1) > 1:
        label += f" {s['fields']}-field"
    return label


def _tint(hex_color: str, frac: float = 0.72) -> tuple:
    """Lighter step of the same hue (mix toward the surface) for the
    compute segment of the split bars — tone-on-tone, not a new hue."""
    r, g, b = (int(hex_color[i:i + 2], 16) / 255 for i in (1, 3, 5))
    return tuple(c + (1.0 - c) * frac for c in (r, g, b))


def _save(fig, outdir: Path, stem: str) -> list[Path]:
    paths = []
    for ext in ("svg", "png"):
        p = outdir / f"{stem}.{ext}"
        fig.savefig(p, dpi=160, facecolor=_SURFACE, bbox_inches="tight")
        paths.append(p)
    return paths


def _scaling_figure(plt, mode: str, grid: str, items: list) -> "object":
    from matplotlib.lines import Line2D

    fig, ax = plt.subplots(figsize=(6.4, 4.2))
    _style_axes(ax)
    ndevs = sorted({p["ndev"] for _, s in items for p in s["points"]})
    anchor = None  # (ndev, time) anchoring the ideal-scaling guide
    for slot, (_, s) in enumerate(items):
        color = _PALETTE[slot]
        pts = sorted(s["points"], key=lambda p: p["ndev"])
        xs = [p["ndev"] for p in pts]
        ys = [p["best_s"] for p in pts]
        ax.plot(xs, ys, color=color, marker="o", markersize=6,
                linewidth=2, label=_series_label(s))
        if anchor is None:
            anchor = (xs[0], ys[0])
        fit = [p.get("fit_time_s") for p in pts]
        if all(f is not None for f in fit):
            ax.plot(xs, fit, color=color, linewidth=1.4,
                    linestyle="--", alpha=0.9)
    if anchor:
        n0, t0 = anchor
        # strong scaling: ideal is t0 * n0/n; weak: flat per-device time
        ideal = [t0 * n0 / n if mode == "strong" else t0 for n in ndevs]
        ax.plot(ndevs, ideal, color=_MUTED, linewidth=1.2, linestyle=":")
    ax.set_xscale("log", base=2)
    ax.set_yscale("log")
    ax.set_xticks(ndevs, [str(n) for n in ndevs])
    ax.minorticks_off()
    ax.set_xlabel("devices", color=_INK2, fontsize=9)
    ax.set_ylabel("wall time per transform (s)", color=_INK2, fontsize=9)
    ax.set_title(f"{mode} scaling — {grid} decomposition",
                 color=_INK, fontsize=11, loc="left")
    handles, labels = ax.get_legend_handles_labels()
    handles += [Line2D([], [], color=_INK2, linestyle="--", linewidth=1.4),
                Line2D([], [], color=_MUTED, linestyle=":", linewidth=1.2)]
    labels += ["model fit", "ideal"]
    ax.legend(handles, labels, frameon=False, fontsize=8,
              labelcolor=_INK2, loc="best")
    return fig


def _redist_figure(plt, grid: str, items: list) -> "object":
    fig, ax = plt.subplots(figsize=(6.4, 4.2))
    _style_axes(ax)
    ax.grid(True, axis="y", color=_GRIDLINE, linewidth=0.8)
    ax.grid(False, axis="x")
    ndevs = sorted({p["ndev"] for _, s in items
                    for p in s["redist"]["points"]})
    width = 0.8 / max(1, len(items))
    for slot, (_, s) in enumerate(items):
        color = _PALETTE[slot]
        total = {p["ndev"]: p["best_s"] for p in s["points"]}
        redist = {p["ndev"]: p["best_s"] for p in s["redist"]["points"]}
        xs, ex, comp = [], [], []
        for i, n in enumerate(ndevs):
            if n not in redist:
                continue
            xs.append(i + (slot - (len(items) - 1) / 2) * width)
            ex.append(redist[n])
            comp.append(max(0.0, total.get(n, redist[n]) - redist[n]))
        label = _series_label(s)
        # 2px surface gap between stacked segments and adjacent bars
        bar_kw = {"width": width * 0.92, "edgecolor": _SURFACE,
                  "linewidth": 1.5}
        ax.bar(xs, ex, color=color, label=f"{label} — redistribution",
               **bar_kw)
        ax.bar(xs, comp, bottom=ex, color=_tint(color),
               label=f"{label} — compute", **bar_kw)
    ax.set_xticks(range(len(ndevs)), [str(n) for n in ndevs])
    ax.set_xlabel("devices", color=_INK2, fontsize=9)
    ax.set_ylabel("wall time (s)", color=_INK2, fontsize=9)
    ax.set_title(f"redistribution vs compute — {grid} decomposition",
                 color=_INK, fontsize=11, loc="left")
    ax.legend(frameon=False, fontsize=8, labelcolor=_INK2, loc="best")
    return fig


def render_scaling_figures(bench: dict, outdir: str | Path) -> list[Path]:
    """Render a bench-v3 record (``normalize_bench.normalize_scaling``)
    into paper-style scaling + redistribution-split figures; returns the
    written paths (SVG and PNG per figure)."""
    plt = _mpl()
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    groups: dict[tuple, list] = {}
    splits: dict[str, list] = {}
    for name in sorted(bench.get("series") or {}):
        s = bench["series"][name]
        if s.get("points"):
            groups.setdefault((s.get("mode"), s.get("grid")), []).append(
                (name, s))
        if s.get("redist", {}).get("points"):
            splits.setdefault(s.get("grid"), []).append((name, s))

    paths = []
    for (mode, grid), items in sorted(groups.items()):
        # hues are assigned by slot order within a figure; past the
        # validated eight, fold the tail into one figure-level overflow
        items = items[:len(_PALETTE)]
        fig = _scaling_figure(plt, mode, grid, items)
        paths += _save(fig, outdir, f"scaling_{mode}_{grid}")
        plt.close(fig)
    for grid, items in sorted(splits.items()):
        items = items[:len(_PALETTE) // 2]
        fig = _redist_figure(plt, grid, items)
        paths += _save(fig, outdir, f"redistribution_split_{grid}")
        plt.close(fig)
    return paths


def main(which=None):
    ART.mkdir(parents=True, exist_ok=True)
    names = which or list(ALL)
    for name in names:
        rows = ALL[name]()
        (ART / f"{name}.json").write_text(json.dumps(rows, indent=1))
        # paper-claim check: fused redistribution <= traditional (per ndev)
        summary = {}
        for r in rows:
            if r["measure"] != "redistribution":
                continue
            key = r["ndev"]
            summary.setdefault(key, {})[r["method"]] = r["best_s"]
        for ndev, d in sorted(summary.items()):
            if {"fused", "traditional"} <= set(d):
                ratio = d["traditional"] / d["fused"]
                print(f"{name}: ndev={ndev} redistribution "
                      f"traditional/fused = {ratio:.2f}x", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:] or None)
