"""One benchmark per paper table/figure (Figs. 6-11), container-scaled.

The paper measures wall-time on a Cray XC40 up to 4096 cores; this container
has one CPU core exposing N virtual XLA host devices.  What IS meaningful
here and what we report:

* fused-vs-traditional *relative* cost at fixed device count (the paper's
  core claim) — the traditional path pays a real, measurable local
  transpose on every exchange;
* scaling *structure* (communication volume per device, redistribution
  count) via the analytic model attached to every point;
* absolute wall-times are single-core multi-threaded and are labelled as
  such (they must NOT be read as distributed scaling).

Figs 10-11 at production scale are dry-run/roofline artifacts, produced by
``benchmarks.fft_roofline`` on the 16x16 (and 2x16x16) mesh.

Output: CSV rows ``fig,series,ndev,time_s,...`` to stdout and
``benchmarks/artifacts/figs/*.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ART = REPO / "benchmarks" / "artifacts" / "figs"

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
# container-scaled stand-ins for the paper's global sizes
SIZES = {
    "small": {
        "fig6_shape": (72, 72, 72),       # paper: 700^3 slab strong
        "fig7_shape": (64, 64, 64),       # paper: 512^3 pencil strong
        "weak_local": (32, 32, 32),       # paper: 64^2*128 per core
        "fig11_shape": (16, 16, 16, 16),  # paper: 128^4, 3-D grid
        "devs": (1, 2, 4, 8),
        "outer": 5,
    },
    "paper": {
        "fig6_shape": (700, 700, 700),
        "fig7_shape": (512, 512, 512),
        "weak_local": (64, 64, 128),
        "fig11_shape": (128, 128, 128, 128),
        "devs": (1, 2, 4, 8, 16, 32),
        "outer": 50,
    },
}[SCALE]


def run_point(shape, grid, method, ndev, *, real=True, measure="total",
              outer=None, inner=3):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + str(REPO)
    cmd = [sys.executable, "-m", "benchmarks.fftbench",
           "--shape", ",".join(map(str, shape)), "--grid", grid,
           "--method", method, "--measure", measure,
           "--inner", str(inner), "--outer", str(outer or SIZES["outer"])]
    if real:
        cmd.append("--real")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"bench point failed: {cmd}\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _sweep(fig, shape_fn, grid, devs, *, methods=("fused", "traditional"),
           measures=("total", "redistribution")):
    rows = []
    for ndev in devs:
        for method in methods:
            for measure in measures:
                if ndev == 1 and measure == "redistribution":
                    continue
                r = run_point(shape_fn(ndev), grid, method, ndev, measure=measure)
                r["fig"] = fig
                rows.append(r)
                print(f"{fig},{method},{measure},ndev={ndev},"
                      f"shape={r['shape']},t={r['best_s']:.4f}s", flush=True)
    return rows


def fig6_slab_strong():
    shape = SIZES["fig6_shape"]
    return _sweep("fig6", lambda n: shape, "slab", SIZES["devs"])


def fig7_pencil_strong():
    shape = SIZES["fig7_shape"]
    devs = [d for d in SIZES["devs"] if d >= 2]
    return _sweep("fig7", lambda n: shape, "pencil", devs)


def fig8_slab_weak():
    lx, ly, lz = SIZES["weak_local"]
    return _sweep("fig8", lambda n: (lx * n, ly, lz), "slab", SIZES["devs"])


def fig9_pencil_weak():
    lx, ly, lz = SIZES["weak_local"]
    devs = [d for d in SIZES["devs"] if d >= 2]
    return _sweep("fig9", lambda n: (lx * n, ly, lz), "pencil", devs)


def fig11_fft4d():
    shape = SIZES["fig11_shape"]
    devs = [d for d in SIZES["devs"] if d >= 8]
    return _sweep("fig11", lambda n: shape, "grid3", devs or [8],
                  measures=("total",))


ALL = {
    "fig6": fig6_slab_strong,
    "fig7": fig7_pencil_strong,
    "fig8": fig8_slab_weak,
    "fig9": fig9_pencil_weak,
    "fig11": fig11_fft4d,
}


def main(which=None):
    ART.mkdir(parents=True, exist_ok=True)
    names = which or list(ALL)
    for name in names:
        rows = ALL[name]()
        (ART / f"{name}.json").write_text(json.dumps(rows, indent=1))
        # paper-claim check: fused redistribution <= traditional (per ndev)
        summary = {}
        for r in rows:
            if r["measure"] != "redistribution":
                continue
            key = r["ndev"]
            summary.setdefault(key, {})[r["method"]] = r["best_s"]
        for ndev, d in sorted(summary.items()):
            if {"fused", "traditional"} <= set(d):
                ratio = d["traditional"] / d["fused"]
                print(f"{name}: ndev={ndev} redistribution "
                      f"traditional/fused = {ratio:.2f}x", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:] or None)
