"""Diff two BENCH records on matched method keys — the CI regression gate.

Reads any committed BENCH schema (bench-v1 single record, bench-v2
record container, bench-v3 scaling series — see
:mod:`benchmarks.normalize_bench`) plus raw ``fftbench --compare`` blobs,
flattens each into ``key -> {best_s, spread_frac, device_kind, backend}``
rows keyed on the workload identity (grid, shape, device count, fields,
``method@dtype@impl``), and compares the intersection:

* a key **regresses** when the new time exceeds the old by more than the
  noise-aware threshold ``rtol + spread_slack * max(spread_old,
  spread_new)`` — the measured run-to-run spread (median/best - 1 over
  the outer repetitions, stamped on every point since bench-v3) widens
  the gate instead of a flaky hair-trigger;
* keys faster than ``--min-time`` are skipped (a sub-0.5 ms CPU point is
  scheduler noise, not signal);
* records from different ``device_kind``/``backend`` are different
  experiments: the diff is reported but **advisory** (exit 0) unless
  ``--force``.

Exit status: 1 if any enforced regression, else 0.  ``--out`` writes the
full machine-readable report (CI uploads it as an artifact).

Usage:
    python benchmarks/benchdiff.py benchmarks/BENCH_pr10.json /tmp/BENCH_new.json
    python benchmarks/benchdiff.py old.json new.json --rtol 0.25 --out diff.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _row(best_s, *, spread=None, device_kind=None, backend=None):
    return {"best_s": best_s, "spread_frac": spread,
            "device_kind": device_kind, "backend": backend}


def _flatten_v1(rec: dict) -> dict:
    """One bench-v1 record (also the shape of each bench-v2 member and of
    a raw fftbench --compare blob after minor key differences)."""
    shape = "x".join(map(str, rec.get("shape", ())))
    base = (f"{rec.get('grid')}@{shape}@nd{rec.get('ndev')}"
            f"@f{rec.get('fields', 1)}")
    kind, backend = rec.get("device_kind"), rec.get("backend")
    out = {}
    for tag, row in (rec.get("methods") or {}).items():
        best = row.get("best_s")
        if best is None:
            continue
        p50 = row.get("p50_s")
        spread = (p50 / best - 1.0) if p50 and best > 0 else None
        out[f"{base}::{tag}"] = _row(best, spread=spread,
                                     device_kind=kind, backend=backend)
    ex = rec.get("exchange")
    if ex:
        for k in ("stacked_s", "per_field_s"):
            if ex.get(k):
                out[f"{base}::exchange.{k[:-2]}"] = _row(
                    ex[k], device_kind=kind, backend=backend)
    return out


def _flatten_v3(rec: dict) -> dict:
    kind, backend = rec.get("device_kind"), rec.get("backend")
    out = {}
    for name, series in (rec.get("series") or {}).items():
        groups = [(name, series.get("points") or [])]
        redist = series.get("redist") or {}
        groups.append((name + "#redist", redist.get("points") or []))
        for prefix, pts in groups:
            for p in pts:
                out[f"{prefix}#nd{p['ndev']}"] = _row(
                    p["best_s"], spread=p.get("spread_frac"),
                    device_kind=kind, backend=backend)
    return out


def flatten_record(rec: dict) -> dict:
    """``key -> row`` for any BENCH schema (v1/v2/v3 or raw --compare)."""
    schema = rec.get("schema")
    if schema == "bench-v3":
        return _flatten_v3(rec)
    if schema == "bench-v2" or (schema is None and "records" in rec):
        out = {}
        for sub in rec.get("records", []):
            out.update(flatten_record(sub))
        return out
    # bench-v1 and raw fftbench --compare blobs share the flat layout
    return _flatten_v1(rec)


def load_record(path: str | Path) -> dict:
    text = Path(path).read_text().strip()
    try:
        return json.loads(text)
    except ValueError:
        return json.loads(text.splitlines()[-1])


def diff_records(old: dict, new: dict, *, rtol: float = 0.25,
                 min_time: float = 5e-4, spread_slack: float = 1.0) -> dict:
    """Compare flattened old/new rows; see module docstring for the rules."""
    rows_old, rows_new = flatten_record(old), flatten_record(new)
    matched = sorted(set(rows_old) & set(rows_new))
    report = {
        "rtol": rtol, "min_time": min_time, "spread_slack": spread_slack,
        "n_old": len(rows_old), "n_new": len(rows_new),
        "matched": len(matched), "advisory": False,
        "regressions": [], "improvements": [], "skipped": [], "compared": [],
    }
    for key in matched:
        o, n = rows_old[key], rows_new[key]
        if (o["device_kind"] and n["device_kind"]
                and (o["device_kind"], o["backend"])
                != (n["device_kind"], n["backend"])):
            report["advisory"] = True
        if o["best_s"] < min_time:
            report["skipped"].append({"key": key, "old_s": o["best_s"],
                                      "why": f"old < min_time {min_time}"})
            continue
        ratio = n["best_s"] / o["best_s"] - 1.0
        noise = max(o.get("spread_frac") or 0.0, n.get("spread_frac") or 0.0)
        threshold = rtol + spread_slack * noise
        entry = {"key": key, "old_s": o["best_s"], "new_s": n["best_s"],
                 "delta_frac": ratio, "threshold": threshold}
        report["compared"].append(entry)
        if ratio > threshold:
            report["regressions"].append(entry)
        elif ratio < -threshold:
            report["improvements"].append(entry)
    if report["advisory"]:
        report["advisory_reason"] = ("device_kind/backend differ between "
                                     "records: different experiments, diff "
                                     "is informational")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH records; exit 1 on regression")
    ap.add_argument("old", help="baseline BENCH record (committed)")
    ap.add_argument("new", help="candidate BENCH record (fresh run)")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="base slowdown threshold (default 0.25 = 25%%)")
    ap.add_argument("--min-time", type=float, default=5e-4,
                    help="ignore keys whose baseline is faster than this")
    ap.add_argument("--spread-slack", type=float, default=1.0,
                    help="how much measured run-to-run spread widens the "
                         "threshold")
    ap.add_argument("--out", default=None, help="write the JSON report here")
    ap.add_argument("--force", action="store_true",
                    help="enforce even across device_kind/backend mismatches")
    args = ap.parse_args(argv)

    report = diff_records(load_record(args.old), load_record(args.new),
                          rtol=args.rtol, min_time=args.min_time,
                          spread_slack=args.spread_slack)
    if args.out:
        Path(args.out).write_text(json.dumps(report, indent=1) + "\n")

    print(f"benchdiff: {report['matched']} matched key(s), "
          f"{len(report['skipped'])} below min-time, "
          f"{len(report['regressions'])} regression(s), "
          f"{len(report['improvements'])} improvement(s)")
    for entry in report["regressions"]:
        print(f"  REGRESSION {entry['key']}: {entry['old_s']:.5f}s -> "
              f"{entry['new_s']:.5f}s (+{entry['delta_frac']:.1%}, "
              f"threshold {entry['threshold']:.1%})")
    for entry in report["improvements"]:
        print(f"  improved   {entry['key']}: {entry['old_s']:.5f}s -> "
              f"{entry['new_s']:.5f}s ({entry['delta_frac']:.1%})")
    if report["matched"] == 0:
        print("benchdiff: WARNING no matched keys (different sweeps or "
              "schemas?) — nothing to gate")
        return 0
    if report["advisory"] and not args.force:
        print(f"benchdiff: advisory only — {report['advisory_reason']}")
        return 0
    if report["regressions"]:
        print("benchdiff: FAIL")
        return 1
    print("benchdiff: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
