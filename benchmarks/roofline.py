"""§Roofline: three-term analysis for every (arch x shape x mesh) cell.

Reads the dry-run artifacts (benchmarks/artifacts/dryrun/*.json) and emits
the roofline table used in EXPERIMENTS.md:

  compute_s    = HLO_FLOPs_global   / (chips * 197e12)     [bf16 peak]
  memory_s     = HLO_bytes_global   / (chips * 819e9)      [HBM]
  collective_s = coll_bytes_global  / (chips * 50e9)       [ICI]

with HLO_* taken from the trip-count-aware accounting (launch/hlo_account),
globalized as per-device * chips.  MODEL_FLOPS = 6*N(_active)*D tokens.

"roofline fraction" = ideal_model_time / dominant_term: how close the cell
would run to peak if only the dominant resource were the limit.  The perf
loop (EXPERIMENTS.md §Perf) drives the dominant term down.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ART = REPO / "benchmarks" / "artifacts" / "dryrun"

PEAK = 197e12
HBM = 819e9
ICI = 50e9

#: fraction of the non-dominant terms a well-pipelined schedule hides under
#: the dominant one (cf. the pipelined FFT exchange in core/redistribute.py:
#: all but the first slice's collective overlaps compute)
OVERLAP_EFF = 0.9


def overlap_time(compute_s, memory_s, collective_s, efficiency=OVERLAP_EFF):
    """Overlap-aware wall-time model.  The three terms are independent
    hardware pipes (MXU, HBM, ICI): a serial schedule pays their sum, a
    perfectly pipelined one pays only the max.  Real schedules land in
    between — ``efficiency`` is the fraction of the non-dominant terms that
    overlap hides (1.0 = perfect, 0.0 = serial)."""
    serial = compute_s + memory_s + collective_s
    dominant = max(compute_s, memory_s, collective_s)
    return dominant + (serial - dominant) * (1.0 - efficiency)


def term_seconds(rec):
    chips = rec["chips"]
    acct = rec.get("acct", {})
    fl = acct.get("flops_per_device", 0.0)
    hb = acct.get("hbm_bytes_per_device", 0.0)
    co = acct.get("collectives_per_device", {}).get("total", 0.0)
    return {
        "compute_s": fl / PEAK,
        "memory_s": hb / HBM,
        "collective_s": co / ICI,
        "chips": chips,
        "hlo_flops_global": fl * chips,
        "hbm_bytes_global": hb * chips,
        "coll_bytes_global": co * chips,
    }


def model_flops(rec):
    tokens = rec["batch"] * (rec["seq"] if rec["kind"] in ("train", "prefill") else 1)
    mult = 6.0 if rec["kind"] == "train" else 2.0   # fwd+bwd+upd vs fwd only
    return mult * rec["active_params"] * tokens


def analytic_min_bytes(rec):
    """Analytic LOWER bound on per-device HBM traffic (perfect fusion):
    params/opt-state movement + one activation-checkpoint stream + caches.
    The HLO-derived term is an upper bound (CPU fusion granularity); the
    truth for a TPU build lies between — both are reported."""
    chips = rec["chips"]
    p = rec["params"]
    tokens = rec["batch"] * rec["seq"]
    if rec["kind"] == "train":
        # read p (bf16, fwd+bwd gathers) + rw fp32 m/v + write p + grads
        param_traffic = p * (2 + 2 + 16 + 4) / chips
        act = 4 * tokens * _d_model(rec) * 2 / chips     # stash w+r, bf16, ~2x
        return param_traffic + act
    if rec["kind"] == "prefill":
        return p * 2 / chips + 4 * tokens * _d_model(rec) * 2 / chips
    # decode: read all (active) params + read the cache once
    cache = rec.get("cache_bytes", 0) or 2 * rec["batch"] * rec["seq"] * _d_model(rec) / 8
    return rec["active_params"] * 2 / chips + cache / chips


_DM = {"glm4_9b": 4096, "stablelm_12b": 5120, "nemotron_4_15b": 6144,
       "qwen2_72b": 8192, "deepseek_v2_lite_16b": 2048, "phi35_moe_42b": 4096,
       "seamless_m4t_medium": 1024, "llava_next_34b": 7168,
       "zamba2_2p7b": 2560, "falcon_mamba_7b": 4096}


def _d_model(rec):
    return _DM.get(rec["arch"], 4096)


def suggest(dom, rec):
    k = rec["kind"]
    if dom == "collective_s":
        return ("overlap FSDP gathers with layer compute / shrink payload "
                "(reduce-scatter grads in bf16, 2D-shard big tables)")
    if dom == "memory_s":
        if k == "decode":
            return "decode is cache-bandwidth-bound: shrink KV (MLA/GQA/quant) or batch more requests"
        return "raise arithmetic intensity: fuse elementwise chains, larger microbatch, remat less"
    return "compute-bound: this is the target regime; chase MXU util (tile sizes, bf16 paths)"


def analyze(mesh_filter="single"):
    rows = []
    for path in sorted(ART.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec["mesh"] != mesh_filter or rec.get("sp_mode", "none") != "none":
            continue
        if rec.get("opt") or rec.get("flags"):
            continue  # §Perf variants live in perf_report, not the baseline table
        t = term_seconds(rec)
        mf = model_flops(rec)
        ideal = mf / (t["chips"] * PEAK)
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        serial_s = t["compute_s"] + t["memory_s"] + t["collective_s"]
        overlap_s = overlap_time(t["compute_s"], t["memory_s"], t["collective_s"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
            **{k: t[k] for k in ("compute_s", "memory_s", "collective_s")},
            "memory_lb_s": analytic_min_bytes(rec) / HBM,
            "serial_s": serial_s,
            "overlap_s": overlap_s,
            "overlap_gain": serial_s / overlap_s if overlap_s else 0.0,
            "dominant": dom.replace("_s", ""),
            "model_flops": mf,
            "hlo_flops": t["hlo_flops_global"],
            "useful_ratio": mf / t["hlo_flops_global"] if t["hlo_flops_global"] else 0.0,
            "roofline_frac": ideal / bound if bound else 0.0,
            "next_move": suggest(dom, rec),
        })
    return rows


def to_markdown(rows):
    head = ("| arch | shape | compute s | memory s (hlo / lb) | collective s | "
            "overlap s (serial) | dominant | MODEL/HLO flops | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|---|")
    out = [head]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} / {r['memory_lb_s']:.3e} | "
            f"{r['collective_s']:.3e} | "
            f"{r['overlap_s']:.3e} ({r['serial_s']:.3e}) | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} |")
    return "\n".join(out)


def main(argv=None):
    mesh = (argv or sys.argv[1:] or ["single"])[0]
    rows = analyze(mesh)
    if not rows:
        print(f"no artifacts for mesh={mesh} under {ART} — run "
              f"`python -m repro.launch.dryrun --all --mesh {mesh}` first")
        return
    print(to_markdown(rows))
    out = REPO / "benchmarks" / "artifacts" / f"roofline_{mesh}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\n[{len(rows)} cells] -> {out}")
    worst = sorted(rows, key=lambda r: r["roofline_frac"])[:3]
    collb = [r for r in sorted(rows, key=lambda r: -r["collective_s"])][:3]
    print("\nworst roofline fraction:", [(r["arch"], r["shape"]) for r in worst])
    print("most collective-bound:", [(r["arch"], r["shape"]) for r in collb])


if __name__ == "__main__":
    main()
