"""Subprocess worker: time one distributed-FFT configuration.

Mirrors the paper's methodology (Sec. 4): an inner loop of ``--inner``
consecutive forward+backward transforms, repeated ``--outer`` times; we
report the fastest outer iteration divided by inner (their "fastest of 50
outers of 3").  ``--measure redistribution`` times an exchanges-only plan
(the paper's "global redistribution" split); fft time = total - redist.
``--compare`` times all four exchange engines {fused, traditional,
pipelined, auto} × every ``--comm-dtypes`` wire payload {complex64, bf16,
int8} on the same problem and reports one JSON table with a ``comm_dtype``
column per row (pass ``--tune-cache`` so the auto schedules round-trip to
disk).

Run via benchmarks.paperfigs which sets XLA_FLAGS for the device count.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_plan(shape, gridspec, ndev, *, real, method, impl, chunks=4,
               comm_dtype=None, tuner_cache=None, transforms=None):
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT

    if gridspec == "slab":
        mesh = make_mesh((ndev,), ("p0",))
        grid = ("p0",)
    elif gridspec == "pencil":
        from repro.core.meshutil import balanced_dims

        mesh = make_mesh(balanced_dims(ndev), ("p0", "p1"))
        grid = ("p0", "p1")
    elif gridspec == "grid3":
        dims = []
        rem = ndev
        for _ in range(2):
            a = int(round(rem ** (1 / (3 - len(dims)))))
            while rem % a:
                a -= 1
            dims.append(a)
            rem //= a
        dims.append(rem)
        mesh = make_mesh(tuple(dims), ("p0", "p1", "p2"))
        grid = ("p0", "p1", "p2")
    else:
        raise ValueError(gridspec)
    if transforms:
        return ParallelFFT(mesh, shape, grid, transforms=transforms,
                           method=method, impl=impl, chunks=chunks,
                           comm_dtype=comm_dtype, tuner_cache=tuner_cache)
    return ParallelFFT(mesh, shape, grid, real=real, method=method, impl=impl,
                       chunks=chunks, comm_dtype=comm_dtype,
                       tuner_cache=tuner_cache)


def exchanges_only(plan):
    """A jit'd function running only the plan's exchange stages (paper's
    'global redistribution' timing split)."""
    from repro.core.meshutil import shard_map
    from repro.core.pfft import ExchangeStage
    from repro.core.redistribute import exchange_shard

    stages = [(s, b, a, dt) for s, b, a, dt in
              zip(plan.stages, plan.pencil_trace, plan.pencil_trace[1:],
                  plan.dtype_trace)
              if isinstance(s, ExchangeStage)]

    schedule = plan.schedule  # resolves "auto" to the tuned per-stage mix

    def run(block):
        for ex_i, (st, before, after, dtype) in enumerate(stages):
            # emulate the fft-stage shape *and dtype* change between
            # exchanges (an r2c mid-plan means later exchanges carry
            # complex64 while earlier ones carried f32)
            if (block.shape != tuple(np.array(before.local_shape))
                    or block.dtype != dtype):
                block = jnp.zeros(before.local_shape, dtype)
            method, chunks, comm_dtype = schedule[ex_i]
            block = exchange_shard(block, st.v, st.w, st.group,
                                   method=method, chunks=chunks,
                                   comm_dtype=comm_dtype)
        return block

    first, first_dtype = stages[0][1], stages[0][3]
    fn = shard_map(run, mesh=plan.mesh, in_specs=first.spec,
                   out_specs=stages[-1][2].spec, check_vma=False)
    return jax.jit(fn), first, first_dtype


METHODS = ("fused", "traditional", "pipelined", "auto")


def _best_of(once, xg, *, outer, inner):
    """Fastest outer iteration of ``inner`` consecutive applications."""
    once(xg).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(outer):
        t0 = time.perf_counter()
        v = xg
        for _ in range(inner):
            v = once(v)
        v.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def _make_input(plan, shape):
    """Random logical input at the plan's true input dtype (real for r2c
    and all-real dct/dst transform plans, complex otherwise)."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal(shape).astype(np.float32)
    if plan.input_dtype == jnp.complex64:
        x = (x + 1j * rng.standard_normal(shape)).astype(np.complex64)
    return x


def _time_plan(plan, shape, args):
    """Time one forward+backward round trip of ``plan`` (total measure)."""
    x = _make_input(plan, shape)
    from repro.core.pencil import pad_global

    xg = jax.device_put(pad_global(jnp.asarray(x), plan.input_pencil),
                        plan.input_pencil.sharding)
    fwd, bwd = jax.jit(plan.forward_padded), jax.jit(plan.backward_padded)
    return _best_of(lambda v: bwd(fwd(v)), xg, outer=args.outer, inner=args.inner)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", type=str, required=True)  # e.g. 128,128,128
    ap.add_argument("--grid", choices=["slab", "pencil", "grid3"], default="slab")
    ap.add_argument("--method", choices=METHODS, default="fused")
    ap.add_argument("--chunks", type=int, default=4,
                    help="slice count for method=pipelined")
    ap.add_argument("--tune-cache", type=str, default=None,
                    help="schedule cache path for method=auto")
    ap.add_argument("--comm-dtype", choices=["complex64", "bf16", "int8"],
                    default="complex64",
                    help="exchange wire payload (auto: accuracy budget)")
    ap.add_argument("--comm-dtypes", type=str, default="complex64,bf16,int8",
                    help="comma list of payloads the --compare sweep covers")
    ap.add_argument("--compare", action="store_true",
                    help="time all four methods x all --comm-dtypes payloads "
                         "and report one table")
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--transforms", type=str, default=None,
                    help="comma list of per-axis transform tags (c2c, r2c, "
                         "dct2, dct3, dst2, dst3), overriding --real; e.g. "
                         "--transforms dct2,c2c,r2c")
    ap.add_argument("--impl", default="jnp")
    ap.add_argument("--inner", type=int, default=3)
    ap.add_argument("--outer", type=int, default=10)
    ap.add_argument("--measure", choices=["total", "redistribution"], default="total")
    args = ap.parse_args(argv)

    shape = tuple(int(s) for s in args.shape.split(","))
    if args.transforms and args.real:
        ap.error("--transforms and --real are mutually exclusive "
                 "(use --transforms ...,r2c for a real plan)")
    transforms = tuple(args.transforms.split(",")) if args.transforms else None
    ndev = len(jax.devices())
    if args.compare:
        out = {"shape": shape, "grid": args.grid, "real": bool(args.real),
               "transforms": list(transforms) if transforms else None,
               "ndev": ndev, "methods": {}}
        for method in METHODS:
            for comm_dtype in args.comm_dtypes.split(","):
                plan = build_plan(shape, args.grid, ndev, real=args.real,
                                  method=method, impl=args.impl,
                                  chunks=args.chunks, comm_dtype=comm_dtype,
                                  tuner_cache=args.tune_cache,
                                  transforms=transforms)
                if not out["methods"]:
                    # the workload's true input kind, once from the first
                    # plan (a --transforms plan can be real without --real)
                    out["real"] = bool(plan.input_dtype == jnp.float32)
                out["methods"][f"{method}@{comm_dtype}"] = {
                    "comm_dtype": comm_dtype,
                    "best_s": _time_plan(plan, shape, args),
                    "schedule": [list(s) for s in plan.schedule],
                    # itemsize=None prices each exchange at its traced
                    # dtype width (complex64 after the r2c stage, f32 for
                    # exchanges of still-real dct/dst data)
                    "model_time_s": plan.model_time_s(itemsize=None),
                    "wire_bytes_per_dev": plan.comm_bytes_per_device(None),
                }
        print(json.dumps(out))
        return
    plan = build_plan(shape, args.grid, ndev, real=args.real,
                      method=args.method, impl=args.impl, chunks=args.chunks,
                      comm_dtype=args.comm_dtype, tuner_cache=args.tune_cache,
                      transforms=transforms)

    x = _make_input(plan, shape)
    from repro.core.pencil import pad_global

    xg = jax.device_put(pad_global(jnp.asarray(x), plan.input_pencil),
                        plan.input_pencil.sharding)

    if args.measure == "redistribution":
        rng = np.random.default_rng(0)
        fn, first, first_dtype = exchanges_only(plan)
        buf = rng.standard_normal(first.physical).astype(np.float32)
        if first_dtype == jnp.complex64:
            buf = (buf + 1j * rng.standard_normal(first.physical)).astype(np.complex64)
        xg = jax.device_put(jnp.asarray(buf), first.sharding)

        def once(v):
            return fn(v)
    else:
        fwd, bwd = jax.jit(plan.forward_padded), jax.jit(plan.backward_padded)

        def once(v):
            return bwd(fwd(v))

    best = _best_of(once, xg, outer=args.outer, inner=args.inner)
    print(json.dumps({
        "shape": shape, "grid": args.grid, "method": args.method,
        "comm_dtype": plan.comm_dtype,
        "real": bool(plan.input_dtype == jnp.float32),
        "ndev": ndev, "measure": args.measure,
        "transforms": [sp.tag() for sp in plan.transforms],
        "best_s": best,
        "comm_bytes_per_dev": plan.comm_bytes_per_device(None),
        "model_flops": plan.model_flops(),
    }))


if __name__ == "__main__":
    main()
