"""Subprocess worker: time one distributed-FFT configuration.

Mirrors the paper's methodology (Sec. 4): an inner loop of ``--inner``
consecutive forward+backward transforms, repeated ``--outer`` times; we
report the fastest outer iteration divided by inner (their "fastest of 50
outers of 3").  ``--measure redistribution`` times an exchanges-only plan
(the paper's "global redistribution" split); fft time = total - redist.
``--compare`` times all four exchange engines {fused, traditional,
pipelined, auto} × every ``--comm-dtypes`` wire payload {complex64, bf16,
int8} on the same problem and reports one JSON table with a ``comm_dtype``
column per row (pass ``--tune-cache`` so the auto schedules round-trip to
disk).  ``--exchange-impls jnp,pallas`` adds fused-exchange-kernel rows
(``method@dtype@pallas``) for every lossy payload; lossless payloads get
no pallas row because the fused kernels don't apply there and the plan
would be identical.

``--fields N`` (N > 1) benchmarks the batched multi-field path: every
timed transform runs N stacked fields through one plan invocation, the
``--compare`` sweep grows a ``batch_fusion`` dimension ({stacked,
pipelined-across-fields, per-field} per method×payload row), and the
report gains an ``"exchange"`` section timing the exchanges-only plan
batched (one all-to-all per stage for all N fields) vs as a per-field
loop (N all-to-alls per stage) — the message-aggregation win in
isolation.

Run via benchmarks.paperfigs which sets XLA_FLAGS for the device count.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_plan(shape, gridspec, ndev, *, real, method, impl, chunks=4,
               comm_dtype=None, tuner_cache=None, transforms=None,
               batch_fusion="stacked", exchange_impl="jnp"):
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT
    from repro.core.planconfig import PlanConfig

    if gridspec == "slab":
        mesh = make_mesh((ndev,), ("p0",))
        grid = ("p0",)
    elif gridspec == "pencil":
        from repro.core.meshutil import balanced_dims

        mesh = make_mesh(balanced_dims(ndev), ("p0", "p1"))
        grid = ("p0", "p1")
    elif gridspec == "grid3":
        dims = []
        rem = ndev
        for _ in range(2):
            a = int(round(rem ** (1 / (3 - len(dims)))))
            while rem % a:
                a -= 1
            dims.append(a)
            rem //= a
        dims.append(rem)
        mesh = make_mesh(tuple(dims), ("p0", "p1", "p2"))
        grid = ("p0", "p1", "p2")
    else:
        raise ValueError(gridspec)
    if not transforms and real:
        # --real sugar, spelled as an explicit transform list (the real=
        # ParallelFFT kwarg is deprecated)
        transforms = ("c2c",) * (len(shape) - 1) + ("r2c",)
    cfg = PlanConfig(method=method, impl=impl, exchange_impl=exchange_impl,
                     chunks=chunks, comm_dtype=comm_dtype,
                     batch_fusion=batch_fusion, tuner_cache=tuner_cache)
    return ParallelFFT(mesh, shape, grid, config=cfg,
                       transforms=transforms or None)


def exchanges_only(plan, *, nfields=1, batch_fusion="stacked"):
    """A jit'd function running only the plan's exchange stages (paper's
    'global redistribution' timing split).

    ``nfields > 1`` runs the stages on a stacked ``(nfields, …)`` block:
    ``batch_fusion="stacked"`` ships all fields in one all-to-all per
    stage, ``"per-field"`` issues the N per-field collectives a loop over
    single-field plans would — the pair isolates the message-aggregation
    win of the batched path."""
    from repro.core.meshutil import shard_map
    from repro.core.pfft import ExchangeStage
    from repro.core.redistribute import exchange_shard

    stages = [(s, b, a, dt) for s, b, a, dt in
              zip(plan.stages, plan.pencil_trace, plan.pencil_trace[1:],
                  plan.dtype_trace)
              if isinstance(s, ExchangeStage)]

    schedule = plan.schedule  # resolves "auto" to the tuned per-stage mix
    nbatch = 1 if nfields > 1 else 0

    def run(block):
        for ex_i, (st, before, _after, dtype) in enumerate(stages):
            # emulate the fft-stage shape *and dtype* change between
            # exchanges (an r2c mid-plan means later exchanges carry
            # complex64 while earlier ones carried f32)
            want = (nfields,) * nbatch + tuple(np.array(before.local_shape))
            if block.shape != want or block.dtype != dtype:
                block = jnp.zeros(want, dtype)
            method, chunks, comm_dtype, ex_impl, _fusion = schedule[ex_i]
            if nbatch and batch_fusion != "stacked":
                # per-field and pipelined-across-fields both issue N
                # per-field collectives here (no FFTs to interleave with)
                block = jnp.stack([
                    exchange_shard(block[f], st.v, st.w, st.group,
                                   method=method, chunks=chunks,
                                   comm_dtype=comm_dtype, impl=ex_impl)
                    for f in range(nfields)])
            else:
                block = exchange_shard(block, st.v, st.w, st.group,
                                       method=method, chunks=chunks,
                                       comm_dtype=comm_dtype, impl=ex_impl,
                                       nbatch=nbatch)
        return block

    first, first_dtype = stages[0][1], stages[0][3]
    fn = shard_map(run, mesh=plan.mesh, in_specs=first.batched_spec(nbatch),
                   out_specs=stages[-1][2].batched_spec(nbatch), check_vma=False)
    return jax.jit(fn), first, first_dtype


METHODS = ("fused", "traditional", "pipelined", "auto")


def _best_of(once, xg, *, outer, inner):
    """Fastest outer iteration of ``inner`` consecutive applications."""
    return _timed(once, xg, outer=outer, inner=inner)[0]


def _timed(once, xg, *, outer, inner):
    """(fastest, median) outer iteration of ``inner`` consecutive
    applications — the median rides along so downstream consumers
    (benchdiff's noise-aware regression gate) can tell run-to-run spread
    from a real slowdown."""
    once(xg).block_until_ready()  # compile + warm
    times = []
    for _ in range(outer):
        t0 = time.perf_counter()
        v = xg
        for _ in range(inner):
            v = once(v)
        v.block_until_ready()
        times.append((time.perf_counter() - t0) / inner)
    return min(times), float(np.median(times))


def _make_input(plan, shape, nfields=1):
    """Random logical input at the plan's true input dtype (real for r2c
    and all-real dct/dst transform plans, complex otherwise); ``nfields``
    stacks N fields along a leading batch axis."""
    rng = np.random.default_rng(0)
    full = ((nfields,) if nfields > 1 else ()) + tuple(shape)
    x = rng.standard_normal(full).astype(np.float32)
    if plan.input_dtype == jnp.complex64:
        x = (x + 1j * rng.standard_normal(full)).astype(np.complex64)
    return x


def _time_plan(plan, shape, args):
    """Time one forward+backward round trip of ``plan`` (total measure),
    batched over ``--fields`` stacked fields when N > 1; returns
    ``(best_s, p50_s)``."""
    nf = args.fields
    x = _make_input(plan, shape, nf)
    from repro.core.pencil import pad_global

    if nf > 1:
        xg = jax.device_put(pad_global(jnp.asarray(x), plan.input_pencil, nbatch=1),
                            plan.input_pencil.batched_sharding())
        fwd = jax.jit(plan.forward_many_padded(nf))
        bwd = jax.jit(plan.backward_many_padded(nf))
    else:
        xg = jax.device_put(pad_global(jnp.asarray(x), plan.input_pencil),
                            plan.input_pencil.sharding)
        fwd, bwd = jax.jit(plan.forward_padded), jax.jit(plan.backward_padded)
    return _timed(lambda v: bwd(fwd(v)), xg, outer=args.outer, inner=args.inner)


def _time_guard_pair(plan, shape, args):
    """Measure the guarded round trip (fused health checks + per-shard
    stat partials) against the unguarded one on the same input, returning
    ``(unguarded_s, guarded_s)``.  The two executors alternate within
    every outer round: timing them in separate back-to-back sweeps
    conflates guard cost with machine drift (thermal/cache state shifts
    over a sweep easily exceed the real overhead).  The guarded jits
    return the stats vector, so XLA cannot dead-code-eliminate the guard
    ops — this measures the real ``guard != "off"`` overhead."""
    nf = args.fields
    x = _make_input(plan, shape, nf)
    from repro.core.pencil import pad_global

    if nf > 1:
        xg = jax.device_put(pad_global(jnp.asarray(x), plan.input_pencil, nbatch=1),
                            plan.input_pencil.batched_sharding())
        ufwd = jax.jit(plan.forward_many_padded(nf))
        ubwd = jax.jit(plan.backward_many_padded(nf))
        gfwd = jax.jit(plan.guarded_padded("forward", nfields=nf))
        gbwd = jax.jit(plan.guarded_padded("backward", nfields=nf))
    else:
        xg = jax.device_put(pad_global(jnp.asarray(x), plan.input_pencil),
                            plan.input_pencil.sharding)
        ufwd, ubwd = jax.jit(plan.forward_padded), jax.jit(plan.backward_padded)
        gfwd = jax.jit(plan.guarded_padded("forward"))
        gbwd = jax.jit(plan.guarded_padded("backward"))

    def unguarded(v):
        return ubwd(ufwd(v))

    def guarded(v):
        y, _ = gfwd(v)
        z, _ = gbwd(y)
        return z

    unguarded(xg).block_until_ready()  # compile + warm
    guarded(xg).block_until_ready()
    best = {"u": float("inf"), "g": float("inf")}
    for _ in range(args.outer):
        for k, once in (("u", unguarded), ("g", guarded)):
            t0 = time.perf_counter()
            v = xg
            for _ in range(args.inner):
                v = once(v)
            v.block_until_ready()
            best[k] = min(best[k], (time.perf_counter() - t0) / args.inner)
    return best["u"], best["g"]


#: "infinite" bandwidth for isolating the model's comm-free residual
_NO_COMM_BW = 1e30


def _model_features(plan, measure: str, nfields: int) -> dict:
    """Analytic-model terms for the measured quantity, in the linear
    surrogate form :mod:`repro.core.modelfit` fits — ``time_s`` at the
    reference coefficients, the comm-free ``compute_s`` residual
    (bandwidth → ∞, latency → 0: FFT flops + codec/copy HBM passes), the
    wire bytes, and the latency-priced collective launch count.  A
    ``total`` measure is a forward+backward round trip, so every term sums
    both directions; ``redistribution`` prices the exchanges-only
    executor."""
    from repro.core.modelfit import REFERENCE_COEFFS

    if measure == "redistribution":
        kw = {"itemsize": None, "nfields": nfields, "exchange_only": True}
        time_s = plan.model_time_s(**kw)
        compute_s = plan.model_time_s(ici_bw=_NO_COMM_BW, ici_latency_s=0.0, **kw)
        wire = plan.comm_bytes_per_device(None, nfields=nfields)
        launches = plan.model_collective_launches(nfields=nfields)
    else:
        kw = {"itemsize": None, "nfields": nfields}
        time_s = compute_s = 0.0
        launches = 0
        for direction in ("forward", "backward"):
            time_s += plan.model_time_s(direction=direction, **kw)
            compute_s += plan.model_time_s(direction=direction, ici_bw=_NO_COMM_BW,
                                           ici_latency_s=0.0, **kw)
            launches += plan.model_collective_launches(nfields=nfields,
                                                       direction=direction)
        # backward walks the same exchanges reversed: same wire volume
        wire = 2 * plan.comm_bytes_per_device(None, nfields=nfields)
    return {
        "time_s": time_s,
        "compute_s": compute_s,
        "wire_bytes_per_dev": wire,
        "launches": launches,
        "coeffs": dict(REFERENCE_COEFFS),
    }


def _rand_block(shape, dtype):
    """Random buffer for exchange timings, complex when the stage is."""
    rng = np.random.default_rng(0)
    buf = rng.standard_normal(shape).astype(np.float32)
    if dtype == jnp.complex64:
        buf = (buf + 1j * rng.standard_normal(shape)).astype(np.complex64)
    return jnp.asarray(buf)


def _exchange_comparison(plan, args):
    """Time the exchanges-only plan over N stacked fields, batched (one
    collective per stage) vs as a per-field loop (N per stage): the
    message-aggregation win in isolation."""
    out = {}
    for fusion in ("stacked", "per-field"):
        fn, first, first_dtype = exchanges_only(plan, nfields=args.fields,
                                                batch_fusion=fusion)
        xg = jax.device_put(_rand_block((args.fields, *first.physical), first_dtype),
                            first.batched_sharding())
        out[fusion.replace("-", "_") + "_s"] = _best_of(
            fn, xg, outer=args.outer, inner=args.inner)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", type=str, required=True)  # e.g. 128,128,128
    ap.add_argument("--grid", choices=["slab", "pencil", "grid3"], default="slab")
    ap.add_argument("--method", choices=METHODS, default="fused")
    ap.add_argument("--chunks", type=int, default=4,
                    help="slice count for method=pipelined")
    ap.add_argument("--tune-cache", type=str, default=None,
                    help="schedule cache path for method=auto")
    ap.add_argument("--comm-dtype", choices=["complex64", "bf16", "int8"],
                    default="complex64",
                    help="exchange wire payload (auto: accuracy budget)")
    ap.add_argument("--comm-dtypes", type=str, default="complex64,bf16,int8",
                    help="comma list of payloads the --compare sweep covers")
    ap.add_argument("--fields", type=int, default=1,
                    help="number of stacked fields per transform (N>1 "
                         "benchmarks the batched multi-field path)")
    ap.add_argument("--batch-fusion", default="stacked",
                    choices=["stacked", "pipelined-across-fields", "per-field"],
                    help="multi-field execution mode for single-method runs "
                         "(--compare sweeps all three)")
    ap.add_argument("--guard", choices=["off", "strict", "degrade"],
                    default="off",
                    help="also time the guarded executor (fused runtime "
                         "health checks) and report the overhead vs the "
                         "unguarded round trip")
    ap.add_argument("--compare", action="store_true",
                    help="time all four methods x all --comm-dtypes payloads "
                         "and report one table")
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--transforms", type=str, default=None,
                    help="comma list of per-axis transform tags (c2c, r2c, "
                         "dct2, dct3, dst2, dst3), overriding --real; e.g. "
                         "--transforms dct2,c2c,r2c")
    ap.add_argument("--impl", default="jnp")
    ap.add_argument("--exchange-impl", choices=["jnp", "pallas"], default="jnp",
                    help="exchange-local pack/codec implementation: 'pallas' "
                         "runs the fused quantize+pack / unpack+dequantize "
                         "kernels on lossy payloads (auto: candidate budget)")
    ap.add_argument("--exchange-impls", type=str, default="jnp",
                    help="comma list of exchange impls the --compare sweep "
                         "covers; pallas rows appear only where the fused "
                         "kernels apply (lossy payloads)")
    ap.add_argument("--inner", type=int, default=3)
    ap.add_argument("--outer", type=int, default=10)
    ap.add_argument("--measure", choices=["total", "redistribution"], default="total")
    ap.add_argument("--no-audit", action="store_true",
                    help="skip the per-row planlint audit (one extra compile "
                         "per --compare row)")
    args = ap.parse_args(argv)

    shape = tuple(int(s) for s in args.shape.split(","))
    if args.transforms and args.real:
        ap.error("--transforms and --real are mutually exclusive "
                 "(use --transforms ...,r2c for a real plan)")
    transforms = tuple(args.transforms.split(",")) if args.transforms else None
    ndev = len(jax.devices())
    if args.compare:
        out = {"shape": shape, "grid": args.grid, "real": bool(args.real),
               "transforms": list(transforms) if transforms else None,
               "ndev": ndev, "fields": args.fields,
               "device_kind": jax.devices()[0].device_kind,
               "backend": jax.default_backend(), "methods": {}}
        fusions = (["stacked", "pipelined-across-fields", "per-field"]
                   if args.fields > 1 else ["stacked"])
        from repro.kernels.exchange import pallas_applicable

        # pallas rows only where the fused kernels apply (lossy payloads);
        # elsewhere the plan is identical to the jnp row
        rows = [(m, d, x) for m in METHODS
                for d in args.comm_dtypes.split(",")
                for x in args.exchange_impls.split(",")
                if x == "jnp" or pallas_applicable(m, d)]
        for method, comm_dtype, ximpl in rows:
            for fusion in fusions:
                plan = build_plan(shape, args.grid, ndev, real=args.real,
                                  method=method, impl=args.impl,
                                  chunks=args.chunks, comm_dtype=comm_dtype,
                                  tuner_cache=args.tune_cache,
                                  transforms=transforms, batch_fusion=fusion,
                                  exchange_impl=ximpl)
                if not out["methods"]:
                    # the workload's true input kind, once from the first
                    # plan (a --transforms plan can be real without --real)
                    out["real"] = bool(plan.input_dtype == jnp.float32)
                sched = (plan.batched_schedule(args.fields)
                         if args.fields > 1 else plan.schedule)
                tag = (f"{method}@{comm_dtype}@{fusion}"
                       if args.fields > 1 else f"{method}@{comm_dtype}")
                if ximpl != "jnp":
                    tag += f"@{ximpl}"
                best_s, p50_s = _time_plan(plan, shape, args)
                out["methods"][tag] = {
                    "comm_dtype": comm_dtype,
                    "exchange_impl": ximpl,
                    "batch_fusion": fusion if args.fields > 1 else None,
                    "best_s": best_s,
                    "p50_s": p50_s,
                    "schedule": [list(s) for s in sched],
                    # itemsize=None prices each exchange at its traced
                    # dtype width (complex64 after the r2c stage, f32 for
                    # exchanges of still-real dct/dst data)
                    "model_time_s": plan.model_time_s(
                        itemsize=None, nfields=args.fields),
                    "wire_bytes_per_dev": plan.comm_bytes_per_device(
                        None, nfields=args.fields),
                    # static certification of the timed artifact: the
                    # row's numbers are meaningless if the compiled plan
                    # doesn't match its claimed schedule
                    "audit": None if args.no_audit
                    else plan.audit(nfields=args.fields).summary(),
                }
                if args.fields > 1 and method == "auto":
                    # one fusion pass suffices: auto tunes batch_fusion
                    # per stage itself, so the plan's own mode is moot
                    break
        if args.fields > 1:
            plan = build_plan(shape, args.grid, ndev, real=args.real,
                              method="fused", impl=args.impl,
                              transforms=transforms)
            out["exchange"] = {"fields": args.fields,
                               **_exchange_comparison(plan, args)}
        print(json.dumps(out))
        return
    plan = build_plan(shape, args.grid, ndev, real=args.real,
                      method=args.method, impl=args.impl, chunks=args.chunks,
                      comm_dtype=args.comm_dtype, tuner_cache=args.tune_cache,
                      transforms=transforms, batch_fusion=args.batch_fusion,
                      exchange_impl=args.exchange_impl)
    nf = args.fields

    if args.measure == "redistribution":
        fusion = args.batch_fusion if nf > 1 else "stacked"
        fn, first, first_dtype = exchanges_only(plan, nfields=nf,
                                                batch_fusion=fusion)
        nbatch = 1 if nf > 1 else 0
        xg = jax.device_put(
            _rand_block((nf,) * nbatch + tuple(first.physical), first_dtype),
            first.batched_sharding(nbatch))

        def once(v):
            return fn(v)

        best, p50 = _timed(once, xg, outer=args.outer, inner=args.inner)
    else:
        best, p50 = _time_plan(plan, shape, args)
    guard_section = None
    if args.guard != "off" and args.measure == "total":
        unguarded_s, guarded_s = _time_guard_pair(plan, shape, args)
        guard_section = {
            "mode": args.guard,
            "unguarded_s": unguarded_s,
            "guarded_s": guarded_s,
            "overhead_frac": guarded_s / unguarded_s - 1.0,
        }
    print(json.dumps({
        "shape": shape, "grid": args.grid, "method": args.method,
        "comm_dtype": plan.comm_dtype,
        "exchange_impl": args.exchange_impl,
        "fields": nf,
        "batch_fusion": args.batch_fusion if nf > 1 else None,
        "real": bool(plan.input_dtype == jnp.float32),
        "ndev": ndev, "measure": args.measure,
        "device_kind": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "transforms": [sp.tag() for sp in plan.transforms],
        "best_s": best,
        "p50_s": p50,
        "spread_frac": p50 / best - 1.0 if best > 0 else 0.0,
        "guard": guard_section,
        "comm_bytes_per_dev": plan.comm_bytes_per_device(None, nfields=nf),
        "model_flops": plan.model_flops(nfields=nf),
        "model": _model_features(plan, args.measure, nf),
    }))


if __name__ == "__main__":
    main()
