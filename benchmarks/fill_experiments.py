"""Regenerate the data-driven sections of EXPERIMENTS.md from artifacts.

Replaces the <!-- placeholder --> markers with tables built from
benchmarks/artifacts/. Idempotent: content between a marker and the next
section header is regenerated on every run.
"""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ART = REPO / "benchmarks" / "artifacts"


def roofline_md(mesh):
    sys.path.insert(0, str(REPO))
    from benchmarks.roofline import analyze, to_markdown
    rows = analyze(mesh)
    return to_markdown(rows) if rows else "(no artifacts)"


def dryrun_md():
    rows = []
    want = [("qwen2_72b", "train_4k"), ("qwen2_72b", "decode_32k"),
            ("deepseek_v2_lite_16b", "train_4k"), ("llava_next_34b", "prefill_32k"),
            ("falcon_mamba_7b", "long_500k"), ("zamba2_2p7b", "long_500k"),
            ("seamless_m4t_medium", "train_4k")]
    out = ["| arch | shape | chips | flops/dev | HBM bytes/dev | coll bytes/dev | compile |",
           "|---|---|---|---|---|---|---|"]
    for arch, shape in want:
        p = ART / "dryrun" / f"{arch}__{shape}__single.json"
        if not p.exists():
            continue
        r = json.loads(p.read_text())
        a = r["acct"]
        out.append(f"| {arch} | {shape} | {r['chips']} | "
                   f"{a['flops_per_device']:.2e} | {a['hbm_bytes_per_device']:.2e} | "
                   f"{a['collectives_per_device'].get('total', 0):.2e} | "
                   f"{r.get('compile_s', '?')}s |")
    return "\n".join(out)


def fft_md():
    p = ART / "figs" / "fft_roofline.json"
    lines = []
    if p.exists():
        d = json.loads(p.read_text())
        lines.append("Production-mesh FFT dry-run (fig10: 512³ r2c pencil on 16×16; "
                     "fig11: 64⁴ c2c on 8×8×4; fwd+bwd; REPRO_BENCH_SCALE=paper "
                     "switches to 2048³/128⁴):\n")
        lines.append("| case | method | serial FFT | compute s | memory s | collective s | dominant |")
        lines.append("|---|---|---|---|---|---|---|")
        for k in ("fig10_fused", "fig10_traditional", "fig10_fused_matmulDFT",
                  "fig11_fused", "fig11_traditional"):
            if k not in d:
                continue
            r = d[k]
            lines.append(f"| {k.split('_')[0]} | {r['method']} | {r.get('impl', 'jnp')} "
                         f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
                         f"| {r['collective_s']:.2e} | {r['dominant']} |")
        if "fig10_fused_matmulDFT" in d:
            lines.append(
                "\nThe `matmul` row is the TPU-native four-step MXU DFT "
                "(DESIGN.md §4): ~7x the radix-FFT FLOPs as predicted, still "
                "<<1% of the memory term — confirming the serial transform is "
                "never the bottleneck and the MXU path is affordable. (Its "
                "memory term is inflated by interpret-mode lowering, which "
                "streams VMEM-resident intermediates; noted, not claimed.)")
        f10f, f10t = d["fig10_fused"], d["fig10_traditional"]
        lines.append(f"\nfig10 traditional/fused HBM = "
                     f"{f10t['hbm_bytes_per_device'] / f10f['hbm_bytes_per_device']:.2f}x "
                     "(the pack/unpack copies). Both dominated by memory/collective — "
                     "FFT is the textbook communication-bound workload, which is the "
                     "paper's premise.")
    # wall-time fig tables
    for fig in ("fig6", "fig7", "fig8", "fig9", "fig11"):
        p = ART / "figs" / f"{fig}.json"
        if not p.exists():
            continue
        rows = json.loads(p.read_text())
        lines.append(f"\n**{fig}** (CPU wall-time, 1 physical core, N virtual "
                     "devices — relative method comparison only):\n")
        lines.append("| ndev | shape | method | measure | best s |")
        lines.append("|---|---|---|---|---|")
        for r in rows:
            lines.append(f"| {r['ndev']} | {'x'.join(map(str, r['shape']))} "
                         f"| {r['method']} | {r['measure']} | {r['best_s']:.4f} |")
    return "\n".join(lines)


MARKERS = {
    "<!-- ROOFLINE_TABLE_SINGLE -->": lambda: roofline_md("single"),
    "<!-- ROOFLINE_TABLE_MULTI -->": lambda: roofline_md("multi"),
    "<!-- DRYRUN_TABLE -->": dryrun_md,
    "<!-- PERF_FFT -->": fft_md,
    "<!-- ROOFLINE_NOTES -->": lambda: ROOFLINE_NOTES,
}

ROOFLINE_NOTES = """\
* **Every baseline cell is memory-dominated (HLO upper bound).** Three
  honest reasons, separated by the lb column: (i) fp32 softmax/score
  chains and norm chains stream (B,S,D)-sized fp32 fusions on this CPU
  lowering — a TPU build fuses more (the flash kernel keeps score tiles in
  VMEM entirely); (ii) full-layer remat re-streams the forward; (iii) real
  algorithmic traffic (caches, stashes). The analytic lower bound (perfect
  fusion) shows the other extreme; truth for a TPU build lies between.
* **MODEL/HLO flops** ~0.7–0.8 for dense trains = remat + attention +
  dispatch overheads (full remat ≈ 4/3 fwd reuse + masked attention 2x);
  ~0.3–0.5 for prefill (masked attention, fixed by the `tri` §Perf flag);
  ≥1.0 for SSM archs (6·N·D overestimates attention-free archs).
* **decode/long cells have roofline frac ≈ 0**: one token per step cannot
  amortize reading N_active params — decode is bandwidth-bound by nature;
  the §Perf lever is cache traffic (hmajor) and batching, not FLOPs.
* **collective term** is within 2.4x of the dominant memory term for the
  big dense trains (qwen2: 29s vs 50s) — FSDP gathers + fp32 TP activation
  all-reduces; §Perf iterations 1.1/1.3 attack it (dots remat −12%,
  Megatron-SP refuted on this lowering).
* long_500k runs only on the sub-quadratic archs (zamba2, falcon-mamba) —
  their decode state is O(1)/O(S·d_state) vs O(S·H·dh): falcon long_500k
  memory term 0.39 ms vs a hypothetical 32k-cache dense decode at ~100 ms.
* Known accounting approximations: conditional branches double-counted
  (upper bound); Pallas custom-calls opaque to cost analysis (flash kernel
  benefits argued structurally, never claimed numerically)."""


def main():
    text = (REPO / "EXPERIMENTS.md").read_text()
    for marker, fn in MARKERS.items():
        if marker not in text:
            print(f"marker missing: {marker}")
            continue
        content = fn()
        # replace marker (and any previously generated block up to next header)
        pattern = re.escape(marker) + r"(?:\n<!-- gen -->.*?<!-- /gen -->)?"
        repl = marker + "\n<!-- gen -->\n" + content + "\n<!-- /gen -->"
        text = re.sub(pattern, lambda m: repl, text, count=1, flags=re.S)
    (REPO / "EXPERIMENTS.md").write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
