"""Normalize a ``fftbench --compare`` JSON blob into a flat BENCH record.

The perf trajectory across PRs needs comparable data points; the raw
--compare output nests per-(method, comm_dtype) rows with schedules and
model terms.  This script reduces it to the stable schema

    {"schema": "bench-v1", "pr": N, "shape": [...], "grid": "...",
     "ndev": N, "real": bool,
     "methods": {"fused@complex64": {"best_s": ..., "model_time_s": ...,
                 "wire_bytes_per_dev": ...}, ...},
     "best": {"method": "...", "best_s": ...}}

Usage:
    python benchmarks/normalize_bench.py fftbench.json --pr 3 --out BENCH_pr3.json
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def normalize(raw: dict, pr: int | None = None) -> dict:
    rows = {}
    for tag, rec in raw["methods"].items():
        rows[tag] = {
            "best_s": rec["best_s"],
            "model_time_s": rec.get("model_time_s"),
            "wire_bytes_per_dev": rec.get("wire_bytes_per_dev"),
            "schedule": rec.get("schedule"),
        }
    best_tag = min(rows, key=lambda t: rows[t]["best_s"])
    out = {
        "schema": "bench-v1",
        "shape": list(raw["shape"]),
        "grid": raw["grid"],
        "ndev": raw["ndev"],
        "real": bool(raw.get("real", False)),
        # identifies the workload: a dct/pruned plan of the same shape is
        # not comparable to the plain c2c plan
        "transforms": raw.get("transforms"),
        "methods": rows,
        "best": {"method": best_tag, "best_s": rows[best_tag]["best_s"]},
    }
    if pr is not None:
        out["pr"] = pr
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("raw", help="fftbench --compare JSON output (file)")
    ap.add_argument("--pr", type=int, default=None, help="PR number tag")
    ap.add_argument("--out", default=None, help="output path (default: stdout)")
    args = ap.parse_args(argv)
    # the compare table is the last JSON line (fftbench may log above it)
    last = Path(args.raw).read_text().strip().splitlines()[-1]
    rec = normalize(json.loads(last), pr=args.pr)
    text = json.dumps(rec, indent=1, sort_keys=True)
    if args.out:
        Path(args.out).write_text(text + "\n")
    else:
        print(text)


if __name__ == "__main__":
    main()
