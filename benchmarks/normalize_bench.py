"""Normalize ``fftbench --compare`` JSON blobs into flat BENCH records.

The perf trajectory across PRs needs comparable data points; the raw
--compare output nests per-(method, comm_dtype[, batch_fusion]) rows with
schedules and model terms.  This script reduces each blob to the stable
schema

    {"schema": "bench-v1", "pr": N, "shape": [...], "grid": "...",
     "ndev": N, "real": bool, "fields": N,
     "methods": {"fused@complex64": {"best_s": ..., "model_time_s": ...,
                 "wire_bytes_per_dev": ...}, ...},
     "exchange": {"fields": N, "stacked_s": ..., "per_field_s": ...},
     "guard_mode": "off" | "strict" | "degrade",
     "best": {"method": "...", "best_s": ...}}

(``guard_mode`` is stamped on every record — a number timed under runtime
guards is a different experiment from an unguarded one; guarded runs also
carry the raw ``guard`` section with the measured overhead_frac.)

(``fields``/``exchange`` appear for multi-field runs: the ``exchange``
section is the exchanges-only timing of the batched single-collective
path vs the per-field loop.)  Several raw files normalize into one
``{"schema": "bench-v2", "records": [...]}`` container so one BENCH file
can carry multiple grid shapes.

Usage:
    python benchmarks/normalize_bench.py fftbench.json --pr 3 --out BENCH_pr3.json
    python benchmarks/normalize_bench.py slab.json pencil.json --pr 4 --out BENCH_pr4.json
"""

from __future__ import annotations

import argparse
import json
import subprocess
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent


def git_sha() -> str | None:
    """Short SHA of the checkout that produced this record (a perf number
    without provenance can't be attributed to a change), or None outside a
    git checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=_REPO, capture_output=True, text=True,
                             timeout=10)
    except OSError:
        return None
    return out.stdout.strip() or None if out.returncode == 0 else None


def normalize(raw: dict, pr: int | None = None) -> dict:
    rows = {}
    if "methods" in raw:
        for tag, rec in raw["methods"].items():
            rows[tag] = {
                "comm_dtype": rec.get("comm_dtype"),
                "exchange_impl": rec.get("exchange_impl", "jnp"),
                "best_s": rec["best_s"],
                "model_time_s": rec.get("model_time_s"),
                "wire_bytes_per_dev": rec.get("wire_bytes_per_dev"),
                "schedule": rec.get("schedule"),
                # planlint certification of the timed artifact (fftbench
                # --compare rows carry it unless run with --no-audit)
                "audit": rec.get("audit"),
            }
    else:
        # single-method fftbench blob (e.g. a --guard overhead run)
        tag = f"{raw['method']}@{raw.get('comm_dtype') or 'complex64'}"
        rows[tag] = {
            "best_s": raw["best_s"],
            "model_time_s": raw.get("model_time_s"),
            "wire_bytes_per_dev": raw.get("comm_bytes_per_dev"),
            "schedule": None,
            "audit": None,
        }
    best_tag = min(rows, key=lambda t: rows[t]["best_s"])
    out = {
        "schema": "bench-v1",
        "shape": list(raw["shape"]),
        "grid": raw["grid"],
        "ndev": raw["ndev"],
        "real": bool(raw.get("real", False)),
        # identifies the workload: a dct/pruned plan of the same shape is
        # not comparable to the plain c2c plan, nor a 3-field batched run
        # to a single-field one
        "transforms": raw.get("transforms"),
        "fields": raw.get("fields", 1),
        # hardware + code provenance: records from different device kinds
        # or commits are different experiments, not regressions
        "device_kind": raw.get("device_kind"),
        "backend": raw.get("backend"),
        "git_sha": git_sha(),
        "methods": rows,
        "best": {"method": best_tag, "best_s": rows[best_tag]["best_s"]},
    }
    # guard provenance: a record timed under runtime guards is a different
    # experiment from an unguarded one — stamp the mode on every record
    out["guard_mode"] = (raw.get("guard") or {}).get("mode", "off")
    if raw.get("guard"):
        out["guard"] = raw["guard"]
    if raw.get("exchange"):
        out["exchange"] = raw["exchange"]
    if pr is not None:
        out["pr"] = pr
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("raw", nargs="+",
                    help="fftbench --compare JSON output file(s)")
    ap.add_argument("--pr", type=int, default=None, help="PR number tag")
    ap.add_argument("--out", default=None, help="output path (default: stdout)")
    args = ap.parse_args(argv)
    records = []
    for path in args.raw:
        # the compare table is the last JSON line (fftbench may log above it)
        last = Path(path).read_text().strip().splitlines()[-1]
        records.append(normalize(json.loads(last), pr=args.pr))
    if len(records) == 1:
        rec = records[0]
    else:
        rec = {"schema": "bench-v2", "records": records}
        if args.pr is not None:
            rec["pr"] = args.pr
    text = json.dumps(rec, indent=1, sort_keys=True)
    if args.out:
        Path(args.out).write_text(text + "\n")
    else:
        print(text)


if __name__ == "__main__":
    main()
