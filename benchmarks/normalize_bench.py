"""Normalize ``fftbench --compare`` JSON blobs into flat BENCH records.

The perf trajectory across PRs needs comparable data points; the raw
--compare output nests per-(method, comm_dtype[, batch_fusion]) rows with
schedules and model terms.  This script reduces each blob to the stable
schema

    {"schema": "bench-v1", "pr": N, "shape": [...], "grid": "...",
     "ndev": N, "real": bool, "fields": N,
     "methods": {"fused@complex64": {"best_s": ..., "model_time_s": ...,
                 "wire_bytes_per_dev": ...}, ...},
     "exchange": {"fields": N, "stacked_s": ..., "per_field_s": ...},
     "guard_mode": "off" | "strict" | "degrade",
     "best": {"method": "...", "best_s": ...}}

(``guard_mode`` is stamped on every record — a number timed under runtime
guards is a different experiment from an unguarded one; guarded runs also
carry the raw ``guard`` section with the measured overhead_frac.)

(``fields``/``exchange`` appear for multi-field runs: the ``exchange``
section is the exchanges-only timing of the batched single-collective
path vs the per-field loop.)  Several raw files normalize into one
``{"schema": "bench-v2", "records": [...]}`` container so one BENCH file
can carry multiple grid shapes.

**bench-v3** (scaling sweeps): a raw ``benchmarks.scalebench`` blob
(marker key ``"scalebench"``) normalizes through
:func:`normalize_scaling` into

    {"schema": "bench-v3", "pr": N, "device_kind": ..., "backend": ...,
     "git_sha": ..., "priors": {fitted ici_bw/ici_latency_s/...},
     "n_misses": N,
     "series": {"strong@slab@16x16x16@fused@complex64@jnp": {
        "mode": "strong", "grid": "slab", "method": "fused", ...,
        "points": [{"shape", "ndev", "best_s", "p50_s", "spread_frac",
                    "model_time_s", "fit_time_s", "residual",
                    "wire_bytes_per_dev", "launches"}, ...],
        "fit": {"ici_bw", "ici_latency_s", "rmse_log", "misses": [...]},
        "redist": {"points": [...], "fit": {...}}  # when split was swept
     }, ...}}

— every point carries its measured time, the analytic ``model_time_s``,
and the residual vs the per-series least-squares fit
(:mod:`repro.core.modelfit`).  v1/v2 raw blobs keep normalizing exactly
as before, and ``benchmarks/benchdiff.py`` reads all three schemas.

Usage:
    python benchmarks/normalize_bench.py fftbench.json --pr 3 --out BENCH_pr3.json
    python benchmarks/normalize_bench.py slab.json pencil.json --pr 4 --out BENCH_pr4.json
    python benchmarks/normalize_bench.py scalebench_raw.json --pr 10 --out BENCH_pr10.json
"""

from __future__ import annotations

import argparse
import json
import subprocess
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent


def git_sha() -> str | None:
    """Short SHA of the checkout that produced this record (a perf number
    without provenance can't be attributed to a change), or None outside a
    git checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=_REPO, capture_output=True, text=True,
                             timeout=10)
    except OSError:
        return None
    return out.stdout.strip() or None if out.returncode == 0 else None


def normalize(raw: dict, pr: int | None = None) -> dict:
    rows = {}
    if "methods" in raw:
        for tag, rec in raw["methods"].items():
            rows[tag] = {
                "comm_dtype": rec.get("comm_dtype"),
                "exchange_impl": rec.get("exchange_impl", "jnp"),
                "best_s": rec["best_s"],
                "model_time_s": rec.get("model_time_s"),
                "wire_bytes_per_dev": rec.get("wire_bytes_per_dev"),
                "schedule": rec.get("schedule"),
                # planlint certification of the timed artifact (fftbench
                # --compare rows carry it unless run with --no-audit)
                "audit": rec.get("audit"),
            }
    else:
        # single-method fftbench blob (e.g. a --guard overhead run)
        tag = f"{raw['method']}@{raw.get('comm_dtype') or 'complex64'}"
        rows[tag] = {
            "best_s": raw["best_s"],
            "model_time_s": raw.get("model_time_s"),
            "wire_bytes_per_dev": raw.get("comm_bytes_per_dev"),
            "schedule": None,
            "audit": None,
        }
    best_tag = min(rows, key=lambda t: rows[t]["best_s"])
    out = {
        "schema": "bench-v1",
        "shape": list(raw["shape"]),
        "grid": raw["grid"],
        "ndev": raw["ndev"],
        "real": bool(raw.get("real", False)),
        # identifies the workload: a dct/pruned plan of the same shape is
        # not comparable to the plain c2c plan, nor a 3-field batched run
        # to a single-field one
        "transforms": raw.get("transforms"),
        "fields": raw.get("fields", 1),
        # hardware + code provenance: records from different device kinds
        # or commits are different experiments, not regressions
        "device_kind": raw.get("device_kind"),
        "backend": raw.get("backend"),
        "git_sha": git_sha(),
        "methods": rows,
        "best": {"method": best_tag, "best_s": rows[best_tag]["best_s"]},
    }
    # guard provenance: a record timed under runtime guards is a different
    # experiment from an unguarded one — stamp the mode on every record
    out["guard_mode"] = (raw.get("guard") or {}).get("mode", "off")
    if raw.get("guard"):
        out["guard"] = raw["guard"]
    if raw.get("exchange"):
        out["exchange"] = raw["exchange"]
    if pr is not None:
        out["pr"] = pr
    return out


def _series_point(raw_point: dict, fitted: dict | None) -> dict:
    """One bench-v3 series point: measured time + model terms + the fit
    residual :func:`repro.core.modelfit.fit_series` computed for it."""
    model = raw_point.get("model") or {}
    out = {
        "shape": list(raw_point["shape"]),
        "ndev": raw_point["ndev"],
        "best_s": raw_point["best_s"],
        "p50_s": raw_point.get("p50_s"),
        "spread_frac": raw_point.get("spread_frac"),
        "model_time_s": model.get("time_s"),
        "compute_s": model.get("compute_s"),
        "wire_bytes_per_dev": model.get("wire_bytes_per_dev"),
        "launches": model.get("launches"),
    }
    if fitted is not None:
        out["fit_time_s"] = fitted["fit_time_s"]
        out["residual"] = fitted["residual"]
    return out


def normalize_scaling(raw: dict, pr: int | None = None) -> dict:
    """Normalize a raw ``benchmarks.scalebench`` sweep into one bench-v3
    record with per-series least-squares model fits and per-point
    residuals.  The returned dict additionally carries the full fit report
    under ``"_fit_report"`` (callers persist it separately and drop the
    key before committing the BENCH record)."""
    from repro.core import modelfit

    first = raw["series"][0]["points"][0]
    series_out = {}
    fit_inputs = {}
    for s in raw["series"]:
        name = s["name"]
        entry = {k: s.get(k) for k in ("mode", "grid", "method", "fields",
                                       "base_shape")}
        entry["comm_dtype"] = s.get("comm_dtype") or "complex64"
        entry["exchange_impl"] = s.get("exchange_impl") or "jnp"
        for key, pts_key in (("points", "points"),
                             ("redist", "redist_points")):
            pts = s.get(pts_key)
            if not pts:
                continue
            fit = modelfit.fit_series(pts)
            fitted_rows = fit.pop("points")
            rows = [_series_point(p, f) for p, f in zip(pts, fitted_rows)]
            if key == "points":
                entry["points"] = rows
                entry["fit"] = fit
                fit_inputs[name] = pts
            else:
                entry["redist"] = {"points": rows, "fit": fit}
                fit_inputs[name + "#redist"] = pts
        series_out[name] = entry
    report = modelfit.fit_report(fit_inputs,
                                 device_kind=first.get("device_kind"),
                                 backend=first.get("backend"))
    out = {
        "schema": "bench-v3",
        "preset": raw.get("preset"),
        "device_kind": first.get("device_kind"),
        "backend": first.get("backend"),
        "git_sha": git_sha(),
        "series": series_out,
        "priors": report["priors"],
        "n_misses": report["n_misses"],
        "_fit_report": report,
    }
    if pr is not None:
        out["pr"] = pr
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("raw", nargs="+",
                    help="fftbench --compare JSON output file(s), or one "
                         "scalebench raw sweep")
    ap.add_argument("--pr", type=int, default=None, help="PR number tag")
    ap.add_argument("--out", default=None, help="output path (default: stdout)")
    ap.add_argument("--fit-report", default=None,
                    help="for a scalebench sweep: also write the full "
                         "model-fit residual report here")
    args = ap.parse_args(argv)
    records = []
    for path in args.raw:
        text = Path(path).read_text().strip()
        try:  # a pretty-printed scalebench sweep is one JSON document
            blob = json.loads(text)
        except ValueError:
            # fftbench prints its table as the last JSON line (it may log
            # free-form text above it)
            blob = json.loads(text.splitlines()[-1])
        if blob.get("scalebench"):
            rec = normalize_scaling(blob, pr=args.pr)
            report = rec.pop("_fit_report")
            if args.fit_report:
                Path(args.fit_report).write_text(
                    json.dumps(report, indent=1, sort_keys=True) + "\n")
            records.append(rec)
        else:
            records.append(normalize(blob, pr=args.pr))
    if len(records) == 1:
        rec = records[0]
    else:
        rec = {"schema": "bench-v2", "records": records}
        if args.pr is not None:
            rec["pr"] = args.pr
    text = json.dumps(rec, indent=1, sort_keys=True)
    if args.out:
        Path(args.out).write_text(text + "\n")
    else:
        print(text)


if __name__ == "__main__":
    main()
