"""Scaling-proof harness: weak/strong sweeps with analytic-model fits.

The paper's entire evaluation (Sec. 5, Figs. 6-11) is strong/weak scaling;
this driver is our machine-checked version of it.  It sweeps

    grid size x device count x fields x (slab | pencil)

in subprocesses (one fresh python per point with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the same device
scaling :mod:`benchmarks.paperfigs` uses, and the same inner/outer
best-of-N methodology as :mod:`benchmarks.fftbench`, which is the worker).
Each point carries the measured time *and* the analytic model terms
(:meth:`ParallelFFT.model_time_s` decomposed into the linear surrogate of
:mod:`repro.core.modelfit`), so after the sweep the harness

* least-squares fits the bandwidth/latency coefficients per series,
* flags >2x model misses into a machine-readable residual report
  (``modelfit_report.json`` — arm it as tuner priors via
  ``REPRO_MODEL_PRIORS`` to prune future candidate sweeps),
* normalizes everything into one ``bench-v3`` record
  (:func:`benchmarks.normalize_bench.normalize_scaling`) — the input of
  the ``benchmarks/benchdiff.py`` regression gate in CI,
* and (``--figures``) renders paper-style weak/strong scaling and
  redistribution-split figures via :mod:`benchmarks.paperfigs`.

Presets:

``smoke``   — the CI PR-gate sweep: tiny shapes, ndev in {1,2,4}/{2,4,8},
              strong+weak on slab and pencil, one 3-field series, a
              redistribution split on the strong 16^3 series.  This is
              also what produces the committed ``BENCH_prN.json`` records.
``nightly`` — larger shapes up to 8 devices, an ``auto`` tuned series and
              a bf16-payload series on top of the smoke matrix.

Usage:
    python -m benchmarks.scalebench --preset smoke --out benchmarks/artifacts/scaling
    python -m benchmarks.scalebench --preset nightly --figures --pr 10
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO / "benchmarks" / "artifacts" / "scaling"


def _series_name(s: dict) -> str:
    """Stable series key: mode@grid@shape@method@dtype@impl[@fN] — the
    ``method@dtype@impl`` triple is what benchdiff matches records on."""
    shape_tag = "x".join(map(str, s["shape"]))
    if s["mode"] == "weak":
        shape_tag = "loc" + shape_tag  # per-device local shape
    name = (f"{s['mode']}@{s['grid']}@{shape_tag}"
            f"@{s['method']}@{s.get('comm_dtype') or 'complex64'}"
            f"@{s.get('exchange_impl', 'jnp')}")
    if s.get("fields", 1) > 1:
        name += f"@f{s['fields']}"
    return name


def _point_shape(s: dict, ndev: int) -> tuple[int, ...]:
    """Strong scaling holds the global shape; weak scaling scales the
    leading axis with the device count (paper Figs. 8-9: fixed per-core
    local size)."""
    shape = tuple(s["shape"])
    if s["mode"] == "weak":
        return (shape[0] * ndev, *shape[1:])
    return shape


def preset_series(preset: str) -> list[dict]:
    slab_devs, pencil_devs = (1, 2, 4), (2, 4, 8)
    if preset == "smoke":
        base, big = (16, 16, 16), (32, 16, 16)
        weak_local = (8, 16, 16)
        series = []
        for grid, devs in (("slab", slab_devs), ("pencil", pencil_devs)):
            for method in ("fused", "traditional"):
                series.append({"mode": "strong", "grid": grid, "shape": base,
                               "method": method, "devices": devs, "split": True})
                series.append({"mode": "strong", "grid": grid, "shape": big,
                               "method": method, "devices": devs})
            series.append({"mode": "weak", "grid": grid, "shape": weak_local,
                           "method": "fused", "devices": devs})
        series.append({"mode": "strong", "grid": "slab", "shape": base,
                       "method": "fused", "devices": slab_devs, "fields": 3})
        return series
    if preset == "nightly":
        slab_devs, pencil_devs = (1, 2, 4, 8), (2, 4, 8)
        base, big = (32, 32, 32), (64, 32, 32)
        weak_local = (16, 32, 32)
        series = []
        for grid, devs in (("slab", slab_devs), ("pencil", pencil_devs)):
            for method in ("fused", "traditional"):
                series.append({"mode": "strong", "grid": grid, "shape": base,
                               "method": method, "devices": devs, "split": True})
                series.append({"mode": "strong", "grid": grid, "shape": big,
                               "method": method, "devices": devs})
            series.append({"mode": "weak", "grid": grid, "shape": weak_local,
                           "method": "fused", "devices": devs, "split": True})
            # tuned schedules and the lossy-wire trade at scale
            series.append({"mode": "strong", "grid": grid, "shape": base,
                           "method": "auto", "devices": devs, "tune": True})
            series.append({"mode": "strong", "grid": grid, "shape": base,
                           "method": "fused", "comm_dtype": "bf16",
                           "devices": devs})
        series.append({"mode": "strong", "grid": "slab", "shape": base,
                       "method": "fused", "devices": slab_devs, "fields": 3})
        series.append({"mode": "strong", "grid": "pencil", "shape": base,
                       "method": "fused", "devices": pencil_devs, "fields": 3})
        return series
    raise SystemExit(f"unknown preset {preset!r} (smoke | nightly)")


def run_point(shape, ndev: int, *, grid: str, method: str, measure: str,
              fields: int = 1, comm_dtype: str | None = None,
              exchange_impl: str = "jnp", inner: int, outer: int,
              tune_cache: str | None = None, timeout: int = 1800) -> dict:
    """One fftbench worker subprocess at ``ndev`` virtual host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep + str(REPO)
                         + (os.pathsep + env["PYTHONPATH"]
                            if env.get("PYTHONPATH") else ""))
    cmd = [sys.executable, "-m", "benchmarks.fftbench",
           "--shape", ",".join(map(str, shape)), "--grid", grid,
           "--method", method, "--measure", measure,
           "--inner", str(inner), "--outer", str(outer)]
    if fields > 1:
        cmd += ["--fields", str(fields)]
    if comm_dtype:
        cmd += ["--comm-dtype", comm_dtype]
    if exchange_impl != "jnp":
        cmd += ["--exchange-impl", exchange_impl]
    if tune_cache:
        cmd += ["--tune-cache", tune_cache]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"scalebench point failed: {' '.join(cmd)}\n"
                           f"{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_sweep(series_list: list[dict], *, inner: int, outer: int,
              tune_cache: str | None = None, log=print) -> dict:
    """Execute every series point; returns the raw sweep blob
    ``normalize_bench.normalize_scaling`` consumes."""
    t_start = time.time()
    out_series = []
    total_pts = sum(len(s["devices"]) * (2 if s.get("split") else 1)
                    - (1 if s.get("split") and 1 in s["devices"] else 0)
                    for s in series_list)
    done = 0
    for s in series_list:
        name = _series_name(s)
        points, redist_points = [], []
        for ndev in s["devices"]:
            shape = _point_shape(s, ndev)
            measures = ["total"]
            # redistribution split: exchanges-only timing (the paper's
            # "global redistribution" decomposition); meaningless on one
            # device, where no exchange exists
            if s.get("split") and ndev > 1:
                measures.append("redistribution")
            for measure in measures:
                r = run_point(shape, ndev, grid=s["grid"], method=s["method"],
                              measure=measure, fields=s.get("fields", 1),
                              comm_dtype=s.get("comm_dtype"),
                              exchange_impl=s.get("exchange_impl", "jnp"),
                              inner=inner, outer=outer,
                              tune_cache=tune_cache if s.get("tune") else None)
                done += 1
                (points if measure == "total" else redist_points).append(r)
                log(f"[{done}/{total_pts}] {name} ndev={ndev} {measure}: "
                    f"{r['best_s']:.5f}s (model {r['model']['time_s']:.2e}s)",
                    flush=True)
        entry = {"name": name, "points": points,
                 **{k: s.get(k) for k in ("mode", "grid", "method",
                                          "comm_dtype", "exchange_impl")},
                 "fields": s.get("fields", 1),
                 "base_shape": list(s["shape"])}
        if redist_points:
            entry["redist_points"] = redist_points
        out_series.append(entry)
    return {"scalebench": True, "series": out_series,
            "elapsed_s": time.time() - t_start,
            "inner": inner, "outer": outer}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", default="smoke", help="smoke | nightly")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT,
                    help="artifact directory (raw sweep, BENCH record, "
                         "fit report, figures)")
    ap.add_argument("--pr", type=int, default=None,
                    help="PR number stamped on the BENCH record")
    ap.add_argument("--inner", type=int, default=2)
    ap.add_argument("--outer", type=int, default=5)
    ap.add_argument("--tune-cache", default=None,
                    help="schedule-cache path for tuned (method=auto) series")
    ap.add_argument("--figures", action="store_true",
                    help="render scaling/redistribution figures (matplotlib)")
    ap.add_argument("--update-priors", type=Path, default=None,
                    help="also write the fitted coefficients to this path "
                         "(arm with REPRO_MODEL_PRIORS for tuner priors)")
    args = ap.parse_args(argv)

    from benchmarks.normalize_bench import normalize_scaling

    args.out.mkdir(parents=True, exist_ok=True)
    raw = run_sweep(preset_series(args.preset), inner=args.inner,
                    outer=args.outer, tune_cache=args.tune_cache)
    raw["preset"] = args.preset
    (args.out / "scalebench_raw.json").write_text(json.dumps(raw, indent=1))

    bench = normalize_scaling(raw, pr=args.pr)
    report = bench.pop("_fit_report")  # full per-point residual report
    bench_path = args.out / "BENCH.json"
    bench_path.write_text(json.dumps(bench, indent=1, sort_keys=True) + "\n")
    report_path = args.out / "modelfit_report.json"
    report_path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    if args.update_priors:
        from repro.core import modelfit

        modelfit.save_priors(report, args.update_priors)
        print(f"priors -> {args.update_priors} "
              f"(arm with REPRO_MODEL_PRIORS={args.update_priors})")

    pri = report["priors"]
    print(f"fit: ici_bw={pri['ici_bw']:.3e} B/s, "
          f"ici_latency={pri['ici_latency_s']:.3e} s, "
          f"{report['n_misses']} model miss(es)")
    print(f"BENCH -> {bench_path}\nreport -> {report_path}")

    if args.figures:
        from benchmarks.paperfigs import render_scaling_figures

        figs = render_scaling_figures(bench, args.out / "figs")
        print("figures ->", ", ".join(str(f) for f in figs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
