import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Production-scale FFT dry-run roofline (paper Figs. 10-11 analogue).

Lowers the paper's big transforms on the production mesh and compares the
fused (paper) vs traditional (P3DFFT-style) redistribution at the HLO level:

  fig10: 2048^3 r2c pencil FFT on 16x16 = 256 chips
  fig11: 128^4  c2c FFT on an (8,8,4) 3-D processor grid (256 chips)

For each: trip-aware FLOPs, HBM bytes, collective payloads, the three
roofline terms, and the fused-vs-traditional delta (the paper's claim,
restated for TPU: the traditional path pays extra HBM traffic for the
pack/unpack copies while moving the same collective payload).
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO = Path(__file__).resolve().parent.parent
ART = REPO / "benchmarks" / "artifacts" / "figs"

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def lower_fft(shape, mesh_shape, axis_names, grid, *, real, method, impl="jnp"):
    from repro.core.meshutil import make_mesh
    from repro.core.pfft import ParallelFFT
    from repro.core.planconfig import PlanConfig
    from repro.launch.hlo_account import account

    mesh = make_mesh(mesh_shape, axis_names)
    # real=True spelled as an explicit transform list (r2c on the last axis)
    transforms = (("c2c",) * (len(shape) - 1) + ("r2c",)) if real else None
    plan = ParallelFFT(mesh, shape, grid, transforms=transforms,
                       config=PlanConfig(method=method, impl=impl))
    dtype = jnp.float32 if real else jnp.complex64
    x = jax.ShapeDtypeStruct(plan.input_pencil.physical, dtype)

    def fwd_bwd(v):
        return plan.backward_padded(plan.forward_padded(v))

    jfn = jax.jit(fwd_bwd,
                  in_shardings=plan.input_pencil.sharding,
                  out_shardings=plan.input_pencil.sharding)
    compiled = jfn.lower(x).compile()
    acct = account(compiled.as_text())
    chips = int(np.prod(mesh_shape))
    rec = {
        "shape": shape, "mesh": mesh_shape, "grid": [str(g) for g in grid],
        "real": real, "method": method, "impl": impl, "chips": chips,
        "flops_per_device": acct["flops"],
        "hbm_bytes_per_device": acct["hbm_bytes"],
        "collectives_per_device": acct["collectives"],
        "compute_s": acct["flops"] / PEAK,
        "memory_s": acct["hbm_bytes"] / HBM,
        "collective_s": acct["collectives"].get("total", 0.0) / ICI,
        "model_flops": 2 * plan.model_flops(),  # fwd + bwd
        # exchange payloads are complex64 even for r2c (exchanges run after
        # the r2c stage), so all modeled comm terms use itemsize 8
        "comm_model_bytes_per_dev": 2 * plan.comm_bytes_per_device(8),
        # overlap-aware analytic wall time (core/redistribute.exchange_time_model):
        # what the same plan would cost with the pipelined exchange engine
        "model_time_s": 2 * plan.model_time_s(itemsize=8),
        "model_time_pipelined_s": 2 * ParallelFFT(
            mesh, shape, grid, transforms=transforms,
            config=PlanConfig(method="pipelined", impl=impl),
        ).model_time_s(itemsize=8),
        # comm-compression lever: same pipelined plan with bf16 wire payloads
        # (2x fewer ICI bytes, priced against the extra quant HBM passes)
        "model_time_pipelined_bf16_s": 2 * ParallelFFT(
            mesh, shape, grid, transforms=transforms,
            config=PlanConfig(method="pipelined", impl=impl, comm_dtype="bf16"),
        ).model_time_s(itemsize=8),
        "comm_model_bytes_per_dev_bf16": 2 * plan.comm_bytes_per_device(
            8, comm_dtype="bf16"),
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: rec[k])
    rec["dominant"] = dom.replace("_s", "")
    ideal = rec["model_flops"] / (chips * PEAK)
    rec["roofline_frac"] = ideal / rec[dom]
    return rec


def main(_argv=None):
    ART.mkdir(parents=True, exist_ok=True)
    scale = os.environ.get("REPRO_BENCH_SCALE", "small")
    if scale == "paper":
        fig10_shape, fig11_shape = (2048, 2048, 2048), (128, 128, 128, 128)
    else:  # container default: same structure, 4x smaller to keep compile fast
        fig10_shape, fig11_shape = (512, 512, 512), (64, 64, 64, 64)
    out = {}
    # TPU-native serial-FFT variant: four-step matmul DFT on the MXU
    # (DESIGN.md §4) — ~10x the FLOPs of radix FFT but on the 197-TFLOP unit
    out["fig10_fused_matmulDFT"] = lower_fft(
        fig10_shape, (16, 16), ("p0", "p1"), ("p0", "p1"),
        real=True, method="fused", impl="matmul")
    r = out["fig10_fused_matmulDFT"]
    print(f"fig10_fused_matmulDFT: dominant={r['dominant']} "
          f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
          f"collective={r['collective_s']:.3e}s", flush=True)
    for method in ("fused", "traditional"):
        out[f"fig10_{method}"] = lower_fft(
            fig10_shape, (16, 16), ("p0", "p1"), ("p0", "p1"),
            real=True, method=method)
        out[f"fig11_{method}"] = lower_fft(
            fig11_shape, (8, 8, 4), ("p0", "p1", "p2"), ("p0", "p1", "p2"),
            real=False, method=method)
        for k in (f"fig10_{method}", f"fig11_{method}"):
            r = out[k]
            print(f"{k}: dominant={r['dominant']} compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s collective={r['collective_s']:.3e}s "
                  f"frac={r['roofline_frac']:.3f}", flush=True)
    for fig in ("fig10", "fig11"):
        f, t = out[f"{fig}_fused"], out[f"{fig}_traditional"]
        print(f"{fig}: traditional/fused HBM bytes = "
              f"{t['hbm_bytes_per_device'] / max(f['hbm_bytes_per_device'], 1):.2f}x, "
              f"collective bytes = "
              f"{t['collectives_per_device'].get('total', 0) / max(f['collectives_per_device'].get('total', 1), 1):.2f}x")
    (ART / "fft_roofline.json").write_text(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
