"""Debug driver: every smoke arch through loss+grad, prefill, decode."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.meshutil import make_mesh, set_mesh
from repro.models.lm import LM
from repro.models.sharding import Axes

mesh = make_mesh((1, 1), ("data", "model"))
axes = Axes(multi_pod=False)

names = sys.argv[1:] or configs.ARCH_NAMES
for name in names:
    cfg = configs.smoke(name)
    lm = LM(cfg, mesh, axes, q_block=8, xent_chunks=2)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))

    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["frontend"] = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frontend"] = jax.random.normal(key, (B, S, cfg.d_model))

    with set_mesh(mesh):
        (loss, metrics), grads = jax.jit(jax.value_and_grad(lm.loss, has_aux=True))(params, batch)
        assert jnp.isfinite(loss), (name, loss)
        gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        assert jnp.isfinite(gnorm), name

        cur = S + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
        M = cur + 4
        cache, logits = jax.jit(lambda p, b: lm.prefill(p, b, max_len=M))(params, batch)
        assert jnp.all(jnp.isfinite(logits)), name
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        cache2, lg = jax.jit(lm.decode_step)(params, cache, tok, jnp.int32(cur))
        assert jnp.all(jnp.isfinite(lg)), name
    print(f"{name:24s} ok  loss={float(loss):.3f} params={n_params:,}")
print("ALL MODEL SANITY OK")
