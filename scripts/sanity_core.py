"""Quick sanity: exchange + ParallelFFT on 8 virtual host devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meshutil import make_mesh
from repro.core.pencil import make_pencil, pad_global, unpad_global
from repro.core.redistribute import exchange
from repro.core.pfft import ParallelFFT

mesh = make_mesh((2, 4), ("p0", "p1"))
print("mesh", mesh)

# --- exchange correctness: fused vs traditional vs numpy oracle ---
rng = np.random.default_rng(0)
shape = (8, 12, 16)
x = rng.standard_normal(shape).astype(np.float32)

src = make_pencil(mesh, shape, ("p0", "p1", None), divisors=(4, 2, 1))
xp = pad_global(jnp.asarray(x), src)
xs = jax.device_put(xp, src.sharding)

for method in ("fused", "traditional", "pipelined"):
    y, dst = exchange(xs, src, v=2, w=1, method=method, chunks=2)
    # oracle: exchange just realigns; global array unchanged
    got = unpad_global(np.asarray(y), dst)
    np.testing.assert_allclose(got, x, rtol=1e-6)
    print(f"exchange[{method}] ok; dst placement={dst.placement}")

# --- ParallelFFT: pencil 2D grid c2c ---
for real in (False, True):
    for gridspec in (("p0",), ("p0", "p1"), (("p0", "p1"),)):
        transforms = ("c2c", "c2c", "r2c") if real else None
        plan = ParallelFFT(mesh, (16, 12, 20), gridspec, transforms=transforms)
        xin = rng.standard_normal((16, 12, 20)).astype(np.float32)
        if not real:
            xin = (xin + 1j * rng.standard_normal((16, 12, 20))).astype(np.complex64)
        xg = jax.device_put(pad_global(jnp.asarray(xin), plan.input_pencil), plan.input_pencil.sharding)
        yhat = plan.forward(jnp.asarray(xin))
        want = np.fft.rfftn(xin) if real else np.fft.fftn(xin)
        np.testing.assert_allclose(np.asarray(yhat), want / 1.0, rtol=2e-4, atol=2e-3)
        back = plan.backward(yhat)
        np.testing.assert_allclose(np.asarray(back), xin, rtol=2e-4, atol=2e-3)
        print(f"pfft real={real} grid={gridspec} ok")

# 4D on 3D grid
mesh3 = make_mesh((2, 2, 2), ("a", "b", "c"))
plan = ParallelFFT(mesh3, (8, 8, 8, 8), ("a", "b", "c"))
xin = (rng.standard_normal((8, 8, 8, 8)) + 1j * rng.standard_normal((8, 8, 8, 8))).astype(np.complex64)
yhat = plan.forward(jnp.asarray(xin))
np.testing.assert_allclose(np.asarray(yhat), np.fft.fftn(xin), rtol=2e-4, atol=2e-3)
print("pfft 4D/3Dgrid ok")

# kernels
from repro.kernels.fft import ops as fops
x1 = (rng.standard_normal((4, 96)) + 1j * rng.standard_normal((4, 96))).astype(np.complex64)
np.testing.assert_allclose(np.asarray(fops.fft_matmul(jnp.asarray(x1))), np.fft.fft(x1, axis=-1), rtol=2e-4, atol=2e-3)
x2 = rng.standard_normal((4, 384)).astype(np.float32)
np.testing.assert_allclose(np.asarray(fops.rfft_matmul(jnp.asarray(x2))), np.fft.rfft(x2, axis=-1), rtol=2e-4, atol=2e-2)
np.testing.assert_allclose(np.asarray(fops.irfft_matmul(jnp.asarray(np.fft.rfft(x2)), n=384)), x2, rtol=2e-4, atol=2e-3)
print("fft kernels ok")

from repro.kernels.transpose.ops import transpose01
x3 = rng.standard_normal((6, 10, 5)).astype(np.float32)
np.testing.assert_allclose(np.asarray(transpose01(jnp.asarray(x3))), x3.swapaxes(0, 1))
print("transpose kernel ok")
print("ALL SANITY OK")
