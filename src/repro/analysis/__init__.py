"""Static analysis of compiled plans and source (`planlint`).

:mod:`repro.analysis.planlint` audits a compiled :class:`ParallelFFT` plan
against its schedule contracts — collective launch counts, per-collective
wire bytes, the paper's no-realignment invariant, and dtype flow — by
walking the lowered jaxpr and the optimized HLO.
:mod:`repro.analysis.srclint` is the companion AST lint over source files
for shard_map pitfalls.  ``python -m repro.analysis.planlint`` runs both
over the example plans and emits a JSON report.
"""

__all__ = ["AuditReport", "Violation", "audit_plan", "Finding", "lint_paths"]

_EXPORTS = {
    "AuditReport": "repro.analysis.planlint",
    "Violation": "repro.analysis.planlint",
    "audit_plan": "repro.analysis.planlint",
    "Finding": "repro.analysis.srclint",
    "lint_paths": "repro.analysis.srclint",
}


def __getattr__(name):
    # lazy re-export: keeps `python -m repro.analysis.planlint` from
    # importing the submodule twice (runpy's double-import warning)
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
