"""planlint — static auditor proving a compiled plan matches its schedule.

The paper's thesis (Sec. 3.3.2) is that the generalized all-to-all over
discontiguous subarrays *eliminates local realignment passes*.  This module
turns that claim — and the rest of a plan's schedule contracts — into
machine-checked invariants over the compiled artifact, before any benchmark
runs:

``audit_plan(plan)`` lowers the plan's executor, walks the jaxpr and the
post-SPMD optimized HLO (via :mod:`repro.launch.hlo_account`), and checks:

PLAN001  jaxpr ``all_to_all`` launch count == the schedule's expected count
         (× pipeline slices for ``pipelined``, × 2 for int8's scale
         exchange, × nfields under non-stacked batch fusions).
PLAN002  the multiset of per-collective HLO payload bytes == the analytic
         :func:`repro.core.redistribute.exchange_wire_bytes` model for each
         stage's tuned ``comm_dtype``.
PLAN003  realignment transposes: ``transpose`` eqns source-attributed to the
         exchange engine (``core/redistribute.py`` / ``core/pfft.py``) ==
         the engine contract of
         :func:`repro.core.redistribute.exchange_engine_ops` — **zero** for
         fused (the no-realignment invariant), exactly the documented
         pack/unpack copies for traditional.
PLAN004  realignment concatenates attributed to the engine == the contract
         (pipelined's slice reassembly, non-stacked batch restacking).
PLAN005  silent f64/complex128 upcast anywhere in the lowered program.
PLAN006  unpaired quantize/dequantize: ``convert_element_type`` eqns into a
         narrow wire dtype (int8/bf16) must balance the converts back out.
PLAN007  trip-aware HLO ``all-to-all`` instruction count == expected
         launches (the post-optimization cross-check of PLAN001).
PLAN008  guard-op presence matches the plan's ``guard`` mode: eqns
         source-attributed to ``repro/robustness/`` (the fused health
         checks) must appear in a guarded executor's jaxpr and must be
         **absent** — zero eqns — when ``guard="off"``, proving the
         unguarded artifact is bit-identical to a pre-guard plan.
PLAN009  fused-kernel containment: ``pallas_call`` eqns attributed to
         ``kernels/exchange/`` == the schedule's expected kernel launches
         (2 per ``impl="pallas"`` lossy stage side-pair, × pipeline
         slices, × nfields under non-stacked fusions; **zero** for jnp
         stages), and when *every* lossy stage runs the fused kernels the
         artifact carries **zero** eqns attributed to ``core/quant.py`` —
         the whole codec (quantize, scales, plane marshalling) lives
         inside the kernel calls, so no engine-side pack/unpack/codec
         pass survives outside them.

Realignment is asserted at the **jaxpr** level: on the CPU backend XLA
decomposes the tiled all-to-all into slice/concat + a tuple-operand
collective, materializing transposes for *every* engine, so the optimized
HLO cannot distinguish fused from traditional there — the jaxpr, with
source attribution of each transpose/concatenate to the module that emitted
it, can.  Transposes inside the transform itself (``core/fftcore.py``'s
DCT/DST axis brackets, ``kernels/``) and the wire codec
(``core/quant.py``'s plane stacking) are the transform's own business and
are tracked but never counted against the engine.

The ``schedule=`` override audits the artifact against a *claimed* schedule
instead of the plan's own resolved one — the negative-test hook: auditing a
traditional plan under a fused-claiming schedule must report PLAN003.

CLI::

    python -m repro.analysis.planlint [--out report.json] [--devices N]

audits mirrors of the three example plans (quickstart / navier_stokes /
poisson, including a batched navier_stokes invocation), runs
:mod:`repro.analysis.srclint` over ``src/``, writes a JSON report, and
exits nonzero on any violation.
"""

from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass, field

#: modules whose transposes/concatenates are engine realignment ops: the
#: exchange implementations and the plan executor that reassembles them
ENGINE_MODULES = ("core/redistribute.py", "core/pfft.py")

#: module prefix whose eqns are runtime guard ops (PLAN008): the fused
#: health checks live in repro/robustness/ precisely so this attribution
#: can prove guard="off" artifacts contain none of them
GUARD_MODULE_PREFIX = "robustness/"

#: module prefix of the fused exchange kernels (PLAN009): pallas_call eqns
#: attributed here are the kernel launches a pallas-impl stage must emit
EXCHANGE_KERNEL_PREFIX = "kernels/exchange/"

#: the reference wire codec (PLAN009): a plan whose lossy stages all run
#: the fused kernels must trace zero eqns attributed to this module
QUANT_MODULE = "core/quant.py"

#: narrow wire dtypes whose converts must pair up (PLAN006)
_NARROW_WIRE_DTYPES = ("int8", "bfloat16")

#: result-dtype tokens that flag a silent upcast (PLAN005)
_WIDE_DTYPES = ("float64", "complex128")
_WIDE_HLO_TOKENS = ("f64[", "c128[")


@dataclass
class Violation:
    code: str
    message: str
    stage: int | None = None

    def to_dict(self) -> dict:
        d = {"code": self.code, "message": self.message}
        if self.stage is not None:
            d["stage"] = self.stage
        return d


@dataclass
class AuditReport:
    """Outcome of one :func:`audit_plan` call.

    ``expected`` is the analytic side (launch counts, wire bytes, engine-op
    contract, with a per-stage breakdown), ``observed`` the measured side
    (jaxpr and HLO), ``collectives`` the per-instruction HLO records of
    :func:`repro.launch.hlo_account.collective_instrs`, and ``violations``
    every contract the artifact broke (empty == the plan is certified)."""

    label: str
    direction: str
    nfields: int
    schedule: list
    expected: dict
    observed: dict
    collectives: list = field(default_factory=list)
    violations: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {
            "label": self.label, "direction": self.direction,
            "nfields": self.nfields, "ok": self.ok,
            "schedule": [list(e) for e in self.schedule],
            "expected": self.expected, "observed": self.observed,
            "collectives": self.collectives,
            "violations": [v.to_dict() for v in self.violations],
        }

    def summary(self) -> dict:
        """Compact per-plan audit record for BENCH JSON rows: enough to diff
        model-vs-artifact drift across PRs without the full report."""
        return {
            "ok": self.ok,
            "violations": sorted({v.code for v in self.violations}),
            "all_to_alls": self.observed.get("jaxpr_all_to_alls"),
            "wire_bytes": self.expected.get("wire_bytes"),
            "hlo_wire_bytes": self.observed.get("hlo_all_to_all_bytes"),
            "engine_transposes": self.observed.get("engine_transposes"),
            "engine_concats": self.observed.get("engine_concats"),
            "guard_eqns": self.observed.get("guard_eqns"),
            "exchange_pallas_calls": self.observed.get("exchange_pallas_calls"),
        }


# ---------------------------------------------------------------------------
# jaxpr walk
# ---------------------------------------------------------------------------


def _as_jaxprs(v):
    from jax._src import core as jcore

    if isinstance(v, jcore.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, jcore.Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _as_jaxprs(x)]
    return []


def _iter_eqns(jaxpr):
    """Every eqn of ``jaxpr`` and all sub-jaxprs (shard_map/pjit/scan/...)."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            for v in eqn.params.values():
                stack.extend(_as_jaxprs(v))


def _eqn_module(eqn) -> str | None:
    """Repo-relative module (``core/redistribute.py``) that emitted ``eqn``,
    from the innermost in-repo traceback frame; None for pure-jax eqns."""
    tb = getattr(eqn.source_info, "traceback", None)
    if tb is None:
        return None
    for fr in tb.frames:
        fname = fr.file_name.replace(os.sep, "/")
        if "/repro/" in fname and "/analysis/" not in fname:
            return fname.rsplit("/repro/", 1)[1]
    return None


def _jaxpr_stats(jaxpr) -> dict:
    """Counts planlint checks against: all_to_all launches, source-attributed
    transposes/concatenates, narrow-dtype convert pairs, wide-dtype eqns."""
    a2a = 0
    guard_eqns = 0
    kernel_pallas_calls = 0
    quant_eqns = 0
    transposes: dict[str, int] = {}
    concats: dict[str, int] = {}
    conv_in: dict[str, int] = {d: 0 for d in _NARROW_WIRE_DTYPES}
    conv_out: dict[str, int] = {d: 0 for d in _NARROW_WIRE_DTYPES}
    wide: list[str] = []
    for eqn in _iter_eqns(jaxpr):
        name = eqn.primitive.name
        mod = _eqn_module(eqn)
        if mod is not None and mod.startswith(GUARD_MODULE_PREFIX):
            guard_eqns += 1
        if mod == QUANT_MODULE:
            quant_eqns += 1
        if name == "pallas_call":
            if mod is not None and mod.startswith(EXCHANGE_KERNEL_PREFIX):
                kernel_pallas_calls += 1
        elif name == "all_to_all":
            a2a += 1
        elif name in ("transpose", "concatenate"):
            mod = mod or "<jax>"
            tgt = transposes if name == "transpose" else concats
            tgt[mod] = tgt.get(mod, 0) + 1
        elif name == "convert_element_type":
            out_dt = str(eqn.outvars[0].aval.dtype)
            in_dt = str(eqn.invars[0].aval.dtype)
            if out_dt in conv_in:
                conv_in[out_dt] += 1
            if in_dt in conv_out:
                conv_out[in_dt] += 1
        for ov in eqn.outvars:
            dt = str(getattr(ov.aval, "dtype", ""))
            if dt in _WIDE_DTYPES:
                wide.append(f"{name} -> {dt} at {_eqn_module(eqn) or '<jax>'}")
    eng_t = sum(n for m, n in transposes.items() if m in ENGINE_MODULES)
    eng_c = sum(n for m, n in concats.items() if m in ENGINE_MODULES)
    return {
        "jaxpr_all_to_alls": a2a,
        "guard_eqns": guard_eqns,
        "exchange_pallas_calls": kernel_pallas_calls,
        "quant_eqns": quant_eqns,
        "engine_transposes": eng_t,
        "engine_concats": eng_c,
        "transposes_by_module": transposes,
        "concats_by_module": concats,
        "narrow_converts_in": conv_in,
        "narrow_converts_out": conv_out,
        "wide_dtype_eqns": wide,
    }


# ---------------------------------------------------------------------------
# expected side (the analytic schedule contract)
# ---------------------------------------------------------------------------


def _plan_walk(plan, direction: str, schedule4):
    """(stages, pencils, dtypes, schedule) in execution order."""
    from repro.core.pfft import _reverse_plan

    if direction == "forward":
        return plan.stages, plan.pencil_trace, plan.dtype_trace, schedule4
    if direction == "backward":
        stages, pencils = _reverse_plan(plan.stages, plan.pencil_trace)
        return stages, pencils, plan.dtype_trace[::-1], schedule4[::-1]
    raise ValueError(f"unknown direction {direction!r}")


def _stage_payload_multiset(src_pen, v, w, isz, comm_dtype, nfields, fusion,
                            method, chunks, nbatch) -> list[int]:
    """Per-collective wire bytes this stage should put on the wire, one
    entry per expected all-to-all (payload and, for int8, scale)."""
    import numpy as np

    from repro.core.decomp import local_lengths
    from repro.core.pencil import group_size
    from repro.core.quant import wire_ratio

    m = group_size(src_pen.mesh, src_pen.placement[w])
    local = int(np.prod(src_pen.local_shape, dtype=np.int64))
    b = src_pen.local_shape[v] // m
    if method == "pipelined":
        lengths = [n for n in local_lengths(b, max(1, min(chunks, b))) if n > 0]
    else:
        lengths = [b]
    if nbatch and fusion != "stacked":
        calls, fields_per_call = nfields, 1
    else:
        calls, fields_per_call = 1, nfields
    ratio = wire_ratio(comm_dtype)
    out: list[tuple[int, int]] = []
    for _ in range(calls):
        for n in lengths:
            elems = local * fields_per_call * n // b
            narrow = elems * (m - 1) // m * isz // ratio
            # the bf16 payload is a pure rounding convert, which XLA may
            # legally hoist across the (data-movement-only) collective; the
            # single-host CPU backend does exactly that, shipping the
            # rounded values at f32 width.  (int8 cannot be hoisted: its
            # dequantize needs the separately-shipped scales.)  This holds
            # for impl="pallas" too on CPU: interpret mode lowers the
            # kernel to transparent HLO, so the same rewrite applies —
            # only a real (TPU) kernel launch is opaque to it, and there
            # the cpu-only acceptance below never triggers.
            widened = narrow * 2 if comm_dtype == "bf16" else narrow
            out.append((narrow, widened))
            if comm_dtype == "int8":
                out.append((4 * (m - 1) * fields_per_call,) * 2)
    return out


def _expected_contract(plan, direction: str, schedule4, nfields: int) -> dict:
    """The analytic side of the audit: per exchange stage, the launch count,
    wire bytes, payload multiset, and engine-op contract its schedule entry
    implies, plus plan-level totals."""
    from repro.core.pfft import ExchangeStage
    from repro.core.redistribute import (
        exchange_engine_ops, exchange_wire_bytes, pipeline_slices)
    from repro.kernels.exchange import pallas_applicable

    stages, pencils, dtypes, sched = _plan_walk(plan, direction, schedule4)
    nbatch = 1 if nfields > 1 else 0
    per_stage = []
    ex_i = 0
    for i, st in enumerate(stages):
        if not isinstance(st, ExchangeStage):
            continue
        method, chunks, comm_dtype, impl, fusion = sched[ex_i]
        ex_i += 1
        src_pen = pencils[i]
        isz = plan._stage_itemsize(i, dtypes)
        slices = (pipeline_slices(src_pen, st.v, st.w, chunks=chunks)
                  if method == "pipelined" else 1)
        per_field_launches = slices * (2 if comm_dtype == "int8" else 1)
        # a pallas stage emits one encode + one decode kernel per
        # payload collective side-pair (per slice for pipelined)
        fused_kernel = impl == "pallas" and pallas_applicable(method, comm_dtype)
        per_field_pcalls = 2 * slices if fused_kernel else 0
        if nbatch and fusion != "stacked":
            launches = per_field_launches * nfields
            pcalls = per_field_pcalls * nfields
            ops = exchange_engine_ops(src_pen, st.v, st.w, method=method,
                                      chunks=chunks, nbatch=0,
                                      comm_dtype=comm_dtype, impl=impl)
            transposes = ops["transposes"] * nfields
            # per-field outputs are restacked with one concatenate
            concats = ops["concats"] * nfields + 1
        else:
            launches = per_field_launches
            pcalls = per_field_pcalls
            ops = exchange_engine_ops(src_pen, st.v, st.w, method=method,
                                      chunks=chunks, nbatch=nbatch,
                                      comm_dtype=comm_dtype, impl=impl)
            transposes, concats = ops["transposes"], ops["concats"]
        wire = exchange_wire_bytes(src_pen, st.v, st.w, itemsize=isz,
                                   comm_dtype=comm_dtype, nfields=nfields,
                                   slices=slices)
        payloads = _stage_payload_multiset(
            src_pen, st.v, st.w, isz, comm_dtype, nfields, fusion, method,
            chunks, nbatch)
        per_stage.append({
            "stage": ex_i - 1, "v": st.v, "w": st.w, "method": method,
            "chunks": chunks, "comm_dtype": comm_dtype, "impl": impl,
            "batch_fusion": fusion,
            "itemsize": isz, "slices": slices, "launches": launches,
            "wire_bytes": wire,
            "payload_bytes": sorted(p for p, _ in payloads),
            "payload_bytes_widened": sorted(wp for _, wp in payloads),
            "engine_transposes": transposes, "engine_concats": concats,
            "pallas_calls": pcalls,
        })
    return {
        "launches": sum(s["launches"] for s in per_stage),
        "wire_bytes": sum(s["wire_bytes"] for s in per_stage),
        "payload_bytes": sorted(p for s in per_stage for p in s["payload_bytes"]),
        "payload_bytes_widened": sorted(
            p for s in per_stage for p in s["payload_bytes_widened"]),
        "engine_transposes": sum(s["engine_transposes"] for s in per_stage),
        "engine_concats": sum(s["engine_concats"] for s in per_stage),
        "pallas_calls": sum(s["pallas_calls"] for s in per_stage),
        "stages": per_stage,
    }


# ---------------------------------------------------------------------------
# the audit
# ---------------------------------------------------------------------------


def audit_plan(plan, *, nfields: int = 1, direction: str = "forward",
               schedule=None, label: str = "", check_hlo: bool = True) -> AuditReport:
    """Audit one compiled plan executor against its schedule contracts.

    The executor always runs the plan's *own* resolved schedule;
    ``schedule=`` only overrides the *claimed* contract the artifact is
    checked against (identical by default) — auditing a traditional plan
    against a fused-claiming schedule is how the negative tests prove the
    auditor catches a silently-reintroduced realignment pass.

    ``check_hlo=False`` skips compilation (PLAN002/PLAN007 and the HLO side
    of PLAN005) for contexts without enough devices to back the mesh; the
    jaxpr-level checks — including the realignment invariant — still run.
    """
    import jax

    from repro.core.planconfig import as_schedule
    from repro.core.quant import canonical_comm_dtype

    actual = plan.batched_schedule(nfields) if nfields > 1 else plan.schedule
    claimed = as_schedule(schedule if schedule is not None else actual)
    if len(claimed) != plan.n_exchanges:
        raise ValueError(f"claimed schedule has {len(claimed)} entries for "
                         f"{plan.n_exchanges} exchange stages")

    guard = getattr(plan, "guard", "off")
    if direction == "forward":
        in_pen, dtype = plan.input_pencil, plan.input_dtype
        fn = (plan.forward_many_padded(nfields) if nfields > 1
              else plan.forward_padded)
    elif direction == "backward":
        in_pen, dtype = plan.output_pencil, plan.spectral_dtype
        fn = (plan.backward_many_padded(nfields) if nfields > 1
              else plan.backward_padded)
    else:
        raise ValueError(f"unknown direction {direction!r}")
    if guard != "off":
        # audit the executor a guarded plan actually runs (its (block,
        # stats) output is fine for make_jaxpr/lower)
        fn = plan.guarded_padded(direction, nfields=nfields)
    shape = ((nfields,) if nfields > 1 else ()) + tuple(in_pen.physical)
    aval = jax.ShapeDtypeStruct(shape, dtype)

    expected = _expected_contract(plan, direction, claimed, nfields)
    observed = _jaxpr_stats(jax.make_jaxpr(fn)(aval).jaxpr)
    violations: list[Violation] = []

    if observed["jaxpr_all_to_alls"] != expected["launches"]:
        violations.append(Violation(
            "PLAN001",
            f"jaxpr all_to_all count {observed['jaxpr_all_to_alls']} != "
            f"expected {expected['launches']} launches"))
    if guard == "off" and observed["guard_eqns"]:
        violations.append(Violation(
            "PLAN008",
            f"guard='off' artifact contains {observed['guard_eqns']} eqn(s) "
            f"attributed to {GUARD_MODULE_PREFIX} — the unguarded jaxpr must "
            f"be bit-identical to a pre-guard plan"))
    elif guard != "off" and not observed["guard_eqns"]:
        violations.append(Violation(
            "PLAN008",
            f"guard={guard!r} artifact contains no {GUARD_MODULE_PREFIX} "
            f"eqns — the fused health checks are missing"))
    if observed["engine_transposes"] != expected["engine_transposes"]:
        violations.append(Violation(
            "PLAN003",
            f"engine realignment transposes {observed['engine_transposes']} "
            f"(by module: { {m: n for m, n in observed['transposes_by_module'].items() if m in ENGINE_MODULES} }) "
            f"!= contract {expected['engine_transposes']}"))
    if observed["engine_concats"] != expected["engine_concats"]:
        violations.append(Violation(
            "PLAN004",
            f"engine concatenates {observed['engine_concats']} != contract "
            f"{expected['engine_concats']}"))
    if observed["wide_dtype_eqns"]:
        violations.append(Violation(
            "PLAN005",
            f"silent wide-dtype eqns: {observed['wide_dtype_eqns'][:4]}"))
    if observed["exchange_pallas_calls"] != expected["pallas_calls"]:
        violations.append(Violation(
            "PLAN009",
            f"{EXCHANGE_KERNEL_PREFIX} pallas_call count "
            f"{observed['exchange_pallas_calls']} != the schedule's expected "
            f"{expected['pallas_calls']} fused-kernel launches"))
    lossy_entries = [e for e in claimed
                     if canonical_comm_dtype(e.comm_dtype) != "complex64"]
    if (lossy_entries and all(e.impl == "pallas" for e in lossy_entries)
            and observed["quant_eqns"]):
        violations.append(Violation(
            "PLAN009",
            f"every lossy stage claims impl='pallas' but {observed['quant_eqns']} "
            f"eqn(s) still attribute to {QUANT_MODULE} — codec work leaked "
            f"outside the fused kernels"))
    claimed_narrow = {"bfloat16": 0, "int8": 0}
    for e in claimed:
        if e.comm_dtype == "bf16":
            claimed_narrow["bfloat16"] += 1
        elif e.comm_dtype == "int8":
            claimed_narrow["int8"] += 1
    for d in _NARROW_WIRE_DTYPES:
        enc, dec = observed["narrow_converts_in"][d], observed["narrow_converts_out"][d]
        if enc != dec:
            violations.append(Violation(
                "PLAN006",
                f"unpaired {d} quantize/dequantize: {enc} encodes vs "
                f"{dec} decodes"))
        elif claimed_narrow[d] and not enc:
            violations.append(Violation(
                "PLAN006",
                f"schedule claims a {d} wire payload on "
                f"{claimed_narrow[d]} stage(s) but the jaxpr contains no "
                f"{d} quantize converts"))
        elif enc and not claimed_narrow[d]:
            violations.append(Violation(
                "PLAN006",
                f"artifact quantizes to {d} ({enc} converts) but no "
                f"schedule entry claims that payload"))

    collectives: list = []
    if check_hlo:
        from repro.launch.hlo_account import collective_instrs

        hlo = jax.jit(fn).lower(aval).compile().as_text()
        collectives = collective_instrs(hlo)
        a2a = [r for r in collectives if r["kind"] == "all-to-all"]
        hlo_launches = int(round(sum(r["mult"] for r in a2a)))
        hlo_payloads = sorted(int(round(r["payload_bytes"])) for r in a2a)
        observed["hlo_all_to_alls"] = hlo_launches
        observed["hlo_all_to_all_bytes"] = sum(hlo_payloads)
        observed["hlo_payload_bytes"] = hlo_payloads
        observed["hlo_wide_dtypes"] = sorted(
            {t for t in _WIDE_HLO_TOKENS if t in hlo})
        if hlo_launches != expected["launches"]:
            violations.append(Violation(
                "PLAN007",
                f"HLO all-to-all count {hlo_launches} != expected "
                f"{expected['launches']} launches"))
        observed["backend_widened_wire"] = False
        if hlo_payloads != expected["payload_bytes"]:
            # single-host CPU XLA hoists the bf16 rounding convert across
            # the collective (the wire is free there), shipping rounded
            # values at f32 width: accept that exact widening on the cpu
            # backend, flagged, so the strict contract still binds on real
            # accelerator backends.
            widened = expected["payload_bytes_widened"]
            if (jax.default_backend() == "cpu" and hlo_payloads == widened
                    and widened != expected["payload_bytes"]):
                observed["backend_widened_wire"] = True
            else:
                violations.append(Violation(
                    "PLAN002",
                    f"HLO per-collective payload bytes {hlo_payloads} != "
                    f"exchange_wire_bytes model {expected['payload_bytes']}"))
        if observed["hlo_wide_dtypes"]:
            violations.append(Violation(
                "PLAN005",
                f"wide dtypes in optimized HLO: {observed['hlo_wide_dtypes']}"))

    return AuditReport(
        label=label or f"{plan.shape}:{plan.method}", direction=direction,
        nfields=nfields, schedule=list(claimed), expected=expected,
        observed=observed, collectives=collectives, violations=violations)


# ---------------------------------------------------------------------------
# CLI: audit the example plans + lint src/
# ---------------------------------------------------------------------------


def _example_plans():
    """Mirrors of the three example plans (examples/*.py shapes, transforms
    and methods) plus the fused-kernel (PLAN009) cases, built on however
    many devices the backend provides."""
    import jax

    from repro.core.fftcore import TransformSpec, dealias_grid
    from repro.core.meshutil import balanced_dims, make_mesh
    from repro.core.pfft import ParallelFFT
    from repro.core.planconfig import PlanConfig

    mesh = make_mesh(balanced_dims(len(jax.devices())), ("p0", "p1"))
    n = 32
    m = dealias_grid(n)
    return {
        "quickstart": (ParallelFFT(mesh, (42, 63, 64), grid=("p0", "p1"),
                                   config=PlanConfig(method="fused")), 1),
        # same plan with runtime guards on: PLAN008's positive case (guard
        # eqns present) and proof the guarded artifact still meets every
        # other schedule contract
        "quickstart[guarded]": (ParallelFFT(
            mesh, (42, 63, 64), grid=("p0", "p1"),
            config=PlanConfig(method="fused", guard="degrade")), 1),
        # the fused exchange kernels on both lossy payloads: PLAN009's
        # positive cases — every codec/pack eqn must live inside the
        # kernels/exchange/ pallas calls, none in core/quant.py
        "quickstart[int8-pallas]": (ParallelFFT(
            mesh, (42, 63, 64), grid=("p0", "p1"),
            config=PlanConfig(method="fused", comm_dtype="int8",
                              exchange_impl="pallas")), 1),
        "quickstart[bf16-pallas-trad]": (ParallelFFT(
            mesh, (42, 63, 64), grid=("p0", "p1"),
            config=PlanConfig(method="traditional", comm_dtype="bf16",
                              exchange_impl="pallas")), 1),
        "navier_stokes": (ParallelFFT(
            mesh, (m, m, m), grid=("p0", "p1"),
            config=PlanConfig(method="fused"),
            transforms=(TransformSpec.pruned(n), TransformSpec.pruned(n),
                        TransformSpec.r2c(n_keep=n // 2 + 1))), 1),
        "navier_stokes[batched]": (ParallelFFT(
            mesh, (m, m, m), grid=("p0", "p1"),
            config=PlanConfig(method="fused"),
            transforms=(TransformSpec.pruned(n), TransformSpec.pruned(n),
                        TransformSpec.r2c(n_keep=n // 2 + 1))), 3),
        "poisson": (ParallelFFT(mesh, (32, 32, 32), grid=("p0", "p1"),
                                transforms=("dct2", "c2c", "r2c"),
                                config=PlanConfig(method="fused")), 1),
    }


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.planlint",
        description="Audit the example plans' compiled artifacts against "
                    "their schedule contracts and lint src/ for shard_map "
                    "pitfalls.")
    ap.add_argument("--out", default="plan_audit.json",
                    help="JSON report path (default: %(default)s)")
    ap.add_argument("--devices", type=int, default=8,
                    help="host device count to request when XLA_FLAGS is "
                         "unset (default: %(default)s)")
    ap.add_argument("--only", default=None,
                    help="comma-separated plan labels to audit (default: all)")
    ap.add_argument("--src", default=None,
                    help="source tree to lint (default: the repo's src/)")
    ap.add_argument("--no-src-lint", action="store_true",
                    help="skip the AST source lint")
    args = ap.parse_args(argv)

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    from repro.analysis.srclint import lint_paths

    plans = _example_plans()
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        plans = {k: v for k, v in plans.items() if k in keep}
        missing = keep - set(plans)
        if missing:
            print(f"planlint: unknown plan labels {sorted(missing)}",
                  file=sys.stderr)
            return 2

    reports = {}
    for lbl, (plan, nfields) in plans.items():
        rep = audit_plan(plan, nfields=nfields, label=lbl)
        reports[lbl] = rep
        status = "ok" if rep.ok else "FAIL " + ",".join(
            sorted({v.code for v in rep.violations}))
        print(f"planlint: {lbl:24s} a2a={rep.observed['jaxpr_all_to_alls']} "
              f"wire={rep.expected['wire_bytes']}B "
              f"engine_transposes={rep.observed['engine_transposes']} "
              f"[{status}]")
        for v in rep.violations:
            print(f"  {v.code}: {v.message}", file=sys.stderr)

    findings = []
    if not args.no_src_lint:
        src_root = args.src or os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        findings = lint_paths([src_root])
        for f in findings:
            print(f"srclint: {f.path}:{f.line}: {f.code} {f.message}",
                  file=sys.stderr)
        print(f"planlint: srclint over {src_root}: "
              f"{len(findings)} finding(s)")

    ok = all(r.ok for r in reports.values()) and not findings
    payload = {
        "ok": ok,
        "plans": {lbl: r.to_dict() for lbl, r in reports.items()},
        "srclint": [f.to_dict() for f in findings],
    }
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1, default=str)
    print(f"planlint: report written to {args.out}; "
          f"{'all clean' if ok else 'VIOLATIONS FOUND'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
