"""srclint — AST lint over source trees for shard_map pitfalls.

Pure-stdlib companion to :mod:`repro.analysis.planlint` (no jax import):
parses every ``.py`` file under the given paths and reports

SRC101  a collective primitive (``lax.all_to_all`` / ``psum`` / ...) called
        in a function not reachable from any ``shard_map`` region.  A
        collective outside shard_map traces fine and fails (or silently
        misbehaves) at run time; reachability is a project-wide
        name-closure seeded from every name mentioned inside a
        ``shard_map(...)`` call's function argument, so helpers invoked
        transitively from a mapped function count as covered.
SRC102  an axis-name string literal passed to a collective that is not
        declared by any ``make_mesh``/``Mesh`` axis-name tuple in the
        scanned tree (skipped when the tree declares no literal axis names
        — axis names flowing in as parameters cannot be checked
        statically).
SRC103  a ``shard_map(..., in_specs=(...), ...)`` whose function argument
        is a plain named def with a known positional arity that differs
        from the ``in_specs`` tuple literal's length — the mismatch
        otherwise only explodes at trace time.
SRC104  cache-key construction hazards: ``json.dumps`` without
        ``sort_keys=True`` inside a ``*key*``-named function (two
        semantically equal dicts must serialize to one cache key), and a
        dict literal used as a subscript key (unhashable at run time).
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass

#: jax collective callables that require an enclosing shard_map/pmap region
COLLECTIVE_NAMES = frozenset({
    "all_to_all", "psum", "psum_scatter", "pmean", "pmax", "pmin",
    "ppermute", "pshuffle", "all_gather", "axis_index",
})

#: callables whose call sites declare a mapped region (first arg = body fn)
_SHARD_MAP_NAMES = frozenset({"shard_map", "_shard_map", "pmap"})

#: callables whose string arguments declare mesh axis names
_MESH_CTORS = frozenset({"make_mesh", "Mesh", "AbstractMesh"})


@dataclass
class Finding:
    code: str
    path: str
    line: int
    message: str

    def to_dict(self) -> dict:
        return {"code": self.code, "path": self.path, "line": self.line,
                "message": self.message}


def _call_name(node: ast.Call) -> str | None:
    """Trailing name of the called expression: ``lax.all_to_all`` ->
    ``all_to_all``, ``shard_map`` -> ``shard_map``."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _names_in(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


@dataclass
class _FnInfo:
    name: str
    path: str
    line: int
    arity: tuple[int, int] | None  # (min, max) positional arity; None if *args
    calls: set                  # names this function calls
    collectives: list           # (name, line) of direct collective calls


class _ModuleScan(ast.NodeVisitor):
    """One file's worth of facts for the project-wide passes."""

    def __init__(self, path: str):
        self.path = path
        self.fns: list[_FnInfo] = []
        self.seeds: set[str] = set()          # names inside shard_map fn args
        self.axis_decls: set[str] = set()     # declared mesh axis names
        self.axis_uses: list = []             # (literal, line)
        self.spec_arity: list = []            # (fn_name, n_specs, line)
        self.aliases: dict[str, str] = {}     # import asname -> original name
        self.findings: list[Finding] = []
        self._stack: list[_FnInfo] = []

    def visit_ImportFrom(self, node: ast.ImportFrom):
        for alias in node.names:
            if alias.asname and alias.asname != alias.name:
                self.aliases[alias.asname] = alias.name
        self.generic_visit(node)

    # -- function tracking --------------------------------------------------

    def _visit_fn(self, node):
        a = node.args
        if a.vararg:
            arity = None  # *args: any spec arity is fine
        else:
            hi = len(a.posonlyargs) + len(a.args)
            arity = (hi - len(a.defaults), hi)
        info = _FnInfo(node.name, self.path, node.lineno, arity, set(), [])
        self.fns.append(info)
        self._stack.append(info)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # -- calls ---------------------------------------------------------------

    def visit_Call(self, node: ast.Call):
        name = _call_name(node)
        cur = self._stack[-1] if self._stack else None
        if name:
            if cur is not None:
                cur.calls.add(name)
            if name in COLLECTIVE_NAMES:
                self._note_collective(node, name, cur)
            elif name in _SHARD_MAP_NAMES and node.args:
                self.seeds.update(_names_in(node.args[0]))
                self._note_spec_arity(node)
            elif name in _MESH_CTORS:
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                        self.axis_decls.add(sub.value)
            elif name == "dumps" and cur is not None and "key" in cur.name.lower():
                if not any(kw.arg == "sort_keys" for kw in node.keywords):
                    self.findings.append(Finding(
                        "SRC104", self.path, node.lineno,
                        f"json.dumps in {cur.name}() without sort_keys=True: "
                        f"dict ordering leaks into the cache key"))
        self.generic_visit(node)

    def _note_collective(self, node: ast.Call, name: str, cur):
        if cur is None:
            self.findings.append(Finding(
                "SRC101", self.path, node.lineno,
                f"collective {name} called at module scope (outside any "
                f"shard_map-mapped function)"))
        else:
            cur.collectives.append((name, node.lineno))
        # axis-name literal usage: second positional arg or axis_name kwarg
        cands = list(node.args[1:2]) + [kw.value for kw in node.keywords
                                        if kw.arg == "axis_name"]
        for c in cands:
            if isinstance(c, ast.Constant) and isinstance(c.value, str):
                self.axis_uses.append((c.value, node.lineno))
            elif isinstance(c, ast.Tuple):
                for el in c.elts:
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        self.axis_uses.append((el.value, node.lineno))

    def _note_spec_arity(self, node: ast.Call):
        fn_arg = node.args[0]
        if not isinstance(fn_arg, ast.Name):
            return  # partial/lambda/attribute: arity unknowable here
        for kw in node.keywords:
            if kw.arg == "in_specs" and isinstance(kw.value, ast.Tuple):
                self.spec_arity.append(
                    (fn_arg.id, len(kw.value.elts), node.lineno))

    def visit_Subscript(self, node: ast.Subscript):
        key = node.slice
        if isinstance(key, ast.Dict) or (
                isinstance(key, ast.Call) and _call_name(key) == "dict"):
            self.findings.append(Finding(
                "SRC104", self.path, node.lineno,
                "dict used as a subscript key (unhashable): hash or "
                "json-serialize it first"))
        self.generic_visit(node)


def _iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs
                       if d not in ("__pycache__", ".git", ".venv", "node_modules")]
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_paths(paths) -> list[Finding]:
    """Lint every ``.py`` under ``paths``; returns findings sorted by file
    and line.  Files that fail to parse yield a single SRC100 finding."""
    scans: list[_ModuleScan] = []
    findings: list[Finding] = []
    for path in _iter_py_files(paths):
        try:
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
        except (OSError, SyntaxError) as e:
            findings.append(Finding("SRC100", path, getattr(e, "lineno", 0) or 0,
                                    f"unparseable: {e}"))
            continue
        scan = _ModuleScan(path)
        scan.visit(tree)
        scans.append(scan)
        findings.extend(scan.findings)

    # project-wide reachability closure (SRC101).  Calls through an import
    # alias (``from m import f as g``) count as calls to the original name.
    aliases: dict[str, str] = {}
    for s in scans:
        aliases.update(s.aliases)

    def _expand(names):
        out = set(names)
        out.update(aliases[n] for n in names if n in aliases)
        return out

    seeds = set().union(*(_expand(s.seeds) for s in scans)) if scans else set()
    calls_by_name: dict[str, set] = {}
    for s in scans:
        for fn in s.fns:
            calls_by_name.setdefault(fn.name, set()).update(_expand(fn.calls))
    reachable = set()
    frontier = [n for n in seeds if n in calls_by_name]
    while frontier:
        n = frontier.pop()
        if n in reachable:
            continue
        reachable.add(n)
        frontier.extend(c for c in calls_by_name.get(n, ())
                        if c in calls_by_name and c not in reachable)
    for s in scans:
        for fn in s.fns:
            if fn.collectives and fn.name not in reachable and fn.name not in seeds:
                for cname, line in fn.collectives:
                    findings.append(Finding(
                        "SRC101", s.path, line,
                        f"collective {cname} in {fn.name}(), which is not "
                        f"reachable from any shard_map region in the "
                        f"scanned tree"))

    # axis-name literals vs declared mesh axes (SRC102)
    declared = set().union(*(s.axis_decls for s in scans)) if scans else set()
    if declared:
        for s in scans:
            for axis, line in s.axis_uses:
                if axis not in declared:
                    findings.append(Finding(
                        "SRC102", s.path, line,
                        f"axis name {axis!r} is not declared by any "
                        f"make_mesh/Mesh in the scanned tree "
                        f"(declared: {sorted(declared)})"))

    # in_specs arity vs mapped function arity (SRC103)
    arity_by_name: dict[str, tuple[int, int] | None] = {}
    for s in scans:
        for fn in s.fns:
            # conflicting defs with the same name: give up on that name
            if fn.name in arity_by_name and arity_by_name[fn.name] != fn.arity:
                arity_by_name[fn.name] = None
            else:
                arity_by_name.setdefault(fn.name, fn.arity)
    for s in scans:
        for fn_name, n_specs, line in s.spec_arity:
            arity = arity_by_name.get(fn_name)
            if arity is not None and not arity[0] <= n_specs <= arity[1]:
                findings.append(Finding(
                    "SRC103", s.path, line,
                    f"shard_map in_specs has {n_specs} specs but "
                    f"{fn_name}() takes {arity[0]}..{arity[1]} positional "
                    f"args"))

    return sorted(findings, key=lambda f: (f.path, f.line, f.code))
