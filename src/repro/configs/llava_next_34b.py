"""LLaVA-NeXT-34B [hf:llava-hf family] — VLM backbone; anyres vision
frontend is a stub providing 2048 precomputed patch-embedding tokens."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    rope_theta=5e6, mlp="swiglu", norm="rmsnorm",
    frontend="vision", n_frontend_tokens=2048,
)
