"""Nemotron-4-15B [arXiv:2402.16819] — dense GQA kv=8, squared-ReLU MLP."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000, head_dim=128,
    rope_theta=1e4, mlp="relu2", norm="layernorm",
)
