"""Phi-3.5-MoE 42B (A6.6B) [hf:microsoft/Phi-3.5-MoE-instruct] — 16e top-2."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32064, head_dim=128,
    rope_theta=1e4, mlp="swiglu", norm="layernorm",
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_ff_expert=6400),
)
