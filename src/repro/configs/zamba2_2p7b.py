"""Zamba2-2.7B [arXiv:2411.15242] — 54 Mamba2 layers + ONE shared
attention+MLP block invoked every 6 layers (input = concat(x, emb));
sub-quadratic => long_500k runs."""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    attn_every=6, rope_theta=1e4, mlp="gelu", norm="layernorm",
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                  headdim=64, chunk=128),
    subquadratic=True,
)
