"""DeepSeek-V2-Lite 16B [arXiv:2405.04434] — MLA kv_lora=512, MoE 64e top-6
(+2 shared), first layer dense.  (Assignment note: the line says both
"64e top-6" and "160 routed"; 160 routed is full V2 — Lite is 64, used here.)"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    rope_theta=1e4, mlp="swiglu", norm="rmsnorm",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  first_k_dense=1, dense_ff=10944),
)
