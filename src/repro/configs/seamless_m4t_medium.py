"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec backbone; audio frontend
is a stub (input_specs provides precomputed frame embeddings per the
assignment).  12 encoder + 12 decoder layers at d=1024."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, n_encoder_layers=12, encdec=True,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    rope_theta=1e4, mlp="gelu", norm="layernorm",
    frontend="audio",
)
