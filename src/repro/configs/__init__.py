"""Architecture registry: the 10 assigned archs + the paper's FFT configs.

``get(name)`` returns the exact published ArchConfig; ``smoke(name)`` a
reduced same-family variant for CPU tests.  ``SHAPES`` are the assigned
input-shape cells; ``cells(name)`` enumerates the applicable (arch, shape)
pairs (long_500k only for sub-quadratic archs — skip recorded in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from dataclasses import replace
from importlib import import_module

from repro.models.config import ArchConfig, MLAConfig, MoEConfig, SSMConfig

ARCH_NAMES = (
    "glm4_9b",
    "stablelm_12b",
    "nemotron_4_15b",
    "qwen2_72b",
    "deepseek_v2_lite_16b",
    "phi35_moe_42b",
    "seamless_m4t_medium",
    "llava_next_34b",
    "zamba2_2p7b",
    "falcon_mamba_7b",
)

# assigned shapes: name -> (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get(name: str) -> ArchConfig:
    name = name.replace("-", "_").replace(".", "p")
    mod = import_module(f"repro.configs.{name}")
    return mod.CONFIG


def smoke(name: str) -> ArchConfig:
    """Reduced same-family config: tiny widths, 2-ish layers, tiny vocab."""
    cfg = get(name)
    kw = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
              d_ff=128, vocab=256, head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, n_experts=8, top_k=2, d_ff_expert=32,
                            dense_ff=96, capacity_factor=8.0,
                            first_k_dense=min(cfg.moe.first_k_dense, 1))
        kw["n_layers"] = 2 + kw["moe"].first_k_dense
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                              v_head_dim=16)
        kw["head_dim"] = 0
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=8, headdim=8, chunk=16)
    if cfg.family == "hybrid":
        kw["n_layers"] = 4
        kw["attn_every"] = 2
        kw["n_kv_heads"] = 4
    if cfg.family == "audio":
        kw["n_encoder_layers"] = 2
    if cfg.family == "vlm":
        kw["n_frontend_tokens"] = 8
    return replace(cfg, **kw)


def cells(name: str) -> list[str]:
    """Applicable shape cells for an arch (the 40-cell table)."""
    cfg = get(name)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_NAMES for s in cells(a)]
