"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba1, attention-free;
sub-quadratic => long_500k runs.  TP shards d_inner (no heads axis — the
paper's seq<->head redistribution is inapplicable; see DESIGN.md)."""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    mlp="swiglu", norm="rmsnorm",
    ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2, chunk=128),
    subquadratic=True,
)
