"""``python -m repro.serve`` — run the spectral serving engine on a
synthetic request load and print a JSON report.

Demo / smoke entrypoint, not a network server: it builds a device mesh,
starts a :class:`~repro.serve.engine.SpectralServer`, fires ``--requests``
forward transforms at it (mixing ``--shapes`` round-robin so the LRU
registry and the coalescer both get exercised), waits for every future,
and reports the outcome histogram plus engine stats.  ``--chaos`` arms a
recurring serve-level fault matrix (slow collectives, executor crashes,
cache corruption, request bursts) — the report then demonstrates the
resilience lifecycle: every request still terminates in a structured
outcome within its deadline.

Typical smoke run (8 virtual devices):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.serve --shapes 32,32,32 --requests 12 --chaos
"""

from __future__ import annotations

import argparse
import json


def _parse_shapes(spec: str):
    shapes = []
    for part in spec.split(";"):
        shapes.append(tuple(int(s) for s in part.split(",")))
    return shapes


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.serve",
                                 description=__doc__.split("\n\n")[0])
    ap.add_argument("--shapes", default="32,32,32",
                    help="semicolon-separated global shapes, e.g. "
                         "'32,32,32;16,16,16'")
    ap.add_argument("--grid", choices=["slab", "pencil"], default="slab")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--method", default="fused",
                    help="plan method (fused/traditional/pipelined/auto)")
    ap.add_argument("--deadline", type=float, default=60.0,
                    help="per-request deadline in seconds")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--tune-cache", default=None,
                    help="shared schedule DB path (method=auto)")
    ap.add_argument("--chaos", action="store_true",
                    help="arm the serve-level fault matrix")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.core.meshutil import balanced_dims, make_mesh
    from repro.core.planconfig import PlanConfig
    from repro.robustness import faults
    from repro.serve import ServeConfig, SpectralServer

    ndev = len(jax.devices())
    if args.grid == "slab":
        mesh, grid = make_mesh((ndev,), ("p0",)), ("p0",)
    else:
        mesh = make_mesh(balanced_dims(ndev), ("p0", "p1"))
        grid = ("p0", "p1")
    shapes = _parse_shapes(args.shapes)
    pc = PlanConfig(method=args.method, tuner_cache=args.tune_cache,
                    guard="degrade")
    sc = ServeConfig(deadline_s=args.deadline, max_batch=args.max_batch,
                     max_queue=args.max_queue)
    rng = np.random.default_rng(args.seed)

    fault_ctx = None
    if args.chaos:
        fault_ctx = (faults.FaultPlan()
                     .slow_collective(seconds=0.05, times=2)
                     .executor_crash(times=1)
                     .cache_corruption(mode="garbage", times=1)
                     .request_burst(factor=2, times=1))
        fault_ctx.__enter__()
    try:
        with SpectralServer(mesh, grid, plan_config=pc, config=sc) as srv:
            futures = []
            n = args.requests * faults.serve_burst()
            for i in range(n):
                shape = shapes[i % len(shapes)]
                x = rng.standard_normal(shape).astype(np.float32)
                futures.append(srv.submit(x, deadline_s=args.deadline))
            outcomes = [f.result(grace=sc.grace_s) for f in futures]
            stats = srv.stats()
    finally:
        if fault_ctx is not None:
            fault_ctx.__exit__(None, None, None)

    hist: dict[str, int] = {}
    for o in outcomes:
        hist[o.status] = hist.get(o.status, 0) + 1
    unresolved = [o for o in outcomes if o is None]
    report = {
        "requests": len(outcomes),
        "outcomes": hist,
        "unresolved": len(unresolved),
        "chaos": bool(args.chaos),
        "fired_faults": (fault_ctx.fired if fault_ctx is not None else []),
        "stats": stats,
        "sample": [o.summary() for o in outcomes[:4]],
    }
    print(json.dumps(report, indent=1, default=str))
    return 1 if unresolved else 0


if __name__ == "__main__":
    raise SystemExit(main())
