"""Resilient spectral serving engine (``python -m repro.serve``).

A long-running service over the distributed-FFT core: hot compiled plans in
a warm-started LRU registry, same-shape request coalescing into batched
multi-field executions, and a full per-request resilience lifecycle —
deadlines, bounded retry with deterministic-jitter backoff, admission
control with load shedding, and per-plan circuit breakers wired into the
guarded-execution degradation ladder and the shared tuner DB.

Layers: :mod:`~repro.serve.lifecycle` (outcomes, self-resolving futures,
backoff), :mod:`~repro.serve.registry` (plan LRU + breakers),
:mod:`~repro.serve.engine` (the :class:`SpectralServer` dispatch loop).
Chaos hooks live in :mod:`repro.robustness.faults` (``slow_collective``,
``executor_crash``, ``cache_corruption``, ``request_burst``).

Not the LM demo — that moved to :mod:`repro.launch.serve_lm`.
"""

from repro.serve.engine import ServeConfig, SpectralServer
from repro.serve.lifecycle import (
    OUTCOME_STATUSES, TRIP_CIRCUIT, TRIP_SHED, TRIP_TIMEOUT,
    Outcome, RequestFuture, backoff_s,
)
from repro.serve.registry import CircuitBreaker, PlanRegistry, fallback_schedule

__all__ = [
    "SpectralServer", "ServeConfig", "PlanRegistry", "CircuitBreaker",
    "fallback_schedule", "Outcome", "RequestFuture", "backoff_s",
    "OUTCOME_STATUSES", "TRIP_TIMEOUT", "TRIP_SHED", "TRIP_CIRCUIT",
]
