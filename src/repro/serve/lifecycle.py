"""Request lifecycle primitives: outcomes, self-resolving futures, backoff.

Every request submitted to the serving engine terminates in exactly one
structured :class:`Outcome` — the engine has **no silent terminal state**:

``ok``                 — clean execution, no degradation.
``degraded``           — the result is valid but something gave way: the
                         guard ladder widened a stage, or the circuit
                         breaker routed the request through the fallback
                         schedule (``trip="circuit-open"``).
``shed``               — admission control refused the request at submit
                         time (bounded queue full, ``trip="overload-shed"``).
``deadline-exceeded``  — the deadline passed before a result landed
                         (``trip="timeout"``).  The future *self-resolves*:
                         a wedged executor (slow collective, compile hang)
                         can never hang the caller — the late completion is
                         counted in the engine's ``late_results`` stat
                         instead of silently discarded.
``error``              — a structured failure (exhausted retries, exhausted
                         degradation ladder); ``error`` carries the repr.

:class:`RequestFuture` is the one-shot synchronization cell: the first
``resolve`` wins (worker vs. deadline race is explicit — the loser's
attempt returns ``False``), and ``result()`` never waits past
``deadline + grace``.

Retry backoff is exponential with **deterministic jitter**: the jitter
fraction is a hash of ``(request_id, attempt)``, so chaos tests replay
byte-identical schedules while concurrent retries still decorrelate.
"""

from __future__ import annotations

import hashlib
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any

#: terminal request states (see module docstring)
OUTCOME_STATUSES = ("ok", "degraded", "shed", "deadline-exceeded", "error")

#: serve-level trip codes (extends the guard trip codes of
#: :mod:`repro.robustness.health`)
TRIP_TIMEOUT = "timeout"
TRIP_SHED = "overload-shed"
TRIP_CIRCUIT = "circuit-open"

_rid_counter = itertools.count()


@dataclass
class Outcome:
    """Structured terminal state of one request."""

    status: str                     #: one of :data:`OUTCOME_STATUSES`
    request_id: str
    value: Any = None               #: the spectrum (ok/degraded only)
    trip: str | None = None         #: serve/guard trip code, None for clean ok
    error: str | None = None        #: repr of the terminal failure
    retries: int = 0                #: re-dispatch attempts consumed
    transitions: int = 0            #: guard-ladder transitions on the winning run
    latency_s: float = 0.0          #: submit -> resolve wall time
    batched: int = 1                #: coalesced group size this request rode in

    def __post_init__(self):
        if self.status not in OUTCOME_STATUSES:
            raise ValueError(f"unknown outcome status {self.status!r}")

    def summary(self) -> dict:
        """JSON-safe view (drops the array payload)."""
        return {"status": self.status, "request_id": self.request_id,
                "trip": self.trip, "error": self.error,
                "retries": self.retries, "transitions": self.transitions,
                "latency_s": round(self.latency_s, 6), "batched": self.batched}


class RequestFuture:
    """One-shot result cell with a hard deadline.

    ``resolve`` is first-write-wins and returns whether this call won;
    ``result()`` blocks until resolution but never past the deadline plus
    ``grace`` — if nothing resolved it by then, it resolves *itself* with
    ``deadline-exceeded``.  That self-resolution is the engine's zero-hang
    guarantee: no fault (slow collective, wedged compile, dead worker) can
    make a caller wait unboundedly or receive nothing."""

    def __init__(self, request_id: str, deadline: float,
                 submitted: float | None = None):
        self.request_id = request_id
        self.deadline = deadline          #: absolute, time.monotonic() scale
        self.submitted = time.monotonic() if submitted is None else submitted
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._outcome: Outcome | None = None

    @property
    def resolved(self) -> bool:
        return self._event.is_set()

    def resolve(self, outcome: Outcome) -> bool:
        """Install ``outcome`` unless something already won the race;
        returns True when this call was the winner."""
        with self._lock:
            if self._outcome is not None:
                return False
            outcome.latency_s = time.monotonic() - self.submitted
            self._outcome = outcome
            self._event.set()
            return True

    def result(self, *, grace: float = 0.25) -> Outcome:
        """The terminal outcome, waiting at most until ``deadline+grace``."""
        remaining = self.deadline + grace - time.monotonic()
        if remaining > 0:
            self._event.wait(remaining)
        if not self._event.is_set():
            self.resolve(Outcome("deadline-exceeded", self.request_id,
                                 trip=TRIP_TIMEOUT))
        return self._outcome


@dataclass
class Request:
    """One unit of admitted work: a field to transform under a plan key."""

    x: Any                          #: logical-shape field (array-like)
    shape: tuple[int, ...]
    direction: str                  #: "forward" | "backward"
    future: RequestFuture
    retries: int = 0
    meta: dict = field(default_factory=dict)

    @property
    def group_key(self):
        """Coalescing identity: same shape + direction ride one batch."""
        return (self.shape, self.direction)


def next_request_id(prefix: str = "r") -> str:
    return f"{prefix}{next(_rid_counter)}"


def backoff_s(request_id: str, attempt: int, *, base: float = 0.05,
              cap: float = 1.0) -> float:
    """Exponential backoff with deterministic jitter.

    ``min(cap, base * 2^(attempt-1)) * frac`` where ``frac ∈ [0.5, 1.0)``
    is derived from ``sha1(request_id:attempt)`` — replayable (chaos tests
    assert exact schedules) yet decorrelated across concurrent retriers,
    which is what jitter is for (no retry convoy re-hitting a recovering
    resource in lockstep)."""
    if attempt < 1:
        return 0.0
    raw = min(cap, base * (2.0 ** (attempt - 1)))
    h = hashlib.sha1(f"{request_id}:{attempt}".encode()).digest()
    frac = 0.5 + (int.from_bytes(h[:4], "big") / 2**32) * 0.5
    return raw * frac
