"""Hot plan registry (warm-started LRU) and per-plan circuit breakers.

The registry keeps compiled :class:`~repro.core.pfft.ParallelFFT` plans hot,
keyed by :func:`repro.core.tuner.plan_key` — the same identity the shared
schedule DB uses, so two serve replicas pointing at one tuner cache agree on
what "the same plan" means.  ``get(shape)`` builds a missing plan from the
registry's :class:`~repro.core.planconfig.PlanConfig` template and **warms**
it (:meth:`ParallelFFT.warm`): schedule resolution — pre-tuned entries load
straight from the crash-safe DB (atomic writes + ``flock``, see
:mod:`repro.core.tuner`) — plus tracing and compilation all happen at
admission, never on the request hot path.  Capacity eviction is LRU; an
evicted plan's compiled executors are dropped with it (its tuned schedule
survives in the DB, so re-admission re-compiles but never re-tunes).

Each registry slot carries a :class:`CircuitBreaker` (classic three-state):

``closed``     — primary path; consecutive ``GuardError`` terminal failures
                 count toward ``threshold``.
``open``       — tripped: the engine stops offering requests to the failing
                 primary schedule and serves them through the bottom of the
                 degradation ladder (:func:`fallback_schedule`) while the
                 quarantine-and-retune happens off the hot path.
``half-open``  — after ``cooldown_s`` one probe request is let through; a
                 clean probe closes the breaker, a failure re-opens it.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict

from repro.core.planconfig import PlanConfig, StageEntry


class CircuitBreaker:
    """Three-state breaker; thread-safe, monotonic-clock based."""

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 5.0):
        self.threshold = max(1, int(threshold))
        self.cooldown_s = float(cooldown_s)
        self._failures = 0
        self._opened_at: float | None = None
        self._probing = False
        self._lock = threading.Lock()
        self.trips = 0  #: lifetime trip count

    @property
    def state(self) -> str:
        with self._lock:
            return self._state_locked()

    def _state_locked(self) -> str:
        if self._opened_at is None:
            return "closed"
        if time.monotonic() - self._opened_at >= self.cooldown_s:
            return "half-open"
        return "open"

    def allow(self) -> bool:
        """May the primary path be attempted right now?  In half-open,
        only the first caller gets the probe slot until it reports back."""
        with self._lock:
            st = self._state_locked()
            if st == "closed":
                return True
            if st == "half-open" and not self._probing:
                self._probing = True
                return True
            return False

    def record_success(self):
        with self._lock:
            self._failures = 0
            self._opened_at = None
            self._probing = False

    def record_failure(self) -> bool:
        """Count a terminal primary-path failure; returns True when this
        call tripped (or re-tripped) the breaker open."""
        with self._lock:
            was_open = self._opened_at is not None
            self._probing = False
            self._failures += 1
            if self._failures >= self.threshold or was_open:
                self._opened_at = time.monotonic()
                self._failures = 0
                self.trips += 1
                return True
            return False


def fallback_schedule(plan) -> tuple[StageEntry, ...]:
    """The bottom of the degradation ladder for every exchange stage —
    ``traditional @ complex64 @ jnp @ stacked``: lossless wire, reference
    impl, the engine with no overlap machinery to go wrong.  This is what
    a tripped breaker serves through while the primary schedule retunes."""
    bottom = StageEntry("traditional", 1, "complex64", "jnp", "stacked")
    return (bottom,) * plan.n_exchanges


class PlanRegistry:
    """Warm-started LRU of compiled plans + their breakers.

    Thread-safe; ``get`` may compile (slow) under a per-registry build
    lock so concurrent first requests for one shape compile once."""

    def __init__(self, mesh, grid, *, config: PlanConfig | None = None,
                 capacity: int = 8, warm_directions=("forward",),
                 warm_nfields: int = 1, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0):
        from repro.core.pfft import ParallelFFT  # deferred: jax import cost

        self._ParallelFFT = ParallelFFT
        self.mesh, self.grid = mesh, grid
        self.config = config if config is not None else PlanConfig()
        self.capacity = max(1, int(capacity))
        self.warm_directions = tuple(warm_directions)
        self.warm_nfields = int(warm_nfields)
        self._breaker_kw = {"threshold": breaker_threshold,
                            "cooldown_s": breaker_cooldown_s}
        self._plans: OrderedDict[str, object] = OrderedDict()  # plan_key -> plan
        self._breakers: dict[str, CircuitBreaker] = {}
        self._shape_key: dict[tuple, str] = {}  # shape -> plan_key memo
        self._lock = threading.RLock()
        self.evictions = 0
        self.builds = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def key_for(self, shape: tuple[int, ...]) -> str | None:
        with self._lock:
            return self._shape_key.get(tuple(shape))

    def breaker(self, key: str) -> CircuitBreaker:
        with self._lock:
            if key not in self._breakers:
                self._breakers[key] = CircuitBreaker(**self._breaker_kw)
            return self._breakers[key]

    def get(self, shape: tuple[int, ...]):
        """The hot plan for ``shape`` (LRU-touched), building + warming on
        miss.  Returns ``(plan_key, plan)``."""
        from repro.core import tuner

        shape = tuple(shape)
        with self._lock:
            key = self._shape_key.get(shape)
            if key is not None and key in self._plans:
                self._plans.move_to_end(key)
                return key, self._plans[key]
            # build under the registry lock: one compile per shape even
            # when N requests race the first admission
            plan = self._ParallelFFT(self.mesh, shape, self.grid,
                                     config=self.config)
            key = tuner.plan_key(plan, nfields=self.warm_nfields)
            self.builds += 1
            plan.warm(self.warm_directions, nfields=self.warm_nfields)
            self._plans[key] = plan
            self._shape_key[shape] = key
            while len(self._plans) > self.capacity:
                old_key, _ = self._plans.popitem(last=False)
                self.evictions += 1
                # keep the breaker: a flapping plan must not reset its
                # failure history by being evicted and re-admitted
                for s, k in list(self._shape_key.items()):
                    if k == old_key:
                        del self._shape_key[s]
            return key, plan

    def stats(self) -> dict:
        with self._lock:
            return {"plans": len(self._plans), "capacity": self.capacity,
                    "builds": self.builds, "evictions": self.evictions,
                    "breakers": {k[:40]: b.state
                                 for k, b in self._breakers.items()},
                    "breaker_trips": sum(b.trips
                                         for b in self._breakers.values())}
