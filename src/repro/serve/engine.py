"""The resilient spectral serving engine.

:class:`SpectralServer` turns :class:`~repro.core.pfft.ParallelFFT` into a
long-running service.  One dispatch worker drains a bounded admission queue;
requests for the same ``(shape, direction)`` are **coalesced** into one
batched ``forward_many``/``backward_many`` invocation (PR 4's engine: one
collective per exchange stage for the whole group instead of one per
request).  Every request rides the full resilience lifecycle:

admission    — the queue is bounded (``max_queue``); overload is *shed* at
               submit time with a structured ``shed`` outcome, never queued
               into unbounded latency.
deadline     — per-request; the future self-resolves ``deadline-exceeded``
               so a wedged execution is observable (``late_results``) but
               can never hang a caller.
retry        — transient failures (injected crashes, non-guard exceptions)
               re-dispatch with exponential backoff + deterministic jitter,
               bounded by ``max_retries`` and the group's earliest deadline.
breaker      — terminal ``GuardError`` failures count against the plan's
               circuit breaker; a trip quarantines the schedule in the
               shared tuner DB (:func:`repro.core.tuner.quarantine`) and
               kicks a *background* retune (``plan.warm`` off the hot
               path), while requests keep flowing through the bottom of the
               degradation ladder (:func:`~repro.serve.registry.
               fallback_schedule`) as ``degraded`` / ``circuit-open``.

Fault hooks (:mod:`repro.robustness.faults`) are called at fixed points —
``tap_serve_execute`` before every execution attempt, ``tap_serve_cache``
against the shared schedule DB per dispatch — so the whole lifecycle is
deterministically chaos-testable.
"""

from __future__ import annotations

import logging
import threading
import time
from collections import deque
from dataclasses import dataclass

from repro.robustness import faults
from repro.robustness.runner import GuardError, run_guarded
from repro.serve.lifecycle import (
    TRIP_CIRCUIT, TRIP_SHED, TRIP_TIMEOUT,
    Outcome, Request, RequestFuture, backoff_s, next_request_id,
)
from repro.serve.registry import PlanRegistry, fallback_schedule

log = logging.getLogger("repro.serve")

_COUNTERS = ("submitted", "ok", "degraded", "shed", "deadline_exceeded",
             "error", "retries", "coalesced_batches", "batched_requests",
             "fallback_served", "late_results", "expired_before_dispatch",
             "retunes")


@dataclass
class ServeConfig:
    """Engine knobs (plan-level knobs live in the PlanConfig template)."""

    capacity: int = 8              #: LRU plan slots
    max_queue: int = 64            #: admission bound; beyond -> shed
    max_batch: int = 8             #: coalescing cap per dispatch
    deadline_s: float = 30.0       #: default per-request deadline
    grace_s: float = 0.25          #: result() slack past the deadline
    max_retries: int = 2           #: transient re-dispatches per group
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 1.0
    breaker_threshold: int = 3     #: consecutive GuardErrors to trip
    breaker_cooldown_s: float = 5.0
    warm_directions: tuple = ("forward",)
    warm_nfields: int = 1


class SpectralServer:
    """Long-running spectral FFT service over one device mesh.

    ``submit`` is thread-safe and non-blocking (shed rather than block);
    results come back through :class:`~repro.serve.lifecycle.RequestFuture`.
    Plans are forced to ``guard="degrade"`` unless the template already
    asks for ``"strict"`` — an unguarded plan has no ladder to serve
    through, which would void the engine's no-silent-corruption contract.
    """

    def __init__(self, mesh, grid, *, plan_config=None,
                 config: ServeConfig | None = None):
        from repro.core.planconfig import PlanConfig

        self.config = config if config is not None else ServeConfig()
        pc = plan_config if plan_config is not None else PlanConfig()
        if pc.guard == "off":
            pc = pc.replace(guard="degrade")
        self.plan_config = pc
        self.registry = PlanRegistry(
            mesh, grid, config=pc, capacity=self.config.capacity,
            warm_directions=self.config.warm_directions,
            warm_nfields=self.config.warm_nfields,
            breaker_threshold=self.config.breaker_threshold,
            breaker_cooldown_s=self.config.breaker_cooldown_s)
        self._queue: deque[Request] = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._stats = dict.fromkeys(_COUNTERS, 0)
        self._stats_lock = threading.Lock()
        self._retune_threads: list[threading.Thread] = []
        self._worker = threading.Thread(target=self._worker_loop,
                                        name="repro-serve-dispatch",
                                        daemon=True)
        self._worker.start()

    # -- public surface ------------------------------------------------------

    def submit(self, x, *, direction: str = "forward",
               deadline_s: float | None = None) -> RequestFuture:
        """Admit one field for transform; returns its future immediately.
        A full queue sheds the request (structured ``shed`` outcome) —
        overload degrades throughput, never latency honesty."""
        if direction not in ("forward", "backward"):
            raise ValueError(f"unknown direction {direction!r}")
        deadline_s = self.config.deadline_s if deadline_s is None else deadline_s
        rid = next_request_id()
        fut = RequestFuture(rid, time.monotonic() + deadline_s)
        with self._cv:
            if self._closed:
                raise RuntimeError("server is closed")
            self._bump("submitted")
            if len(self._queue) >= self.config.max_queue:
                fut.resolve(Outcome("shed", rid, trip=TRIP_SHED))
                self._bump("shed")
                return fut
            self._queue.append(Request(x=x, shape=tuple(x.shape),
                                       direction=direction, future=fut))
            self._cv.notify()
        return fut

    def stats(self) -> dict:
        with self._stats_lock:
            out = dict(self._stats)
        out["queue_depth"] = len(self._queue)
        out["registry"] = self.registry.stats()
        return out

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until the queue is empty and the worker is idle."""
        end = time.monotonic() + timeout
        while time.monotonic() < end:
            with self._cv:
                if not self._queue and not self._dispatching:
                    return True
            time.sleep(0.01)
        return False

    def close(self, timeout: float = 30.0):
        """Stop admitting, drain in-flight work, join the worker."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._worker.join(timeout)
        for t in self._retune_threads:
            t.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- dispatch ------------------------------------------------------------

    _dispatching = False

    def _bump(self, counter: str, n: int = 1):
        with self._stats_lock:
            self._stats[counter] += n

    def _worker_loop(self):
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait(0.05)
                if not self._queue:
                    if self._closed:
                        return
                    continue
                group = self._take_group_locked()
                self._dispatching = True
            try:
                self._execute_group(group)
            except BaseException as e:  # the worker must never die silently
                log.exception("dispatch group failed terminally: %r", e)
                for r in group:
                    self._resolve(r, Outcome("error", r.future.request_id,
                                             error=repr(e)[:300],
                                             batched=len(group)))
            finally:
                with self._cv:
                    self._dispatching = False

    def _take_group_locked(self) -> list[Request]:
        """Pop the head request plus every queued request with the same
        ``(shape, direction)``, up to ``max_batch`` — the coalescer."""
        head = self._queue.popleft()
        group = [head]
        rest = deque()
        while self._queue and len(group) < self.config.max_batch:
            r = self._queue.popleft()
            (group if r.group_key == head.group_key else rest).append(r)
        self._queue.extendleft(reversed(rest))
        return group

    def _resolve(self, req: Request, outcome: Outcome):
        if req.future.resolve(outcome):
            self._bump(outcome.status.replace("-", "_"))
        else:
            self._bump("late_results")

    def _tuner_path(self):
        from repro.core import tuner

        return self.plan_config.tuner_cache or tuner.default_cache_path()

    def _execute_group(self, group: list[Request]):
        import jax
        import jax.numpy as jnp

        # mid-flight cache-corruption fault point: the shared schedule DB
        # may be scribbled on between any two dispatches
        faults.tap_serve_cache(self._tuner_path())

        now = time.monotonic()
        reqs = []
        for r in group:
            if r.future.deadline <= now:
                self._bump("expired_before_dispatch")
                self._resolve(r, Outcome("deadline-exceeded",
                                         r.future.request_id,
                                         trip=TRIP_TIMEOUT))
            else:
                reqs.append(r)
        if not reqs:
            return
        direction = reqs[0].direction
        if len(reqs) > 1:
            self._bump("coalesced_batches")
            self._bump("batched_requests", len(reqs))
        try:
            key, plan = self.registry.get(reqs[0].shape)
        except Exception as e:
            for r in reqs:
                self._resolve(r, Outcome("error", r.future.request_id,
                                         error=f"plan build failed: {e!r}"[:300],
                                         batched=len(reqs)))
            return
        breaker = self.registry.breaker(key)
        stacked = jnp.stack([jnp.asarray(r.x) for r in reqs])
        earliest = min(r.future.deadline for r in reqs)

        attempt = 0
        while True:
            if not breaker.allow():
                self._serve_fallback(reqs, plan, stacked, direction,
                                     trip=TRIP_CIRCUIT, retries=attempt)
                return
            try:
                faults.tap_serve_execute()
                out = plan._apply_many(stacked, direction)
                y, report = out if isinstance(out, tuple) else (out, None)
                jax.block_until_ready(y)
            except GuardError as e:
                tripped = breaker.record_failure()
                if tripped:
                    self._on_trip(plan, key, direction, len(reqs), e)
                self._serve_fallback(reqs, plan, stacked, direction,
                                     trip=(TRIP_CIRCUIT if tripped
                                           else "guard-error"),
                                     retries=attempt, cause=e)
                return
            except Exception as e:  # transient: injected crash, XLA hiccup
                attempt += 1
                self._bump("retries")
                wait = backoff_s(reqs[0].future.request_id, attempt,
                                 base=self.config.backoff_base_s,
                                 cap=self.config.backoff_cap_s)
                out_of_time = time.monotonic() + wait >= earliest
                if attempt > self.config.max_retries or out_of_time:
                    breaker.record_failure()
                    status = ("deadline-exceeded" if out_of_time
                              and attempt <= self.config.max_retries
                              else "error")
                    for r in reqs:
                        self._resolve(r, Outcome(
                            status, r.future.request_id,
                            trip=TRIP_TIMEOUT if status == "deadline-exceeded"
                            else "retries-exhausted",
                            error=repr(e)[:300], retries=attempt,
                            batched=len(reqs)))
                    return
                log.warning("transient failure (attempt %d), retrying in "
                            "%.3fs: %r", attempt, wait, e)
                time.sleep(wait)
                continue
            break  # success

        breaker.record_success()
        transitions = len(report.transitions) if report is not None else 0
        status = "degraded" if transitions else "ok"
        trip = "guard-degrade" if transitions else None
        for i, r in enumerate(reqs):
            self._resolve(r, Outcome(status, r.future.request_id, value=y[i],
                                     trip=trip, retries=attempt,
                                     transitions=transitions,
                                     batched=len(reqs)))

    def _serve_fallback(self, reqs, plan, stacked, direction, *, trip,
                        retries=0, cause=None):
        """Serve a group through the bottom of the degradation ladder —
        the breaker-open (or ladder-exhausted) path.  Still guarded: a
        fallback that fails too yields structured errors, not silence."""
        import jax

        from repro.core.pencil import pad_global, unpad_global

        self._bump("fallback_served", len(reqs))
        try:
            faults.tap_serve_execute()
            if direction == "forward":
                in_pen, out_pen = plan.input_pencil, plan.output_pencil
                dt = plan.input_dtype
            else:
                in_pen, out_pen = plan.output_pencil, plan.input_pencil
                dt = plan.spectral_dtype
            sched = fallback_schedule(plan)
            xpad = pad_global(stacked.astype(dt), in_pen, nbatch=1)
            if len(reqs) == 1:
                y, report = run_guarded(plan, xpad[0], direction,
                                        schedule=sched)
                y = y[None]
            else:
                y, report = run_guarded(plan, xpad, direction,
                                        nfields=len(reqs), schedule=sched)
            jax.block_until_ready(y)
            y = unpad_global(y, out_pen, nbatch=1)
        except Exception as e:
            log.warning("fallback execution failed: %r (primary cause: %r)",
                        e, cause)
            err = repr(e)[:200] + (f" [primary: {cause!r}]"[:100]
                                   if cause is not None else "")
            for r in reqs:
                self._resolve(r, Outcome("error", r.future.request_id,
                                         trip=trip, error=err,
                                         retries=retries, batched=len(reqs)))
            return
        transitions = len(report.transitions) if report is not None else 0
        for i, r in enumerate(reqs):
            self._resolve(r, Outcome("degraded", r.future.request_id,
                                     value=y[i], trip=trip, retries=retries,
                                     transitions=transitions,
                                     batched=len(reqs)))

    def _on_trip(self, plan, key, direction, nfields, err):
        """Breaker just tripped: quarantine the failing schedule in the
        shared DB and retune + re-warm in the background, off the hot
        path (requests keep flowing through the fallback meanwhile)."""
        from repro.robustness import runner

        log.warning("circuit breaker tripped for plan %s...: %r",
                    key[:60], err)
        if plan.method == "auto":
            try:
                runner._quarantine_and_retune(
                    plan, nfields if nfields > 1 else 1, err)
            except Exception as qe:  # pragma: no cover - quarantine best-effort
                log.warning("quarantine failed: %r", qe)

        def _retune():
            try:
                plan.warm((direction,),
                          nfields=nfields if nfields > 1 else 1)
                self._bump("retunes")
                log.info("background retune/rewarm complete for %s...",
                         key[:60])
            except Exception as re_:  # pragma: no cover - retune best-effort
                log.warning("background retune failed: %r", re_)

        t = threading.Thread(target=_retune, name="repro-serve-retune",
                             daemon=True)
        self._retune_threads.append(t)
        t.start()
