"""Checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/{manifest.json, <leaf-path>.npy ...}

* **atomic** — writes go to ``step_N.tmp`` and are renamed only after the
  manifest (with per-leaf byte checksums) is fsynced; a crashed write can
  never be mistaken for a valid checkpoint.
* **async** — ``CheckpointManager.save_async`` snapshots device arrays to
  host (the only step on the critical path) and writes on a worker thread.
* **elastic** — a checkpoint records *logical* arrays + the PartitionSpec
  strings they were saved under.  ``load_checkpoint(..., shardings=...)``
  re-``device_put``s every leaf into the *target* shardings, so a job can
  restart on a different mesh shape (re-shard on load).  On real multi-host
  clusters the .npy writes would be replaced by tensorstore per-shard
  writes; the manifest/restore protocol is unchanged (DESIGN.md §6).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path)
        out[key] = leaf
    return out, jax.tree_util.tree_structure(tree)


def save_checkpoint(directory: str | os.PathLike, step: int, tree, *,
                    extra: dict | None = None, keep: int = 3) -> Path:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = directory / f"step_{step:010d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, _ = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        store = arr.view(np.uint16) if dtype == "bfloat16" else arr
        fn = key.replace("/", "__") + ".npy"
        np.save(tmp / fn, store)
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": dtype,
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    steps = sorted(p for p in directory.glob("step_*") if not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                   if not p.name.endswith(".tmp") and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def _intact_steps(directory: Path) -> list[int]:
    """Step numbers with a renamed (non-.tmp) dir and a manifest, ascending."""
    return sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                  if not p.name.endswith(".tmp") and (p / "manifest.json").exists())


def _load_step(directory: Path, step: int, flat, treedef, shard_flat, verify: bool):
    """Restore one specific checkpoint step (raises on any corruption)."""
    ckpt = directory / f"step_{step:010d}"
    with open(ckpt / "manifest.json") as f:
        manifest = json.load(f)
    out = {}
    for key in flat:
        meta = manifest["leaves"][key]
        arr = np.load(ckpt / meta["file"])
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if verify and hashlib.sha1(arr.tobytes()).hexdigest() != meta["sha1"]:
            raise IOError(f"checksum mismatch for {key} in {ckpt}")
        if key in shard_flat:
            out[key] = jax.device_put(arr, shard_flat[key])
        else:
            out[key] = arr
    leaves = [out[k] for k in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def load_checkpoint(directory: str | os.PathLike, tree_like, *, step: int | None = None,
                    shardings=None, verify: bool = True, fallback: bool = True):
    """Restore into the structure of ``tree_like``; re-shard if ``shardings``
    (a congruent tree of Shardings) is given — the elastic-restart path.

    With ``step=None`` (restore-latest) and ``fallback=True``, a checkpoint
    that fails to restore — checksum mismatch, torn/missing leaf file,
    unreadable manifest — does not strand the job: the loader walks earlier
    intact checkpoints newest-first, warns about every one it skips, and
    records them in the returned manifest as ``manifest["skipped_steps"]``
    (``[{"step", "error"}, ...]``) so the caller can see exactly how much
    progress was lost.  Only when *every* checkpoint is corrupt does it
    raise, with each step's failure in the message.  An explicit ``step=``
    (or ``fallback=False``) keeps the old fail-fast behavior."""
    directory = Path(directory)
    flat, treedef = _flatten(tree_like)
    shard_flat = _flatten(shardings)[0] if shardings is not None else {}
    if step is not None:
        return _load_step(directory, step, flat, treedef, shard_flat, verify)

    steps = _intact_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    if not fallback:
        return _load_step(directory, steps[-1], flat, treedef, shard_flat, verify)

    skipped: list[dict] = []
    for s in reversed(steps):
        try:
            tree, manifest = _load_step(directory, s, flat, treedef, shard_flat,
                                        verify)
        except (OSError, ValueError, KeyError, EOFError) as e:
            import warnings

            warnings.warn(f"skipping corrupt checkpoint step {s}: {e!r}",
                          stacklevel=2)
            skipped.append({"step": s, "error": repr(e)[:300]})
            continue
        if skipped:
            manifest = dict(manifest)
            manifest["skipped_steps"] = skipped
        return tree, manifest
    detail = "; ".join(f"step {d['step']}: {d['error']}" for d in skipped)
    raise IOError(f"every checkpoint under {directory} is corrupt — {detail}")


class AsyncCheckpointError(RuntimeError):
    """A background ``save_async`` write failed.  ``step`` names the
    checkpoint whose write died; ``__cause__`` carries the original
    exception.  Raised by the *next* ``wait()``/``save_async()`` call —
    a failed background checkpoint can never pass silently."""

    def __init__(self, step: int, cause: BaseException):
        super().__init__(
            f"async checkpoint write for step {step} failed: {cause!r}")
        self.step = step


class CheckpointManager:
    """Async checkpointing with at-most-one outstanding write.

    Failure surfacing: a worker-thread exception is recorded (wrapped in
    :class:`AsyncCheckpointError` with the failing step) and re-raised on
    the next ``wait()`` or ``save_async()`` call — ``save_async`` waits on
    the previous write *before* snapshotting, so the error surfaces before
    any new write is admitted.  A manager garbage-collected with an
    unsurfaced error emits a ``RuntimeWarning`` as a last resort."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: AsyncCheckpointError | None = None

    def save_async(self, step: int, tree, *, extra: dict | None = None):
        # serialize writes AND surface any previous write's failure before
        # admitting this one; snapshot below is the sync part
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra,
                                keep=self.keep)
            except BaseException as e:  # surfaced on next wait()/save_async()
                err = AsyncCheckpointError(step, e)
                err.__cause__ = e
                self._error = err

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        """Join the outstanding write, re-raising its failure (if any) as
        :class:`AsyncCheckpointError`."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self):
        return latest_step(self.directory)

    def __del__(self):
        err = getattr(self, "_error", None)
        if err is not None:  # pragma: no cover - interpreter-shutdown timing
            import warnings

            warnings.warn(f"CheckpointManager dropped without surfacing a "
                          f"failed async write: {err}", RuntimeWarning,
                          stacklevel=1)
