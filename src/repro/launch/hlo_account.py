"""Trip-count-aware accounting over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while body ONCE, which silently
undercounts scan-over-layers programs by ~L (and the same bug would hit a
naive collective-bytes grep).  This module parses the HLO text into
computations, multiplies each computation's contribution by its execution
count (XLA annotates ``known_trip_count`` on while ops), and produces:

  flops              — 2*K*prod(result) per dot, trip-aware
  collectives[kind]  — per-device payload bytes per collective kind,
                       trip-aware (all-gather result/G, reduce-scatter
                       result*G, all-to-all result*(G-1)/G — each device
                       keeps one of its G split chunks, so only the other
                       G-1 cross the wire; others result-sized)
  hbm_bytes          — streaming-traffic model, trip-aware: for every
                       top-level instruction, bytes actually read from
                       operands + bytes actually written.  Slicing
                       semantics are honoured: ``dynamic-slice`` reads its
                       *result* size, ``dynamic-update-slice`` reads+writes
                       its *update* size (the buffer is aliased in place),
                       and fusions are analysed through their fused
                       computation — a parameter consumed only by an
                       internal dynamic-slice contributes the slice size,
                       a root dynamic-update-slice writes only its update.
                       Without this, scan-over-layers caches (L, B, S, H, D)
                       would be charged in full every layer step (~100x
                       overcount).  This is the roofline *memory* term.

Known approximations (documented in EXPERIMENTS.md §Roofline):
  * conditional branches both counted (upper bound);
  * dots inside fused computations (rare on CPU) counted with the fusion's
    multiplier;
  * whiles without a known_trip_count annotation count once (warned).
"""

from __future__ import annotations

import re
import warnings
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

# any dtype-shaped token (known families + pred), for detecting shapes whose
# dtype is missing from _DTYPE_BYTES: those are warned about once per dtype
# instead of silently dropped from the byte accounting
_ANY_SHAPE_RE = re.compile(r"\b((?:f|bf|c|s|u)[0-9][a-z0-9]*|pred)\[[0-9,]*\]")
_WARNED_DTYPES: set[str] = set()
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\]{},\s]*?))\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:calls|to_apply|condition|body|branch_computations)="
                        r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
                 "while", "conditional", "call", "custom-call", "after-all",
                 "partition-id", "replica-id", "iota"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _types_bytes(text: str) -> int:
    for tok in _ANY_SHAPE_RE.findall(text):
        if tok not in _DTYPE_BYTES and tok not in _WARNED_DTYPES:
            _WARNED_DTYPES.add(tok)
            warnings.warn(
                f"hlo_account: dtype {tok!r} missing from _DTYPE_BYTES; "
                f"shapes of this dtype are excluded from byte accounting",
                stacklevel=2)
    return sum(_shape_elems(dims) * _DTYPE_BYTES[t] for t, dims in _SHAPE_RE.findall(text))


def _group_size(line: str, n_operands: int = 0) -> int:
    """Replica-group size of a collective: parsed from either the iota
    (``replica_groups=[G,S]<=[N]``) or explicit-list
    (``replica_groups={{a,b},...}``) HLO form; falls back to the operand
    count for the decomposed (tuple-operand) all-to-all the CPU backend
    emits without annotations."""
    g = _GROUPS_RE.search(line)
    if g:
        return max(int(g.group(2)), 1)
    g = _GROUPS_LIST_RE.search(line)
    if g:
        return max(len([t for t in g.group(1).split(",") if t.strip()]), 1)
    return max(n_operands, 1)


@dataclass
class Instr:
    name: str
    result: str           # result-type text
    op: str
    line: str
    operands: list[str]


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)


def parse(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("(", 1)[0]:
            m = _COMP_NAME_RE.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            continue
        dm = _DEF_RE.match(line)
        if not dm or cur is None:
            continue
        name, rhs = dm.group(1), dm.group(2)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        result, op = om.group(1), om.group(2)
        call = rhs[om.end():]
        depth, end = 1, len(call)
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(call[:end])
        cur.instrs.append(Instr(name, result, op, line, operands))
    return comps


def _instr_index(comps: dict[str, Computation]) -> dict[str, Instr]:
    out = {}
    for c in comps.values():
        for i in c.instrs:
            out[i.name] = i
    return out


def _entry_name(comps: dict[str, Computation], hlo: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(iter(comps))


def execution_counts(comps: dict[str, Computation], hlo: str) -> dict[str, float]:
    """Multiplier per computation, walking calls from the entry."""
    entry = _entry_name(comps, hlo)
    mult: dict[str, float] = {}
    warn: list[str] = []

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps[name].instrs:
            called: list[str] = []
            for g1, g2 in _CALLED_RE.findall(ins.line):
                if g1:
                    called += [c.strip().lstrip("%") for c in g1.split(",")]
                elif g2:
                    called.append(g2)
            if not called:
                continue
            child_m = m
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    child_m = m * int(tm.group(1))
                else:
                    warn.append(ins.name)
            for c in called:
                visit(c, child_m)

    visit(entry, 1.0)
    if warn:
        mult["_warn_unknown_trip"] = len(warn)
    return mult


def _slice_aware_bytes(ins: Instr, index: dict[str, Instr],
                       comps: dict[str, Computation]) -> float:
    """Read+write HBM bytes for one top-level instruction."""
    if ins.op == "dynamic-slice":
        return 2.0 * _types_bytes(ins.result)            # read slice + write
    if ins.op == "dynamic-update-slice":
        upd = index.get(ins.operands[1]) if len(ins.operands) > 1 else None
        b = _types_bytes(upd.result) if upd else _types_bytes(ins.result)
        return 2.0 * b                                    # read + write update
    if ins.op == "fusion":
        called = [g2 for _g1, g2 in _CALLED_RE.findall(ins.line) if g2]
        comp = comps.get(called[0]) if called else None
        if comp is None:
            return float(_types_bytes(ins.result))
        inner_index = {i.name: i for i in comp.instrs}
        # elementwise-reinterpret ops: data flows through untouched (the
        # convert itself costs traffic only if its full extent is consumed
        # downstream, which the terminal-consumer analysis captures)
        passthru = {"bitcast", "copy", "reshape", "convert", "transpose"}

        def terminal_uses(name: str, seen=None) -> list[tuple[Instr, str]]:
            """Terminal (non-pass-through) consumers reached from ``name``,
            paired with the immediate operand name they consume."""
            seen = seen or set()
            out: list[tuple[Instr, str]] = []
            for u in (i for i in comp.instrs if name in i.operands):
                if u.name in seen:
                    continue
                seen.add(u.name)
                if u.op in passthru:
                    out += terminal_uses(u.name, seen)
                else:
                    out.append((u, name))
            return out

        total = 0.0
        # reads: per fusion parameter, honour internal slicing through
        # pass-through chains (convert(param) -> dus[0] reads nothing, etc.)
        for p in comp.instrs:
            if p.op != "parameter":
                continue
            uses = terminal_uses(p.name)
            if not uses:
                continue
            if all(u.op == "dynamic-slice" for u, _ in uses):
                total += max(_types_bytes(u.result) for u, _ in uses)
            elif all(u.op == "dynamic-update-slice" and u.operands
                     and u.operands[0] == via for u, via in uses):
                pass                                      # aliased buffer: no read
            else:
                total += _types_bytes(p.result)
        # writes: peel pass-through wrappers off the root; dus writes update
        root = next((i for i in comp.instrs if "ROOT" in i.line.split("=")[0]),
                    comp.instrs[-1] if comp.instrs else None)

        def write_bytes(node: Instr | None, depth=0) -> float:
            if node is None:
                return float(_types_bytes(ins.result))
            if node.op in passthru and node.operands and depth < 8:
                inner = inner_index.get(node.operands[0])
                if inner is not None:
                    return write_bytes(inner, depth + 1)
            if node.op == "dynamic-update-slice" and len(node.operands) > 1:
                upd = inner_index.get(node.operands[1])
                return float(_types_bytes(upd.result if upd else node.result))
            if node.op == "tuple":
                return sum(write_bytes(inner_index.get(o), depth + 1)
                           for o in node.operands)
            return float(_types_bytes(node.result))
        total += write_bytes(root)
        return total
    # default: read all operands + write result
    b = float(_types_bytes(ins.result))
    for o in ins.operands:
        src = index.get(o)
        if src is not None:
            b += _types_bytes(src.result)
    return b


def _collective_payload_bytes(ins: Instr) -> int:
    """Per-device wire bytes of one collective instruction.

    all-gather contributes its shard (result/G); reduce-scatter reads
    result*G; all-to-all ships result*(G-1)/G — of the G split chunks each
    device produces, one stays local and G-1 cross the wire (this matches
    :func:`repro.core.redistribute.exchange_wire_bytes`'s (m-1)/m element
    count, so planlint can diff the two directly); everything else is
    priced result-sized."""
    base = ins.op.replace("-start", "")
    b = _types_bytes(ins.result)
    gsize = _group_size(ins.line, len(ins.operands))
    if base == "all-gather":
        b //= gsize
    elif base == "reduce-scatter":
        b *= gsize
    elif base == "all-to-all":
        b = b * (gsize - 1) // gsize
    return b


def collective_instrs(hlo: str) -> list[dict]:
    """Per-collective records of an optimized HLO module, trip-aware: one
    dict per collective instruction in an executed computation, with

      kind           — collective op name ("all-to-all", ...)
      name           — instruction name
      computation    — enclosing computation
      mult           — execution count (trip-aware while multiplier)
      group_size     — replica-group size (operand count for the CPU
                       backend's decomposed tuple all-to-all)
      result_bytes   — full result size
      payload_bytes  — per-device wire bytes (see
                       :func:`_collective_payload_bytes`), x ``mult``
      dtypes         — dtype tokens appearing in the result shape

    This is the per-instruction view :mod:`repro.analysis.planlint` diffs
    against a plan's analytic ``exchange_wire_bytes`` model; ``account``
    keeps returning only per-kind totals."""
    comps = parse(hlo)
    mults = execution_counts(comps, hlo)
    out = []
    for cname, comp in comps.items():
        m = mults.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            base = ins.op.replace("-start", "")
            if base not in _COLLECTIVES or ins.op.endswith("-done"):
                continue
            out.append({
                "kind": base,
                "name": ins.name,
                "computation": cname,
                "mult": m,
                "group_size": _group_size(ins.line, len(ins.operands)),
                "result_bytes": _types_bytes(ins.result),
                "payload_bytes": m * _collective_payload_bytes(ins),
                "dtypes": sorted({t for t, _ in _SHAPE_RE.findall(ins.result)}),
            })
    return out


def account(hlo: str) -> dict:
    comps = parse(hlo)
    index = _instr_index(comps)
    mults = execution_counts(comps, hlo)

    flops = 0.0
    coll: dict[str, float] = {}
    hbm = 0.0
    # computations that are fusion bodies (referenced via calls= of a fusion)
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                for _g1, g2 in _CALLED_RE.findall(ins.line):
                    if g2:
                        fusion_bodies.add(g2)

    for cname, comp in comps.items():
        m = mults.get(cname, 0.0)
        if m == 0.0:
            continue
        inside_fusion = cname in fusion_bodies
        for ins in comp.instrs:
            if ins.op == "fft":
                # 5 N log2 N per length-N transform over the batch
                import math as _math
                mlen = re.search(r"fft_length=\{([0-9,]+)\}", ins.line)
                sm = _SHAPE_RE.search(ins.result)
                if mlen and sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    n = 1
                    for d in mlen.group(1).split(","):
                        n *= int(d)
                    total = 1
                    for d in dims:
                        total *= d
                    batch = total / max(n, 1)
                    flops += m * 5.0 * batch * n * max(_math.log2(max(n, 2)), 1.0)
            if ins.op == "dot":
                res = _shape_elems(_SHAPE_RE.search(ins.result).group(2)) \
                    if _SHAPE_RE.search(ins.result) else 0
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
                k = 1
                if cm and ins.operands:
                    lhs = index.get(ins.operands[0])
                    if lhs is not None:
                        sm = _SHAPE_RE.search(lhs.result)
                        if sm:
                            dims = [int(d) for d in sm.group(2).split(",") if d]
                            for ci in cm.group(1).split(","):
                                if ci:
                                    k *= dims[int(ci)]
                flops += m * 2.0 * res * k
            base = ins.op.replace("-start", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                coll[base] = coll.get(base, 0.0) + m * _collective_payload_bytes(ins)
            # streaming HBM-traffic model (top-level only)
            if not inside_fusion and ins.op not in _SKIP_TRAFFIC \
                    and not ins.op.endswith("-done"):
                hbm += m * _slice_aware_bytes(ins, index, comps)
    coll["total"] = sum(coll.values())
    return {"flops": flops, "collectives": coll, "hbm_bytes": hbm,
            "unknown_trip_whiles": int(mults.get("_warn_unknown_trip", 0))}
