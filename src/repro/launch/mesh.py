"""Production meshes.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips.  The ``pod`` axis composes with ``data`` for every
data-parallel collective (axis tuples ``("pod", "data")``), so growing the
pod count never changes per-layer shardings — elastic across pods.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run pins the device count before first jax init).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.core.meshutil import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants (roofline; per assignment)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
