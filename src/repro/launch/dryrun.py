import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod) from 512 placeholder host devices, constructs the *full*
published architecture, and lowers + compiles the appropriate step:

    train_4k    -> train_step   (loss + grads + AdamW update, donated state)
    prefill_32k -> prefill      (32k prompt -> KV/SSM cache + last logits)
    decode_32k  -> decode_step  (1 token against a 32k cache)
    long_500k   -> decode_step  (1 token, 512k state; sub-quadratic archs)

Nothing is ever allocated: params/batches/caches enter as
ShapeDtypeStructs.  The compiled artifact yields ``memory_analysis()``
(proves the cell fits HBM) and ``cost_analysis()`` (FLOPs/bytes), and the
post-SPMD HLO text is scanned for collective operand bytes — the three
roofline terms (EXPERIMENTS.md §Roofline) come from these.

Usage:
  python -m repro.launch.dryrun --arch glm4_9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts/dryrun
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax

from repro.core.meshutil import set_mesh
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.dryrun_lib import (DEFAULT_OUT, _sds, batch_shardings,
                                     collective_bytes, input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.config import active_param_count, param_count
from repro.models.lm import LM
from repro.models.sharding import Axes
from repro.optim import AdamW, OptState

# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


FLAG_MAP = {  # --flags shorthand -> PerfFlags field
    "bf16": {"bf16_attention": True},
    "tri": {"exact_causal_prefill": True},
    "dots": {"remat_policy": "dots"},
    "spres": {"seq_sharded_residual": True},
    "hmaj": {"hmajor_cache": True},
}


def resolve_flags(opt: bool, flags: str):
    from repro.models.lm import OPTIMIZED, PerfFlags
    if opt:
        return OPTIMIZED
    kw = {}
    for f in (flags or "").split(","):
        f = f.strip()
        if f:
            kw.update(FLAG_MAP[f])
    return PerfFlags(**kw)


def build_lm(cfg, mesh, multi_pod: bool, global_batch: int, *, sp_mode="none",
             opt: bool = False, flags: str = ""):
    axes = Axes(multi_pod=multi_pod)
    dp = int(np.prod([mesh.shape[a] for a in axes.dp]))
    batch_sharded = global_batch % dp == 0 and global_batch >= dp
    lm = LM(cfg, mesh, axes, q_block=512, xent_chunks=16, sp_mode=sp_mode,
            batch_sharded=batch_sharded, perf=resolve_flags(opt, flags))
    return lm, axes


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, sp_mode="none",
               opt: bool = False, flags: str = "", compile_: bool = True):
    """Lower (and compile) one cell; returns the result record."""
    cfg = configs.get(arch)
    S, B, kind = configs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    lm, axes = build_lm(cfg, mesh, multi_pod, B, sp_mode=sp_mode, opt=opt,
                        flags=flags)
    pshard = lm.param_shardings()
    aparams = lm.abstract_params()
    t0 = time.time()

    with set_mesh(mesh):
        if kind == "train":
            optimizer = AdamW(lr=1e-4)
            aopt = jax.eval_shape(optimizer.init, aparams)
            oshard = OptState(NamedSharding(mesh, P()), pshard, pshard)
            batch, _ = input_specs(cfg, shape_name)
            bshard = batch_shardings(mesh, axes, batch, B)

            def step_fn(params, opt_state, b):
                (loss, metrics), grads = jax.value_and_grad(lm.loss, has_aux=True)(params, b)
                params, opt_state, om = optimizer.update(grads, opt_state, params)
                return params, opt_state, {"loss": loss}

            jfn = jax.jit(step_fn, in_shardings=(pshard, oshard, bshard),
                          out_shardings=(pshard, oshard, None),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(aparams, aopt, batch)
        elif kind == "prefill":
            batch, _ = input_specs(cfg, shape_name)
            bshard = batch_shardings(mesh, axes, batch, B)
            acache = jax.eval_shape(lambda p, b: lm.prefill(p, b, max_len=None),
                                    aparams, batch)[0]
            cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                  lm.cache_specs(acache),
                                  is_leaf=lambda x: isinstance(x, P))

            def step_fn(params, b):
                return lm.prefill(params, b, max_len=None)

            jfn = jax.jit(step_fn, in_shardings=(pshard, bshard),
                          out_shardings=(cshard, None))
            lowered = jfn.lower(aparams, batch)
        else:  # decode
            small = {"tokens": _sds((B, 8), jnp.int32)}
            if cfg.family == "vlm":
                small["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
            if cfg.family == "audio":
                small["frontend"] = _sds((B, 512, cfg.d_model), jnp.bfloat16)
            acache = jax.eval_shape(lambda p, b: lm.prefill(p, b, max_len=S),
                                    aparams, small)[0]
            cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                  lm.cache_specs(acache),
                                  is_leaf=lambda x: isinstance(x, P))
            dp = int(np.prod([mesh.shape[a] for a in axes.dp]))
            tshard = NamedSharding(mesh, P(axes.dp if B % dp == 0 and B >= dp else None))

            def step_fn(params, cache, token, cur_len):
                return lm.decode_step(params, cache, token, cur_len)

            jfn = jax.jit(step_fn,
                          in_shardings=(pshard, cshard, tshard, NamedSharding(mesh, P())),
                          out_shardings=(cshard, None),
                          donate_argnums=(1,))
            lowered = jfn.lower(aparams, acache, _sds((B,), jnp.int32), _sds((), jnp.int32))

        rec = {
            "arch": arch, "shape": shape_name, "kind": kind,
            "mesh": "multi" if multi_pod else "single",
            "chips": int(np.prod(list(mesh.shape.values()))),
            "seq": S, "batch": B, "sp_mode": sp_mode, "opt": opt,
            "flags": flags,
            "params": param_count(cfg), "active_params": active_param_count(cfg),
            "lower_s": round(time.time() - t0, 1),
        }
        if not compile_:
            return rec, lowered

        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0 - rec["lower_s"], 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)
            }
            if hasattr(ma, "peak_memory_in_bytes"):
                rec["memory"]["peak_memory_in_bytes"] = int(ma.peak_memory_in_bytes)
        except Exception as e:  # CPU backend may not expose it
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            rec["cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float)) and
                           k in ("flops", "bytes accessed", "transcendentals",
                                 "utilization operand 0 {}", "optimal_seconds")}
            rec["flops_per_device"] = float(ca.get("flops", 0.0))
            rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
        except Exception as e:
            rec["cost"] = {"error": str(e)}
        hlo_text = compiled.as_text()
        rec["collective_bytes_per_device"] = collective_bytes(hlo_text)
        # trip-count-aware accounting (cost_analysis counts while bodies once)
        from repro.launch.hlo_account import account
        acct = account(hlo_text)
        rec["acct"] = {
            "flops_per_device": acct["flops"],
            "hbm_bytes_per_device": acct["hbm_bytes"],
            "collectives_per_device": acct["collectives"],
            "unknown_trip_whiles": acct["unknown_trip_whiles"],
        }
        return rec, compiled


def run_cell(arch, shape_name, *, multi_pod, out_dir, sp_mode="none", force=False,
             opt=False, flags=""):
    tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
    if sp_mode != "none":
        tag += f"__{sp_mode}"
    if opt:
        tag += "__opt"
    if flags:
        tag += "__" + flags.replace(",", "-")
    out = Path(out_dir) / f"{tag}.json"
    if out.exists() and not force:
        print(f"[skip] {tag} (cached)")
        return json.loads(out.read_text())
    print(f"[run ] {tag} ...", flush=True)
    rec, _ = lower_cell(arch, shape_name, multi_pod=multi_pod, sp_mode=sp_mode,
                        opt=opt, flags=flags)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    acct = rec.get("acct", {})
    coll = acct.get("collectives_per_device", {}).get("total", 0)
    print(f"[ ok ] {tag}: flops/dev={acct.get('flops_per_device', 0):.3e} "
          f"hbm/dev={acct.get('hbm_bytes_per_device', 0):.3e}B "
          f"coll/dev={coll:.3e}B lower={rec['lower_s']}s "
          f"compile={rec.get('compile_s', '?')}s", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--sp-mode", type=str, default="none")
    ap.add_argument("--flags", type=str, default="",
                    help="comma list of bf16,tri,dots,spres (single-flag "
                         "attribution runs for §Perf)")
    ap.add_argument("--opt", action="store_true",
                    help="enable PerfFlags OPTIMIZED (bf16 attention, exact "
                         "causal prefill, dots remat) — the §Perf variant")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = configs.all_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape in cells:
        for m in meshes:
            try:
                run_cell(arch, shape, multi_pod=(m == "multi"), out_dir=args.out,
                         sp_mode=args.sp_mode, force=args.force, opt=args.opt,
                         flags=args.flags)
            except Exception as e:
                failures.append((arch, shape, m, repr(e)))
                print(f"[FAIL] {arch}/{shape}/{m}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", *f)
        sys.exit(1)
    print("\nDRY-RUN: all requested cells lowered+compiled OK")


if __name__ == "__main__":
    main()
