"""Production training CLI.

  python -m repro.launch.train --arch glm4_9b --preset smoke --steps 20
  python -m repro.launch.train --arch qwen2_72b --preset full ...   # real pods

``--preset smoke`` runs the reduced same-family config on the host devices
(CPU-friendly); ``--preset full`` uses the published config and expects the
production mesh's worth of devices (on TPU pods, started per-host under the
cluster runtime with the same flags).  Fault tolerance is inherited from
``repro.runtime.Trainer``: atomic/async checkpoints, elastic restore,
SIGTERM-clean preemption, heartbeat + straggler events.
"""

from __future__ import annotations

import argparse


from repro import configs
from repro.data import SyntheticLMData
from repro.models.lm import LM
from repro.models.sharding import Axes
from repro.runtime import TrainConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--sp-mode", default="none", choices=["none", "ulysses"])
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.preset == "smoke" else configs.get(args.arch)
    seq = args.seq or (32 if args.preset == "smoke" else 4096)
    gbs = args.global_batch or (4 if args.preset == "smoke" else 256)

    if args.preset == "full":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    else:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.model_parallel)
    axes = Axes(multi_pod="pod" in mesh.shape)
    lm = LM(cfg, mesh, axes, sp_mode=args.sp_mode,
            q_block=min(512, seq), xent_chunks=min(8, seq))
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=seq, global_batch=gbs)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir or f"/tmp/repro_train_{args.arch}",
                     lr=args.lr, warmup=max(2, args.steps // 10))
    trainer = Trainer(lm, data, tc)

    def log(m):
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['time']:.2f}s", flush=True)

    _, _, hist = trainer.run(on_metrics=log)
    losses = [h["loss"] for h in hist]
    print(f"trained {len(hist)} steps; loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
