"""Deprecated alias: ``repro.launch.serve`` → :mod:`repro.launch.serve_lm`.

The LM demo server was renamed so that ``python -m repro.serve`` is
unambiguously the spectral FFT serving engine (:mod:`repro.serve`).  This
stub keeps old invocations working one release; import it and you get the
renamed module's surface plus a DeprecationWarning.
"""

from __future__ import annotations

import warnings

from repro.launch.serve_lm import *  # noqa: F401,F403 - re-export the surface
from repro.launch.serve_lm import main  # noqa: F401 - explicit for -m use

warnings.warn(
    "repro.launch.serve was renamed to repro.launch.serve_lm "
    "(python -m repro.serve is now the spectral FFT serving engine); "
    "this alias will be removed in a future release",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    raise SystemExit(main())
