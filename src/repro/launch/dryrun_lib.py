"""Pure dry-run helpers (no env mutation — importable from tests).

``repro.launch.dryrun`` pins the 512-device XLA flag and drives these; unit
tests import this module directly so the flag never leaks into their
process.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.models.sharding import Axes

DEFAULT_OUT = "benchmarks/artifacts/dryrun"


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape_name: str, *, batch_override: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    S, B, kind = configs.SHAPES[shape_name]
    if batch_override is not None:
        B = batch_override
    if kind == "train" or kind == "prefill":
        batch = {
            "tokens": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
            "mask": _sds((B, S), jnp.float32),
        }
        if kind == "prefill":
            batch = {"tokens": batch["tokens"]}
        if cfg.family == "vlm":
            batch["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frontend"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        return batch, (B, S, kind)
    # decode: token + cur_len (cache is built separately)
    return {"token": _sds((B,), jnp.int32), "cur_len": _sds((), jnp.int32)}, (B, S, kind)


def batch_shardings(mesh, axes: Axes, batch, global_batch: int):
    dp = int(np.prod([mesh.shape[a] for a in axes.dp]))
    b = axes.dp if (global_batch % dp == 0 and global_batch >= dp) else None

    def leaf(x):
        spec = P(b, *(None,) * (x.ndim - 1))
        return NamedSharding(mesh, spec)

    return jax.tree.map(leaf, batch)


# ---------------------------------------------------------------------------
# Collective-byte accounting (parse post-SPMD HLO)
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8, "c128": 16}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Per-device operand bytes of every collective in the post-SPMD HLO.

    Optimized HLO names operands by reference, so we take the *result*
    type(s) printed on the instruction and convert to operand ("payload")
    bytes using the replica-group size G:  all-gather operand = result/G;
    reduce-scatter operand = result*G; all-reduce / all-to-all /
    collective-permute operand = result.  ``-done`` halves of async pairs
    are not double counted.  Returns totals by collective kind.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_types, kind = m.group(1), m.group(2)
        b = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_types))
        g = _GROUPS_RE.search(line)
        gsize = int(g.group(2)) if g else 1
        if kind == "all-gather" and gsize:
            b //= gsize
        elif kind == "reduce-scatter":
            b *= gsize
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


