"""LM serving demo CLI: batched prefill + decode with the sequence-sharded
cache.  (Formerly ``repro.launch.serve``; renamed so ``python -m
repro.serve`` unambiguously means the spectral FFT serving engine.)

  python -m repro.launch.serve_lm --arch glm4_9b --preset smoke --batch 4 \
      --prompt-len 32 --gen 16

Serves a batch of synthetic prompts end-to-end: one prefill (cache build +
first logits) and ``--gen`` greedy decode steps, reporting per-phase
timings.  With ``--preset full`` on a production mesh, the same code path
is the one the dry-run compiles for decode_32k/long_500k cells.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.core.meshutil import set_mesh
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.lm import LM
from repro.models.sharding import Axes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.preset == "smoke" else configs.get(args.arch)
    if args.preset == "full":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    else:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.model_parallel)
    lm = LM(cfg, mesh, Axes(multi_pod="pod" in mesh.shape),
            q_block=min(512, args.prompt_len), xent_chunks=1,
            batch_sharded=args.batch % mesh.shape["data"] == 0)

    key = jax.random.PRNGKey(0)
    with set_mesh(mesh):
        params = lm.init_params(key)
        B, S = args.batch, args.prompt_len
        off = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
        M = S + off + args.gen
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
        if cfg.family == "vlm":
            batch["frontend"] = jax.random.normal(key, (B, off, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frontend"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)

        prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len=M))
        decode = jax.jit(lm.decode_step, donate_argnums=(1,))

        t0 = time.perf_counter()
        cache, logits = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab], -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0

        out_tokens = [np.asarray(tok)]
        cur = S + off
        t0 = time.perf_counter()
        for _ in range(args.gen):
            cache, logits = decode(params, cache, tok, jnp.int32(cur))
            tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok))
            cur += 1
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill:.3f}s ({B * S / t_prefill:.0f} tok/s)  "
          f"decode: {t_decode:.3f}s ({B * args.gen / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample generated ids:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
