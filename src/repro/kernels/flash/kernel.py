"""Pallas TPU kernel: causal flash attention with triangular block skip.

Grid = (batch*kv_heads, q_blocks, kv_blocks); one step contracts a
(block_q, dh) x (block_k, dh) tile pair in VMEM with online softmax.
``pl.when`` skips every strictly-upper block (j > i) — on TPU the skipped
grid step costs only the (empty) control iteration, so causal attention
runs at the exact triangular FLOP count.  This is the hardware answer to
the 2x masked-FLOP overhead of the XLA-level blockwise path (§Perf), and
the reason kernels/ exists for this hot-spot.

Layout: q (BH, Sq, dh), k/v (BH, Skv, dh) with the GQA group folded into
BH by the ops.py wrapper (q heads of one kv head share its k/v tiles).
fp32 accumulators live in VMEM scratch; output is written on the last
unskipped kv step of each q row.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, sm_scale: float, causal: bool):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip blocks entirely above the causal diagonal: visible iff some
    # q_pos >= k_pos, i.e. the block's first k position <= last q position
    run = (j * block_k <= i * block_q + (block_q - 1)) if causal else (j >= 0)

    @pl.when(run)
    def _step():
        q = q_ref[0]                       # (block_q, dh)
        k = k_ref[0]                       # (block_k, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        if causal:
            q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:  # last visible kv block for this q row (uneven blocks ok)
        last = jnp.minimum(nk - 1, ((i + 1) * block_q - 1) // block_k)
    else:
        last = nk - 1

    @pl.when(j == last)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_pallas_call(bh: int, sq: int, skv: int, dh: int, *, block_q: int,
                      block_k: int, causal: bool, dtype, interpret: bool):
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    grid = (bh, sq // block_q, skv // block_k)
    kern = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                             sm_scale=1.0 / math.sqrt(dh), causal=causal)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),    # l (running sum)
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc
        ],
        interpret=interpret,
    )
