"""Jit'd wrapper for the causal flash-attention Pallas kernel.

``flash_attention(q, k, v, causal=True)`` takes (B, S, Hq, dh) / (B, S,
Hkv, dh) GQA tensors; q-head groups are folded onto their kv head so each
grid row reads one kv tile set.  Blocks default to MXU-aligned (512, 512)
and clamp to the sequence.  TPU is the target; CPU validates via
``interpret=True`` against ``ref.attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_pallas_call


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    pad_q, pad_k = -Sq % bq, -Skv % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:  # padded kv must be masked out: rely on causal (pads are at end)
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        if not causal:
            raise ValueError("non-causal flash path needs Skv % block_k == 0")
    # fold GQA: (B, S, Hkv, G, dh) -> (B*Hkv*G, S, dh) sharing kv per group
    qf = q.reshape(B, Sq + pad_q, Hkv, G, dh).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(B * Hkv * G, Sq + pad_q, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv + pad_k, dh),
                    G, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv + pad_k, dh),
                    G, axis=0)
    call = flash_pallas_call(B * Hq, Sq + pad_q, Skv + pad_k, dh,
                             block_q=bq, block_k=bk, causal=causal,
                             dtype=v.dtype, interpret=interpret)
    o = call(qf, kf, vf)
    o = o.reshape(B, Hkv, G, Sq + pad_q, dh).transpose(0, 3, 1, 2, 4)
    return o.reshape(B, Sq + pad_q, Hq, dh)[:, :Sq]
