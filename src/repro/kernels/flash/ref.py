"""Pure-jnp oracle for the flash-attention kernel."""

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool):
    """q (BH, Sq, dh), k/v (BH, Skv, dh) -> (BH, Sq, dh), fp32 math."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(v.dtype)
