"""Fused exchange-local kernels (Pallas): realignment-free pack/codec.

See :mod:`repro.kernels.exchange.ops` for the engine-facing API and
:mod:`repro.kernels.exchange.kernel` for the pallas_call builders.
"""

from repro.kernels.exchange.ops import (  # noqa: F401
    decode_payload,
    encode_payload,
    pack_chunks,
    pallas_applicable,
    unpack_chunks,
)
