"""Engine-facing wrappers for the fused exchange kernels.

Four entry points, mirroring the wire pattern of
:func:`repro.core.redistribute._all_to_all_comm`:

fused / pipelined engines (payload keeps the block layout):
    :func:`encode_payload`  — codec in one pass, payload stays in place.
    :func:`decode_payload`  — inverse, dequantizing each received chunk
                              with its sender's scale.

traditional engine (payload is chunk-major, paper Eqs. 15-17):
    :func:`pack_chunks`     — codec *and* the pack transpose in one pass
                              (the chunk-major gather is the kernel's
                              output index map, not a separate moveaxis).
    :func:`unpack_chunks`   — inverse scatter fused with dequantize: the
                              unpack realignment costs no extra HBM pass.

Every wrapper reshapes its operand to the kernels' canonical
``(P, F, A, M, B, R)`` view — stride-only, free — and reshapes the result
back.  Complex blocks travel as a leading (re, im) plane pair built by the
module-local :func:`_to_planes` / :func:`_from_planes` (same math as
:mod:`repro.core.quant`'s helpers, duplicated *here* so planlint's source
attribution sees the marshalling on the kernel side of the line: a plan
whose lossy stages all run ``impl="pallas"`` traces zero eqns attributed
to ``core/quant.py`` — the PLAN009 invariant).

``pallas_applicable`` is the one shared gate: the pallas impl exists for
*lossy* payloads (there the codec gives the kernels work to fuse with);
a lossless exchange has no local pass to eliminate — the engines'
complex64 path is already realignment-free for ``fused``/``pipelined``,
and kernelizing traditional's lossless pack would add plane-marshalling
passes for nothing — so lossless stages always execute the jnp reference
path regardless of the requested impl.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.quant import canonical_comm_dtype
from repro.kernels.exchange import kernel as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pallas_applicable(method: str, comm_dtype) -> bool:  # noqa: ARG001 — method kept for future per-engine gating
    """Whether ``impl="pallas"`` changes anything for this stage config.
    False means the stage canonically executes the jnp reference path."""
    return canonical_comm_dtype(comm_dtype) != "complex64"


def _prod(xs) -> int:
    return int(math.prod(xs))


def _to_planes(y: jax.Array) -> jax.Array:
    """Block -> leading (re, im) f32 plane pair ``(2, *shape)`` (``(1, ...)``
    for real input).  Module-local twin of quant.complex_to_planes — see
    module docstring for why the eqns must attribute here."""
    if jnp.iscomplexobj(y):
        return jnp.stack([jnp.real(y), jnp.imag(y)]).astype(jnp.float32)
    return y.astype(jnp.float32)[None]


def _from_planes(p: jax.Array, iscomplex: bool) -> jax.Array:
    if iscomplex:
        return lax.complex(p[0], p[1])
    return p[0]


def _stats_dict(st: jax.Array | None) -> dict | None:
    """Per-(field, chunk) kernel counters -> the executor's stats dict
    (summed host-of-shard side, matching health.payload_stats' shape)."""
    if st is None:
        return None
    return {"nonfinite": jnp.sum(st[..., 0]), "saturated": jnp.sum(st[..., 1])}


def _payload_view(shape: tuple[int, ...], axis: int, m: int,
                  nbatch: int) -> tuple[int, ...]:
    """Collapse a planes shape ``(P, *s)`` around split/concat axis ``axis``
    (block coords) into the canonical ``(P, F, A, M, B, R)``."""
    P, s = shape[0], shape[1:]
    n = s[axis]
    if n % m != 0:
        raise ValueError(f"axis extent {n} not divisible by group size {m}")
    return (P, _prod(s[:nbatch]), _prod(s[nbatch:axis]), m, n // m,
            _prod(s[axis + 1:]))


# ---------------------------------------------------------------------------
# fused / pipelined engines: payload in block layout
# ---------------------------------------------------------------------------


def encode_payload(y: jax.Array, *, axis: int, m: int, nbatch: int = 0,
                   codec: str, guard: bool = False, scale_div=None,
                   interpret: bool | None = None):
    """One-pass encode of a block for the fused/pipelined wire: returns
    ``(payload, scale, stats)`` — the narrow (bf16/int8) payload as
    ``(P, *y.shape)`` planes ready for an all-to-all with the split/concat
    axes shifted by one, the ``(F, M)`` per-(field, chunk) f32 scales
    (int8; None otherwise), and the guard stats dict (None unless
    ``guard``).  ``axis`` is the split axis in block coords; the leading
    ``nbatch`` axes are stacked fields."""
    if interpret is None:
        interpret = _interpret_default()
    planes = _to_planes(y)
    view = _payload_view(planes.shape, axis, m, nbatch)
    call = _k.encode_pallas_call(view, codec=codec, pack=False, guard=guard,
                                 scale_div=scale_div, interpret=interpret)
    outs = call(planes.reshape(view))
    q, rest = outs[0], list(outs[1:])
    scale = rest.pop(0) if codec == "int8" else None
    stats = _stats_dict(rest.pop(0) if guard else None)
    return q.reshape(planes.shape), scale, stats


def decode_payload(p: jax.Array, *, axis: int, m: int, nbatch: int = 0,
                   scale: jax.Array | None, codec: str, iscomplex: bool,
                   interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`encode_payload` for the *received* payload ``p``
    (``(P, *out_shape)`` planes whose ``axis`` now carries ``m``
    sender-chunks): dequantize/widen in one pass — chunk ``j`` with sender
    ``j``'s scale from the ``(F, M)`` scale exchange — and rebuild the
    complex block."""
    if interpret is None:
        interpret = _interpret_default()
    view = _payload_view(p.shape, axis, m, nbatch)
    call = _k.decode_pallas_call(view, codec=codec, interpret=interpret)
    args = (p.reshape(view),) if codec != "int8" else (p.reshape(view), scale)
    (out,) = call(*args)
    return _from_planes(out.reshape(p.shape), iscomplex)


# ---------------------------------------------------------------------------
# traditional engine: chunk-major payload (paper Eqs. 15-17)
# ---------------------------------------------------------------------------


def pack_chunks(y: jax.Array, *, axis: int, m: int, nbatch: int = 0,
                codec: str, guard: bool = False, scale_div=None,
                interpret: bool | None = None):
    """One-pass pack+encode for the traditional engine: the codec write
    lands directly in chunk-major layout ``(m, P, *s)`` (``s`` = block
    shape with ``axis`` shrunk to its per-chunk extent), ready for a
    contiguous all-to-all on axis 0.  Returns ``(payload, scale, stats)``
    with ``(M, F)`` scales (int8) whose leading axis matches the
    payload's, so both collectives split the same way."""
    if interpret is None:
        interpret = _interpret_default()
    planes = _to_planes(y)
    P, F, A, M, B, R = view = _payload_view(planes.shape, axis, m, nbatch)
    call = _k.encode_pallas_call(view, codec=codec, pack=True, guard=guard,
                                 scale_div=scale_div, interpret=interpret)
    outs = call(planes.reshape(view))
    q, rest = outs[0], list(outs[1:])
    scale = rest.pop(0) if codec == "int8" else None
    stats = _stats_dict(rest.pop(0) if guard else None)
    s = list(planes.shape[1:])
    s[axis] = B
    return q.reshape((M, P, *s)), scale, stats


def unpack_chunks(p: jax.Array, *, v: int, w: int, m: int, nbatch: int = 0,
                  scale: jax.Array | None, codec: str, iscomplex: bool,
                  interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`pack_chunks` for the received chunk-major payload:
    scatter chunk ``j`` into w-slot ``j`` (chunk-major == global w order,
    the Eq. 17 realignment) fused with dequantize/widen, and rebuild the
    block — w axis full, v axis holding this rank's shard.  ``v``/``w``
    are block coords of the inner shape ``p.shape[2:]``."""
    if interpret is None:
        interpret = _interpret_default()
    M, P = p.shape[0], p.shape[1]
    s = p.shape[2:]
    bv, bw = v + nbatch, w + nbatch
    F = _prod(s[:nbatch])
    if bw < bv:
        a1, wl = _prod(s[nbatch:bw]), s[bw]
        a2, b, r = _prod(s[bw + 1:bv]), s[bv], _prod(s[bv + 1:])
        in_view = (M, P, F, a1, wl, a2, b, r)
        out_view = (P, F, a1, M, wl, a2, b, r)
        m_out = 3
    else:
        a1, b = _prod(s[nbatch:bv]), s[bv]
        a2, wl, r = _prod(s[bv + 1:bw]), s[bw], _prod(s[bw + 1:])
        in_view = (M, P, F, a1, b, a2, wl, r)
        out_view = (P, F, a1, b, a2, M, wl, r)
        m_out = 5
    call = _k.unpack_decode_pallas_call(in_view, out_view, m_out=m_out,
                                        codec=codec, interpret=interpret)
    args = (p.reshape(in_view),) if codec != "int8" else (p.reshape(in_view), scale)
    (out,) = call(*args)
    final = list(s)
    final[bw] = M * s[bw]
    return _from_planes(out.reshape((P, *final)), iscomplex)
