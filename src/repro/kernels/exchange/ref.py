"""jnp reference semantics for the fused exchange kernels.

Each function mirrors one :mod:`repro.kernels.exchange.ops` entry point
exactly — same arguments, same plane/payload layouts, same scale blocking —
but is built from the :mod:`repro.core.quant` codec plus explicit
``moveaxis`` realignment (the multi-pass path the kernels fuse away).
The parity suite (``tests/test_exchange_kernels.py``) asserts the kernels
match these bitwise for bf16 (a pure elementwise cast), and for int8 up to
one ULP of the per-block scale: the kernel bodies run the identical codec
math over the identical (field, chunk) scale blocks, but XLA may compile
the ``amax / 127`` constant division differently inside and outside the
kernel (reciprocal-multiply rewrite), shifting a scale by one ULP and —
at an exact round-to-half boundary — a payload element by one quantum.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import quant


def _prod(xs) -> int:
    return int(math.prod(xs))


def _to_planes(y: jax.Array) -> jax.Array:
    if jnp.iscomplexobj(y):
        return quant.complex_to_planes(y)
    return y.astype(jnp.float32)[None]


def _from_planes(p: jax.Array, iscomplex: bool) -> jax.Array:
    if iscomplex:
        return quant.planes_to_complex(p)
    return p[0]


def _view6(shape, axis: int, m: int, nbatch: int):
    P, s = shape[0], shape[1:]
    return (P, _prod(s[:nbatch]), _prod(s[nbatch:axis]), m, s[axis] // m,
            _prod(s[axis + 1:]))


def encode_payload_ref(y, *, axis, m, nbatch=0, codec, guard=False, scale_div=None):
    planes = _to_planes(y)
    P, F, A, M, B, R = view = _view6(planes.shape, axis, m, nbatch)
    x6 = planes.reshape(view)
    if codec == "bf16":
        stats = ({"nonfinite": jnp.sum(~jnp.isfinite(x6), dtype=jnp.float32),
                  "saturated": jnp.zeros((), jnp.float32)} if guard else None)
        return quant.encode_bf16(x6).reshape(planes.shape), None, stats
    if guard:
        q, sc, stats = quant.quantize_int8(x6, block_axis=(1, 3),
                                           scale_div=scale_div, with_stats=True)
    else:
        q, sc = quant.quantize_int8(x6, block_axis=(1, 3), scale_div=scale_div)
        stats = None
    return q.reshape(planes.shape), sc.reshape(F, M), stats


def decode_payload_ref(p, *, axis, m, nbatch=0, scale, codec, iscomplex):
    P, F, A, M, WB, R = view = _view6(p.shape, axis, m, nbatch)
    x6 = p.reshape(view)
    if codec == "int8":
        out = quant.dequantize_int8(x6, scale.reshape(1, F, 1, M, 1, 1))
    else:
        out = quant.decode_bf16(x6)
    return _from_planes(out.reshape(p.shape), iscomplex)


def pack_chunks_ref(y, *, axis, m, nbatch=0, codec, guard=False, scale_div=None):
    planes = _to_planes(y)
    P, F, A, M, B, R = view = _view6(planes.shape, axis, m, nbatch)
    q, scale, stats = encode_payload_ref(y, axis=axis, m=m, nbatch=nbatch,
                                         codec=codec, guard=guard,
                                         scale_div=scale_div)
    # the pack realignment the kernel's output index map replaces:
    packed = jnp.moveaxis(q.reshape(view), 3, 0)
    s = list(planes.shape[1:])
    s[axis] = B
    if scale is not None:
        scale = jnp.moveaxis(scale, 1, 0)  # (F, M) -> (M, F)
    return packed.reshape((M, P, *s)), scale, stats


def unpack_chunks_ref(p, *, v, w, m, nbatch=0, scale, codec, iscomplex):
    M, P = p.shape[0], p.shape[1]
    s = p.shape[2:]
    bv, bw = v + nbatch, w + nbatch
    F = _prod(s[:nbatch])
    if bw < bv:
        in_view = (M, P, F, _prod(s[nbatch:bw]), s[bw],
                   _prod(s[bw + 1:bv]), s[bv], _prod(s[bv + 1:]))
        m_out = 3
    else:
        in_view = (M, P, F, _prod(s[nbatch:bv]), s[bv],
                   _prod(s[bv + 1:bw]), s[bw], _prod(s[bw + 1:]))
        m_out = 5
    x8 = p.reshape(in_view)
    if codec == "int8":
        out = quant.dequantize_int8(x8, scale.reshape(M, 1, F, 1, 1, 1, 1, 1))
    else:
        out = quant.decode_bf16(x8)
    # the unpack realignment the kernel's output index map replaces:
    out = jnp.moveaxis(out, 0, m_out)
    final = list(s)
    final[bw] = M * s[bw]
    return _from_planes(out.reshape((P, *final)), iscomplex)
