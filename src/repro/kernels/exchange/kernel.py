"""Pallas TPU kernels: fused exchange-local codec + chunk-layout passes.

The paper's thesis (Sec. 3) is that redistribution should never need a
separate local-realignment pass.  The jnp reference engines honor that for
the *lossless* exchange (the strided split/concat rides inside the one
``all_to_all``), but a lossy ``comm_dtype`` reintroduces local passes:
quantize → (pack) → collective → (unpack) → dequantize each materialize
the block in HBM.  These kernels collapse each side into a single
HBM-read → VMEM-tile → HBM-write pass:

encode side (``encode_pallas_call``) — one kernel computes the per-block
    int8 scale (or bf16 rounding) *and* writes the payload directly in the
    outgoing wire layout.  With ``pack=True`` the write is the traditional
    engine's chunk-major gather (paper Eq. 16) — the pack transpose costs
    no extra pass, it is just the kernel's output index map.

decode side (``decode_pallas_call`` / ``unpack_decode_pallas_call``) —
    the inverse: dequantize fused with the received-chunk scatter; for the
    traditional engine the unpack transpose (Eq. 17's realignment) is again
    only the output index map.

Canonical view: every operand is reshaped (stride-only, free) to

    (P, F, A, M, B, R)

``P`` re/im planes (1 for real data), ``F`` collapsed leading batch/field
axes, ``A``/``R`` collapsed axes before/after the exchange axis, ``M`` the
subgroup size, ``B`` the per-chunk extent.  The grid is ``(F, M)``: one
program instance per (field, destination-chunk) — exactly the scale
blocking of :func:`repro.core.quant.quantize_int8`, so the int8 math here
is *bitwise identical* to the reference codec (same max-abs block, same
``_EPS`` floor, same round/clip).  The plane axis always stays inside the
block so re/im share one scale, as in the reference.

The kernels run on TPU natively and everywhere else via ``interpret=True``
(pure-jax emulation), same doctrine as :mod:`repro.kernels.transpose`.  No
complex dtype ever enters VMEM: callers pass (re, im) planes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import _EPS

_WIRE_DTYPES = {"int8": jnp.int8, "bf16": jnp.bfloat16}


def _one_hot_map(ndim: int, f_slot: int, m_slot: int):
    """Index map placing grid coords (f, m) at the given slots, 0 elsewhere."""

    def index_map(i, j):
        idx = [0] * ndim
        idx[f_slot] = i
        idx[m_slot] = j
        return tuple(idx)

    return index_map


def _blocked(shape: tuple[int, ...], f_slot: int, m_slot: int) -> tuple[int, ...]:
    """Block shape: full extents except 1 at the two grid-mapped slots."""
    blk = list(shape)
    blk[f_slot] = 1
    blk[m_slot] = 1
    return tuple(blk)


def _encode_block(x, codec: str, scale_div):
    """The reference codec math of :mod:`repro.core.quant`, applied to one
    VMEM block (= one (field, chunk) scale block).  Returns
    ``(payload, scale | None, nonfinite, saturated)``."""
    if codec == "bf16":
        nonfinite = jnp.sum(~jnp.isfinite(x), dtype=jnp.float32)
        return x.astype(jnp.bfloat16), None, nonfinite, jnp.float32(0.0)
    finite = jnp.isfinite(x)
    xf = jnp.where(finite, x, 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), _EPS) / 127.0
    if scale_div is not None:
        scale = scale / scale_div
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    nonfinite = jnp.sum(~finite, dtype=jnp.float32)
    saturated = jnp.sum((q == 127) | (q == -127), dtype=jnp.float32)
    return q, scale.astype(jnp.float32), nonfinite, saturated


def encode_pallas_call(view_shape, *, codec: str, pack: bool, guard: bool,
                       scale_div, interpret: bool):
    """Build the fused encode kernel for a ``(P, F, A, M, B, R)`` view.

    Outputs (in order): the narrow payload — same view layout, or the
    traditional engine's chunk-major ``(M, P, F, A, B, R)`` when
    ``pack=True`` — then for int8 the per-(field, chunk) f32 scales, then
    (``guard=True``) per-(field, chunk) ``(nonfinite, saturated)`` counts.
    Scale/stats are laid out ``(F, M)`` for the in-place payload and
    ``(M, F)`` for the packed one, matching each payload's leading order so
    the scale all-to-all uses the same split axis as the payload's.
    """
    P, F, A, M, B, R = view_shape
    in_spec = pl.BlockSpec(_blocked(view_shape, 1, 3), _one_hot_map(6, 1, 3))
    if pack:
        q_shape = (M, P, F, A, B, R)
        q_spec = pl.BlockSpec(_blocked(q_shape, 2, 0), _one_hot_map(6, 2, 0))
        scale_shape, smap = (M, F), lambda i, j: (j, i)
    else:
        q_shape = view_shape
        q_spec = pl.BlockSpec(_blocked(q_shape, 1, 3), _one_hot_map(6, 1, 3))
        scale_shape, smap = (F, M), lambda i, j: (i, j)

    out_specs = [q_spec]
    out_shapes = [jax.ShapeDtypeStruct(q_shape, _WIRE_DTYPES[codec])]
    if codec == "int8":
        out_specs.append(pl.BlockSpec((1, 1), smap))
        out_shapes.append(jax.ShapeDtypeStruct(scale_shape, jnp.float32))
    if guard:
        out_specs.append(pl.BlockSpec((1, 1, 2), lambda i, j: (*smap(i, j), 0)))
        out_shapes.append(jax.ShapeDtypeStruct((*scale_shape, 2), jnp.float32))

    def body(x_ref, *out_refs):
        refs = list(out_refs)
        q_ref = refs.pop(0)
        s_ref = refs.pop(0) if codec == "int8" else None
        st_ref = refs.pop(0) if guard else None
        q, scale, nonfinite, saturated = _encode_block(x_ref[...], codec, scale_div)
        q_ref[...] = q.reshape(q_ref.shape)
        if s_ref is not None:
            s_ref[0, 0] = scale
        if st_ref is not None:
            st_ref[0, 0, 0] = nonfinite
            st_ref[0, 0, 1] = saturated

    return pl.pallas_call(
        body,
        grid=(F, M),
        in_specs=[in_spec],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )


def decode_pallas_call(view_shape, *, codec: str, interpret: bool):
    """Build the fused decode kernel for a received ``(P, F, A, M, WB, R)``
    payload view (``M`` = sender-chunk axis of the tiled concat): widen back
    to f32, for int8 dequantizing chunk ``j`` with sender ``j``'s scale
    (a second ``(F, M)`` input)."""
    P, F, A, M, WB, R = view_shape
    spec = pl.BlockSpec(_blocked(view_shape, 1, 3), _one_hot_map(6, 1, 3))
    in_specs = [spec]
    if codec == "int8":
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (i, j)))

    def body(q_ref, *rest):
        if codec == "int8":
            s_ref, o_ref = rest
            o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]
        else:
            (o_ref,) = rest
            o_ref[...] = q_ref[...].astype(jnp.float32)

    return pl.pallas_call(
        body,
        grid=(F, M),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec(_blocked(view_shape, 1, 3), _one_hot_map(6, 1, 3))],
        out_shape=[jax.ShapeDtypeStruct(view_shape, jnp.float32)],
        interpret=interpret,
    )


def unpack_decode_pallas_call(in_shape, out_shape, *, m_out: int, codec: str,
                              interpret: bool):
    """Build the traditional engine's fused unpack: the received chunk-major
    payload ``(M, P, F, ...)`` is scattered into its w-slot (the Eq. 17
    realignment, expressed purely as the output index map) while
    dequantizing/widening.  ``out_shape`` carries ``(P, F, ...)`` leading
    with the chunk axis re-inserted at ``m_out`` (just before the w-shard
    axis: chunk-major == global w order); for int8 the ``(M, F)`` scales
    received alongside ride as a second input."""
    in_specs = [pl.BlockSpec(_blocked(in_shape, 2, 0), _one_hot_map(len(in_shape), 2, 0))]
    if codec == "int8":
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (j, i)))

    def body(q_ref, *rest):
        if codec == "int8":
            s_ref, o_ref = rest
            o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).reshape(o_ref.shape)
        else:
            (o_ref,) = rest
            o_ref[...] = q_ref[...].astype(jnp.float32).reshape(o_ref.shape)

    return pl.pallas_call(
        body,
        grid=(in_shape[2], in_shape[0]),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec(_blocked(out_shape, 1, m_out),
                                _one_hot_map(len(out_shape), 1, m_out))],
        out_shape=[jax.ShapeDtypeStruct(out_shape, jnp.float32)],
        interpret=interpret,
    )
