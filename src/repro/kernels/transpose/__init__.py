"""Tiled local transpose kernel (traditional-redistribution hot-spot)."""
