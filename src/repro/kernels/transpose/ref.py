"""Pure-jnp oracle for the local-transpose kernel."""
import jax.numpy as jnp


def transpose01_ref(x):
    return jnp.swapaxes(x, 0, 1)
