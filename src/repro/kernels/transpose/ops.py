"""Jit'd wrapper for the tiled local-transpose kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.transpose.kernel import transpose01_pallas_call


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def transpose01(x: jax.Array, *, block_a: int = 8, block_b: int = 8,
                interpret: bool | None = None) -> jax.Array:
    """Swap the two leading axes of a rank-3 array via VMEM-tiled copies."""
    if interpret is None:
        interpret = _interpret_default()
    if jnp.iscomplexobj(x):
        # complex travels as (re, im) planes — same doctrine as the FFT
        # kernel (no complex VMEM/MXU type)
        re = transpose01(jnp.real(x), block_a=block_a, block_b=block_b,
                         interpret=interpret)
        im = transpose01(jnp.imag(x), block_a=block_a, block_b=block_b,
                         interpret=interpret)
        return jax.lax.complex(re, im)
    a, b, c = x.shape
    ba, bb = min(block_a, a), min(block_b, b)
    # pad to tile multiples, run, slice back
    a2, b2 = -(-a // ba) * ba, -(-b // bb) * bb
    xp = jnp.pad(x, ((0, a2 - a), (0, b2 - b), (0, 0))) if (a2, b2) != (a, b) else x
    call = transpose01_pallas_call(a2, b2, c, block_a=ba, block_b=bb,
                                   dtype=x.dtype, interpret=interpret)
    y = call(xp)
    return y[:b, :a] if (a2, b2) != (a, b) else y
