"""Pallas TPU kernel: tiled local transpose (A, B, C) -> (B, A, C).

This is the *traditional* redistribution's pack/unpack hot-spot (paper
Eq. 16): swapping the two leading axes of a rank-3 view.  The paper's whole
point is that the fused method never runs this; we implement it as a
first-class kernel so the baseline is honestly optimized — tiles of
(block_a, block_b, C) are staged through VMEM so HBM sees two streaming
passes instead of a strided gather.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(x_ref, o_ref):
    # x tile: (ba, bb, C) -> o tile: (bb, ba, C)
    o_ref[...] = jnp.swapaxes(x_ref[...], 0, 1)


def transpose01_pallas_call(a: int, b: int, c: int, *, block_a: int, block_b: int,
                            dtype, interpret: bool):
    assert a % block_a == 0 and b % block_b == 0, (a, b, block_a, block_b)
    grid = (a // block_a, b // block_b)
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_a, block_b, c), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((block_b, block_a, c), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, a, c), dtype),
        interpret=interpret,
    )
