"""Pallas TPU kernel: batched four-step matmul DFT (DESIGN.md §4).

Complex data travels as separate (re, im) f32 planes — the TPU MXU has no
complex type.  One grid step transforms a (block_b, n1, n2) tile held in
VMEM:

    step 1   contract n1 with the DFT-n1 matrix        (MXU)
    step 2   pointwise twiddle multiply                 (VPU)
    step 3   contract n2 with the DFT-n2 matrix        (MXU)
    step 4   (k1,k2) index transpose on the VMEM tile   (VPU/copy)

A complex matmul is 4 real matmuls, or 3 with ``karatsuba=True``
(P1=Fr·Ar, P2=Fi·Ai, P3=(Fr+Fi)·(Ar+Ai); Re=P1−P2, Im=P3−P1−P2) — a 25 %
MXU-FLOP saving measured in the §Perf log.  Real-input tiles (rfft path)
skip half of step 1 via ``real_input=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _cmatmul(ar, ai, br, bi, dims, *, karatsuba: bool):
    """Complex matmul via real dots.  ``dims`` is dot_general dimension_numbers."""
    dot = functools.partial(lax.dot_general, dimension_numbers=dims,
                            preferred_element_type=jnp.float32)
    if ai is None:  # real lhs (rfft specialization): 2 matmuls
        return dot(ar, br), dot(ar, bi)
    if karatsuba:
        p1 = dot(ar, br)
        p2 = dot(ai, bi)
        p3 = dot(ar + ai, br + bi)
        return p1 - p2, p3 - p1 - p2
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def fourstep_kernel(
    xr_ref, xi_ref, f1r_ref, f1i_ref, f2r_ref, f2i_ref, twr_ref, twi_ref,
    or_ref, oi_ref, *, karatsuba: bool, real_input: bool,
):
    """One (block_b, n1, n2) tile: out[b, k2, k1] = DFT(x[b, n1, n2]).

    ``xi_ref`` is ``None`` on the real-input (rfft) path — the operand is
    dropped from the pallas_call so no zero plane ever reaches VMEM."""
    ar = xr_ref[...]  # (bb, n1, n2)
    ai = None if real_input else xi_ref[...]
    f1r, f1i = f1r_ref[...], f1i_ref[...]  # (n1, n1)
    f2r, f2i = f2r_ref[...], f2i_ref[...]  # (n2, n2)
    twr, twi = twr_ref[...], twi_ref[...]  # (n1, n2)

    # step 1: contract F1[k1, n1] with a[bb, n1, n2] -> (k1, bb, n2)
    br, bi = _cmatmul2(f1r, f1i, ar, ai, karatsuba=karatsuba, real_input=real_input)

    # step 2: twiddle T[k1, n2] broadcast over batch
    cr = br * twr[:, None, :] - bi * twi[:, None, :]
    ci = br * twi[:, None, :] + bi * twr[:, None, :]

    # step 3: contract c[k1, bb, n2] with F2[n2, k2] -> (k1, bb, k2)
    dims3 = (((2,), (0,)), ((), ()))
    dr, di = _cmatmul(cr, ci, f2r, f2i, dims3, karatsuba=karatsuba)

    # step 4: -> (bb, k2, k1); flattening (k2, k1) row-major gives k = k1 + n1*k2
    or_ref[...] = jnp.transpose(dr, (1, 2, 0))
    oi_ref[...] = jnp.transpose(di, (1, 2, 0))


def _cmatmul2(f1r, f1i, ar, ai, *, karatsuba: bool, real_input: bool):
    """step-1 complex matmul: contract F1's axis 1 with a's axis 1."""
    dims = (((1,), (1,)), ((), ()))
    dot = functools.partial(lax.dot_general, dimension_numbers=dims,
                            preferred_element_type=jnp.float32)
    if real_input:
        return dot(f1r, ar), dot(f1i, ar)
    if karatsuba:
        p1 = dot(f1r, ar)
        p2 = dot(f1i, ai)
        p3 = dot(f1r + f1i, ar + ai)
        return p1 - p2, p3 - p1 - p2
    return dot(f1r, ar) - dot(f1i, ai), dot(f1r, ai) + dot(f1i, ar)


def fourstep_pallas_call(
    batch: int, n1: int, n2: int, *, block_b: int, karatsuba: bool,
    real_input: bool, interpret: bool,
):
    """Build the pallas_call for a (batch, n1, n2) -> (batch, n2, n1) DFT.

    ``real_input=True`` takes a single ``xr`` input operand (rfft path:
    there is no imaginary plane to ship)."""
    assert batch % block_b == 0, (batch, block_b)
    grid = (batch // block_b,)
    tile_in = pl.BlockSpec((block_b, n1, n2), lambda i: (i, 0, 0))
    tile_out = pl.BlockSpec((block_b, n2, n1), lambda i: (i, 0, 0))
    full = lambda a, b: pl.BlockSpec((a, b), lambda i: (0, 0))
    if real_input:
        def kern(xr_ref, *refs):
            fourstep_kernel(xr_ref, None, *refs,
                            karatsuba=karatsuba, real_input=True)
        x_specs = [tile_in]                 # xr only
    else:
        kern = functools.partial(fourstep_kernel, karatsuba=karatsuba,
                                 real_input=real_input)
        x_specs = [tile_in, tile_in]        # xr, xi
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            *x_specs,
            full(n1, n1), full(n1, n1),     # F1 re/im
            full(n2, n2), full(n2, n2),     # F2 re/im
            full(n1, n2), full(n1, n2),     # twiddle re/im
        ],
        out_specs=[tile_out, tile_out],
        out_shape=[
            jax.ShapeDtypeStruct((batch, n2, n1), jnp.float32),
            jax.ShapeDtypeStruct((batch, n2, n1), jnp.float32),
        ],
        interpret=interpret,
    )
