"""Pure-jnp oracles for the four-step matmul DFT kernel.

``fft_ref``        — ground truth (jnp.fft).
``fourstep_ref``   — the four-step algorithm in plain jnp (same math as the
                     Pallas kernel, no tiling); validates the decomposition
                     independently of Pallas.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def fft_ref(x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """Reference 1-D (i)FFT along the last axis."""
    return jnp.fft.ifft(x, axis=-1) if inverse else jnp.fft.fft(x, axis=-1)


def dft_matrix(n: int, dtype=np.complex64) -> np.ndarray:
    k = np.arange(n)
    return np.exp(-2j * np.pi * np.outer(k, k) / n).astype(dtype)


def twiddle_matrix(n1: int, n2: int, dtype=np.complex64) -> np.ndarray:
    """T[k1, n2] = exp(-2πi k1 n2 / (n1 n2))."""
    k1 = np.arange(n1)
    n2i = np.arange(n2)
    return np.exp(-2j * np.pi * np.outer(k1, n2i) / (n1 * n2)).astype(dtype)


def fourstep_ref(x: jnp.ndarray, n1: int, n2: int) -> jnp.ndarray:
    """Four-step DFT along the last axis (length n1*n2) in plain jnp.

    n = n1*N2 + n2 (input row-major (n1, n2)); k = k1 + n1*k2 (output
    row-major (k2, k1)).  See DESIGN.md §4.
    """
    *batch, n = x.shape
    assert n == n1 * n2, (n, n1, n2)
    a = x.reshape(*batch, n1, n2)
    f1 = jnp.asarray(dft_matrix(n1))
    f2 = jnp.asarray(dft_matrix(n2))
    tw = jnp.asarray(twiddle_matrix(n1, n2))
    a1 = jnp.einsum("kn,...nm->...km", f1, a)  # DFT over n1
    a2 = a1 * tw  # twiddle
    a3 = jnp.einsum("...km,mj->...kj", a2, f2)  # DFT over n2
    return jnp.swapaxes(a3, -1, -2).reshape(*batch, n)  # (k2, k1) row-major
