"""Pure-jnp oracles for the four-step matmul DFT kernel.

``fft_ref``        — ground truth (jnp.fft).
``fourstep_ref``   — the four-step algorithm in plain jnp (same math as the
                     Pallas kernel, no tiling); validates the decomposition
                     independently of Pallas.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def fft_ref(x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """Reference 1-D (i)FFT along the last axis."""
    return jnp.fft.ifft(x, axis=-1) if inverse else jnp.fft.fft(x, axis=-1)


def dft_matrix(n: int, dtype=np.complex64) -> np.ndarray:
    k = np.arange(n)
    return np.exp(-2j * np.pi * np.outer(k, k) / n).astype(dtype)


def twiddle_matrix(n1: int, n2: int, dtype=np.complex64) -> np.ndarray:
    """T[k1, n2] = exp(-2πi k1 n2 / (n1 n2))."""
    k1 = np.arange(n1)
    n2i = np.arange(n2)
    return np.exp(-2j * np.pi * np.outer(k1, n2i) / (n1 * n2)).astype(dtype)


def dct_matrix(n: int, trig_type: int = 2, dtype=np.float32) -> np.ndarray:
    """Unnormalized (scipy-convention) DCT transform matrix: y = M @ x.

    Type II: M[k, j] = 2 cos(pi k (2j+1) / (2n)).
    Type III: M[k, 0] = 1, M[k, j>0] = 2 cos(pi j (2k+1) / (2n)).
    The two are mutual inverses up to 1/(2n): C3 @ C2 = 2n I.
    """
    k = np.arange(n)[:, None]
    j = np.arange(n)[None, :]
    if trig_type == 2:
        m = 2.0 * np.cos(np.pi * k * (2 * j + 1) / (2 * n))
    elif trig_type == 3:
        m = 2.0 * np.cos(np.pi * j * (2 * k + 1) / (2 * n))
        m[:, 0] = 1.0
    else:
        raise ValueError(f"dct type must be 2 or 3, got {trig_type}")
    return m.astype(dtype)


def dst_matrix(n: int, trig_type: int = 2, dtype=np.float32) -> np.ndarray:
    """Unnormalized (scipy-convention) DST transform matrix: y = M @ x.

    Type II: M[k, j] = 2 sin(pi (k+1) (2j+1) / (2n)).
    Type III: M[k, j<n-1] = 2 sin(pi (j+1) (2k+1) / (2n)),
              M[k, n-1] = (-1)^k.  S3 @ S2 = 2n I.
    """
    k = np.arange(n)[:, None]
    j = np.arange(n)[None, :]
    if trig_type == 2:
        m = 2.0 * np.sin(np.pi * (k + 1) * (2 * j + 1) / (2 * n))
    elif trig_type == 3:
        m = 2.0 * np.sin(np.pi * (j + 1) * (2 * k + 1) / (2 * n))
        m[:, n - 1] = (-1.0) ** k[:, 0]
    else:
        raise ValueError(f"dst type must be 2 or 3, got {trig_type}")
    return m.astype(dtype)


def fourstep_ref(x: jnp.ndarray, n1: int, n2: int) -> jnp.ndarray:
    """Four-step DFT along the last axis (length n1*n2) in plain jnp.

    n = n1*N2 + n2 (input row-major (n1, n2)); k = k1 + n1*k2 (output
    row-major (k2, k1)).  See DESIGN.md §4.
    """
    *batch, n = x.shape
    assert n == n1 * n2, (n, n1, n2)
    a = x.reshape(*batch, n1, n2)
    f1 = jnp.asarray(dft_matrix(n1))
    f2 = jnp.asarray(dft_matrix(n2))
    tw = jnp.asarray(twiddle_matrix(n1, n2))
    a1 = jnp.einsum("kn,...nm->...km", f1, a)  # DFT over n1
    a2 = a1 * tw  # twiddle
    a3 = jnp.einsum("...km,mj->...kj", a2, f2)  # DFT over n2
    return jnp.swapaxes(a3, -1, -2).reshape(*batch, n)  # (k2, k1) row-major
