"""Four-step matmul DFT kernel (TPU MXU-native serial FFT)."""
