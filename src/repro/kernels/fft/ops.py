"""Jit'd wrappers for the four-step matmul DFT Pallas kernel.

``fft_matmul(x, axis, inverse)``   — complex-to-complex, any axis.
``rfft_matmul(x, axis)``           — real input, Hermitian-reduced output.
``irfft_matmul(x, n, axis)``       — inverse of the above.

Factorization policy (``plan_factors``): N = n1·n2 with n1 ≥ n2, both as
close to √N (and MXU-friendly multiples of 8/128) as possible; prime or tiny
N degenerates to a single (N,N) DFT matmul.  Inverse transforms use
ifft(x) = conj(fft(conj(x)))/N so one kernel serves both directions.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fft import ref
from repro.kernels.fft.kernel import fourstep_pallas_call

_DEFAULT_BLOCK_B = 8
_SINGLE_MATMUL_MAX = 256  # below this, one (N,N) DFT matmul beats two steps


def plan_factors(n: int) -> tuple[int, int]:
    """Pick (n1, n2), n = n1*n2, n1 >= n2, n1 minimal such — or (n, 1)."""
    if n <= _SINGLE_MATMUL_MAX:
        return n, 1
    best = (n, 1)
    for n2 in range(int(math.isqrt(n)), 0, -1):
        if n % n2 == 0:
            best = (n // n2, n2)
            break
    return best


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("inverse", "axis", "karatsuba", "block_b", "interpret"))
def fft_matmul(
    x: jax.Array,
    *,
    axis: int = -1,
    inverse: bool = False,
    karatsuba: bool = True,
    block_b: int = _DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    """Complex 1-D DFT along ``axis`` via the four-step Pallas kernel."""
    x = jnp.asarray(x, jnp.complex64)
    axis = axis % x.ndim
    n = x.shape[axis]
    if inverse:
        y = fft_matmul(jnp.conj(x), axis=axis, inverse=False, karatsuba=karatsuba,
                       block_b=block_b, interpret=interpret)
        return jnp.conj(y) / n
    xr, xi = jnp.real(x), jnp.imag(x)
    yr, yi = _fourstep_lastaxis_real(
        _to_last(xr, axis), _to_last(xi, axis), n,
        karatsuba=karatsuba, block_b=block_b, interpret=interpret, real_input=False,
    )
    return _from_last(jax.lax.complex(yr, yi), axis)


@functools.partial(jax.jit, static_argnames=("axis", "karatsuba", "block_b", "interpret"))
def rfft_matmul(
    x: jax.Array, *, axis: int = -1, karatsuba: bool = True,
    block_b: int = _DEFAULT_BLOCK_B, interpret: bool | None = None,
) -> jax.Array:
    """Real-input DFT; returns the n//2+1 non-redundant bins (rfft)."""
    x = jnp.asarray(x, jnp.float32)
    axis = axis % x.ndim
    n = x.shape[axis]
    yr, yi = _fourstep_lastaxis_real(
        _to_last(x, axis), None, n,
        karatsuba=karatsuba, block_b=block_b, interpret=interpret, real_input=True,
    )
    y = jax.lax.complex(yr, yi)[..., : n // 2 + 1]
    return _from_last(y, axis)


@functools.partial(jax.jit, static_argnames=("n", "axis", "karatsuba", "block_b", "interpret"))
def irfft_matmul(
    x: jax.Array, *, n: int, axis: int = -1, karatsuba: bool = True,
    block_b: int = _DEFAULT_BLOCK_B, interpret: bool | None = None,
) -> jax.Array:
    """Inverse of rfft_matmul: Hermitian-extend, full iDFT, take real part."""
    x = jnp.asarray(x, jnp.complex64)
    axis = axis % x.ndim
    xl = _to_last(x, axis)
    # Hermitian extension of the reduced spectrum back to length n.
    tail = jnp.conj(xl[..., 1 : n - n // 2])[..., ::-1]
    full = jnp.concatenate([xl, tail], axis=-1)
    y = fft_matmul(full, axis=-1, inverse=True, karatsuba=karatsuba,
                   block_b=block_b, interpret=interpret)
    return _from_last(jnp.real(y), axis)


@functools.partial(jax.jit, static_argnames=("axis", "trig_type"))
def dct_matmul(x: jax.Array, *, axis: int = -1, trig_type: int = 2) -> jax.Array:
    """Unnormalized DCT-II/III along ``axis`` as one transform-matrix matmul.

    The MXU path for trigonometric axes: unlike the DFT there is no
    four-step factorization with real twiddles, so the whole (n, n) cosine
    matrix is applied in a single f32 matmul (HIGHEST precision — the MXU
    runs it as 3-pass bf16 passes, which keeps ~f32 accuracy).  Complex
    blocks transform re/im independently (the DCT is real-to-real).
    """
    return _trig_matmul(x, axis, ref.dct_matrix(x.shape[axis % x.ndim], trig_type))


@functools.partial(jax.jit, static_argnames=("axis", "trig_type"))
def dst_matmul(x: jax.Array, *, axis: int = -1, trig_type: int = 2) -> jax.Array:
    """Unnormalized DST-II/III along ``axis`` (see :func:`dct_matmul`)."""
    return _trig_matmul(x, axis, ref.dst_matrix(x.shape[axis % x.ndim], trig_type))


def _trig_matmul(x, axis, mat):
    m = jnp.asarray(mat)
    axis = axis % x.ndim

    def apply(real_block):
        y = jnp.moveaxis(real_block.astype(jnp.float32), axis, -1)
        y = jnp.matmul(y, m.T, precision=jax.lax.Precision.HIGHEST)
        return jnp.moveaxis(y, -1, axis)

    if jnp.iscomplexobj(x):
        return jax.lax.complex(apply(jnp.real(x)), apply(jnp.imag(x)))
    return apply(x).astype(x.dtype)


# ---------------------------------------------------------------------------


def _to_last(x, axis):
    return jnp.moveaxis(x, axis, -1)


def _from_last(y, axis):
    return jnp.moveaxis(y, -1, axis)


def _fourstep_lastaxis_real(xr, xi, n, *, karatsuba, block_b, interpret, real_input):
    """Flatten batch, pad to block multiple, run the kernel, restore shape."""
    if interpret is None:
        interpret = _interpret_default()
    n1, n2 = plan_factors(n)
    *batch_shape, _ = xr.shape
    b = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    bb = min(block_b, max(b, 1))
    b_pad = -(-b // bb) * bb

    def prep(a):
        a = a.reshape(b, n1, n2)
        if b_pad != b:
            a = jnp.pad(a, ((0, b_pad - b), (0, 0), (0, 0)))
        return a

    xr2 = prep(xr)
    # real_input path (xi is None): no imaginary plane is materialized or
    # fed to the kernel at all — the pallas_call drops the operand.
    planes = (xr2,) if xi is None else (xr2, prep(xi))

    f1 = ref.dft_matrix(n1)
    f2 = ref.dft_matrix(n2)
    tw = ref.twiddle_matrix(n1, n2)
    consts = [jnp.asarray(np.real(f1)), jnp.asarray(np.imag(f1)),
              jnp.asarray(np.real(f2)), jnp.asarray(np.imag(f2)),
              jnp.asarray(np.real(tw)), jnp.asarray(np.imag(tw))]

    call = fourstep_pallas_call(b_pad, n1, n2, block_b=bb, karatsuba=karatsuba,
                                real_input=real_input, interpret=interpret)
    yr, yi = call(*planes, *consts)
    # output tile layout (b, k2=n2, k1=n1) flattens row-major to k = k1 + n1*k2
    yr = yr.reshape(b_pad, n)[:b].reshape(*batch_shape, n)
    yi = yi.reshape(b_pad, n)[:b].reshape(*batch_shape, n)
    return yr, yi
