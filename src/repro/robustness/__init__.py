"""Guarded execution for ParallelFFT: fused runtime health checks, fault
injection, and a graceful precision/engine degradation ladder.

* :mod:`repro.robustness.health` — traced guard statistics + HealthReport.
* :mod:`repro.robustness.faults` — the FaultPlan injection harness.
* :mod:`repro.robustness.runner` — strict/degrade execution loop.

This ``__init__`` stays import-light (no :mod:`repro.core` import): the
plan executor imports :mod:`.faults`/:mod:`.health` at module scope, so a
runner import here would be circular.  ``GuardError``/``run_guarded``
resolve lazily.
"""

from repro.robustness.faults import FaultInjected, FaultPlan  # noqa: F401
from repro.robustness.health import (  # noqa: F401
    GUARD_MODES, HealthReport, StageHealth)

__all__ = ["FaultInjected", "FaultPlan", "GUARD_MODES", "HealthReport",
           "StageHealth", "GuardError", "run_guarded"]


def __getattr__(name):
    if name in ("GuardError", "run_guarded"):
        from repro.robustness import runner

        return getattr(runner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
