"""Guarded plan execution: evaluate the fused guards, degrade, retry.

:func:`run_guarded` is what ``ParallelFFT.forward/backward`` (and the
``_many`` variants) route through when ``guard != "off"``.  One attempt =
build/reuse the guarded executor for the current schedule, run it, sum
the per-shard guard-stat partials it returned, and evaluate them into a
:class:`~.health.HealthReport`.

``guard="strict"``: any tripped guard or failed execution raises
:class:`GuardError` (carrying the report) — the caller gets a structured
error, never a silently corrupted spectrum.

``guard="degrade"``: the runner walks the degradation ladder and
re-executes, bounded by :data:`MAX_ATTEMPTS`:

* a *tripped stage* widens that stage's wire payload one rung
  (int8 → bf16 → complex64), then drops a fused Pallas exchange kernel
  back to the jnp reference impl (pallas → jnp), before falling back
  through the engines (pipelined → fused → traditional);
* a *global* trip (Parseval, non-finite output) degrades every stage;
* a *failed execution* of a ``method="auto"`` plan quarantines the cache
  entry that produced the schedule (schema-v5 per-entry ``bad`` mark, see
  :func:`repro.core.tuner.quarantine`) and retunes, capped at
  :data:`~repro.core.tuner.MAX_QUARANTINE_RETUNES`; explicit-method plans
  degrade the whole schedule instead.

Every transition is logged on the ``repro.robustness`` logger and recorded
in the final report's ``transitions``; a ladder with no rung left raises
:class:`GuardError` — zero silent-corruption outcomes either way.
"""

from __future__ import annotations

import logging

import numpy as np

from repro.robustness import faults, health

log = logging.getLogger("repro.robustness")

#: hard cap on executions per guarded call (ladder depth is at most
#: 2 payload rungs + 1 impl rung + 2 engine rungs; +headroom for retunes)
MAX_ATTEMPTS = 8

#: one-rung payload widening (lossier -> less lossy)
DTYPE_LADDER = {"int8": "bf16", "bf16": "complex64"}

#: engine fallback order once the payload is lossless
ENGINE_LADDER = {"pipelined": "fused", "fused": "traditional"}


class GuardError(RuntimeError):
    """A guarded execution could not produce a clean result.  ``report``
    carries the last :class:`~.health.HealthReport` (None when the failure
    happened before any execution completed)."""

    def __init__(self, message: str, report=None):
        super().__init__(message)
        self.report = report


def degrade_entry(entry):
    """One ladder rung for a :class:`~repro.core.planconfig.StageEntry`
    (any legacy tuple form upgrades first): widen the payload, then drop
    a fused pallas kernel back to the jnp reference, then fall back
    through the engines; None when the entry is already at the bottom
    (traditional @ complex64 @ jnp)."""
    from repro.core.planconfig import StageEntry

    e = StageEntry.make(entry)
    if e.comm_dtype in DTYPE_LADDER:
        return e._replace(comm_dtype=DTYPE_LADDER[e.comm_dtype])
    if e.impl == "pallas":
        return e._replace(impl="jnp")
    if e.method in ENGINE_LADDER:
        return e._replace(method=ENGINE_LADDER[e.method], chunks=1)
    return None


def degrade_schedule(schedule, stages=None):
    """Degrade the entries at ``stages`` (all when None) one rung each;
    returns the new schedule, or None when no targeted entry has a rung
    left (ladder exhausted)."""
    target = set(stages) if stages else set(range(len(schedule)))
    out, moved = [], False
    for i, e in enumerate(schedule):
        d = degrade_entry(e) if i in target else None
        if d is not None:
            out.append(d)
            moved = True
        else:
            out.append(e)
    return tuple(out) if moved else None


def _resolve_schedule(plan, nfields: int):
    from repro.core.planconfig import as_schedule

    sched = plan.batched_schedule(nfields) if nfields > 1 else plan.schedule
    return as_schedule(sched)


def _quarantine_and_retune(plan, nfields: int, err) -> int:
    """Mark the plan's current cache entry bad, drop every in-process copy
    of the schedule it produced, and return the entry's total quarantine
    count (the retune happens lazily at the next schedule resolve)."""
    from repro.core import tuner

    path = plan.tuner_cache or tuner.default_cache_path()
    key = tuner.plan_key(plan, nfields=nfields)
    n = tuner.quarantine(path, key, repr(err)[:300])
    plan.__dict__.pop("schedule", None)  # cached_property reset
    plan._batched_sched_memo.pop(nfields, None)
    return n


def run_guarded(plan, xpad, direction: str, nfields: int = 1, *,
                schedule=None):
    """Execute ``plan`` on the padded block ``xpad`` under its guard mode;
    returns ``(ypad, HealthReport)``.  See the module docstring for the
    strict/degrade semantics.

    ``schedule`` forces the starting schedule instead of resolving the
    plan's own — the serving engine's circuit breaker routes requests
    through here with a pre-degraded (bottom-ladder) schedule while the
    quarantined entry retunes off the hot path.  A forced schedule that
    fails walks the degradation ladder from where it stands; it never
    quarantines the tuner cache (it is not the cache's schedule)."""
    from repro.core import tuner

    strict = plan.guard == "strict"
    forced = schedule is not None
    if forced:
        from repro.core.planconfig import as_schedule

        schedule = as_schedule(schedule)
    transitions: list[dict] = []
    report = None
    for attempt in range(1, MAX_ATTEMPTS + 1):
        err = None
        try:
            if schedule is None:
                schedule = _resolve_schedule(plan, nfields)
            fn = plan.guarded_padded(direction, schedule=schedule,
                                     nfields=nfields)
            y, raw = fn(xpad)
            # per-shard partial vectors; summing them happens here on the
            # host so the compiled executor stays collective-free
            stats = health.unpack_partials(np.asarray(raw), len(schedule))
        except faults.FaultInjected as e:
            err = e
        except GuardError:
            raise
        except Exception as e:  # genuine compile/resolve/run failure
            err = e
        if err is not None:
            log.warning("guarded %s execution failed (attempt %d): %r",
                        direction, attempt, err)
            if strict:
                raise GuardError(
                    f"schedule failed to execute: {err!r}") from err
            if plan.method == "auto":
                n = _quarantine_and_retune(plan, nfields, err)
                if n > tuner.MAX_QUARANTINE_RETUNES:
                    raise GuardError(
                        f"cache entry quarantined {n}x and still failing: "
                        f"{err!r}") from err
                transitions.append({"attempt": attempt, "kind": "retune",
                                    "quarantines": n,
                                    "reason": repr(err)[:200]})
                log.warning("quarantined tuner cache entry (count %d); "
                            "retuning", n)
                schedule = None
                continue
            new = degrade_schedule(schedule)
            if new is None:
                raise GuardError(
                    f"degradation ladder exhausted after execution failure: "
                    f"{err!r}") from err
            transitions.append({"attempt": attempt, "kind": "degrade",
                                "from": [list(e) for e in schedule],
                                "to": [list(e) for e in new],
                                "reason": repr(err)[:200]})
            log.warning("degrading schedule after failure: %s -> %s",
                        schedule, new)
            schedule = new
            continue

        report = health.build_report(
            plan, direction=direction, nfields=nfields, schedule=schedule,
            stats=stats, guard=plan.guard, transitions=transitions,
            attempts=attempt,
            fired_faults=tuple(faults._ACTIVE.fired) if faults._ACTIVE else ())
        if report.ok:
            if transitions:
                log.info("guarded %s recovered after %d attempt(s): %s",
                         direction, attempt,
                         [t["kind"] for t in transitions])
            return y, report
        if strict:
            raise GuardError(
                f"runtime guard tripped: {report.tripped}", report)
        stages = (None if report.has_global_trip
                  else report.tripped_stage_indices())
        if stages and direction == "backward":
            # report indices are execution-order; the schedule is forward-order
            stages = tuple(len(schedule) - 1 - i for i in stages)
        new = degrade_schedule(schedule, stages)
        if new is None:
            raise GuardError(
                f"degradation ladder exhausted; still tripping "
                f"{report.tripped}", report)
        transitions.append({"attempt": attempt, "kind": "degrade",
                            "tripped": list(report.tripped),
                            "from": [list(e) for e in schedule],
                            "to": [list(e) for e in new]})
        log.warning("guard tripped %s; degrading %s -> %s",
                    report.tripped, schedule, new)
        schedule = new
    raise GuardError(f"guarded execution hit the {MAX_ATTEMPTS}-attempt cap",
                     report)
