"""Deterministic fault injection for guarded-execution testing.

:class:`FaultPlan` is a context manager that arms injectors; the plan
executor calls tiny tap functions at fixed points of every exchange stage
(wire buffers after the collective, stage inputs, the int8 codec's scale,
executor build) and each tap perturbs the traced values only while a
matching fault is armed — with no active FaultPlan every tap returns its
input untouched and traces **zero** ops, so ``guard="off"`` artifacts stay
bit-identical (planlint PLAN008 proves it).

Faults target a (stage, engine, codec) triple — any field left ``None``
is a wildcard — which is what makes the degradation ladder testable: a
fault pinned to ``engine="pipelined"`` stops matching once the runner
falls back to ``fused``, so "recovered" means the ladder actually moved
execution off the faulted configuration.

Injectors:

* :meth:`FaultPlan.corrupt_wire` — burst corruption of a received wire
  buffer (exponent bits forced to ones: the payload element becomes
  Inf/NaN; int8 payloads flip a magnitude bit, bounded by the codec's
  error contract — target ``label="scale"`` for a detectable int8 hit).
* :meth:`FaultPlan.nan_input` — a NaN/Inf element in an exchange stage's
  input block.
* :meth:`FaultPlan.saturate` — divides the int8 codec's scale, collapsing
  the dynamic range so the payload clips (trips the saturation counter).
* :meth:`FaultPlan.fail_compile` — raises :class:`FaultInjected` while the
  executor for a matching schedule entry is being built/traced (a
  schedule-compile failure, e.g. of a poisoned cache entry's engine).
* :meth:`FaultPlan.poison_cache` — writes a structurally *valid* tuner
  cache entry naming a schedule the tuner never timed (pair with
  ``fail_compile`` on that schedule's engine to model a cache entry that
  replays but cannot execute).

Injection happens at trace time, so a fault armed while an executor is
first traced persists in that compiled artifact for its cache lifetime —
construct fresh plans inside the ``with FaultPlan()`` block (tests do).

Serve-level injectors (:mod:`repro.serve`): these fire on the *host* side
of the serving engine's request lifecycle — not at trace time — so they
stay deterministic across backends and hit hot (already-compiled)
executors, which trace-time faults cannot:

* :meth:`FaultPlan.slow_collective` — stalls a plan execution for
  ``seconds`` (models a degraded interconnect wedging a collective; the
  dispatch blocks exactly like a slow all-to-all would), exercising the
  deadline machinery.
* :meth:`FaultPlan.executor_crash` — raises :class:`FaultInjected` from a
  plan execution attempt (a crashed backend executor), exercising the
  bounded retry/backoff path.  Defaults to firing once (``times=1``) so a
  retry can observe recovery.
* :meth:`FaultPlan.cache_corruption` — scribbles over the shared schedule
  DB *between* requests (mode ``"garbage"``: unparseable bytes; mode
  ``"truncate"``: an empty file) — the mid-flight corruption another
  crashed replica could leave behind.
* :meth:`FaultPlan.request_burst` — tells the load harness (CLI / soak
  test) to multiply its offered load by ``factor`` for one wave,
  exercising admission control and load shedding.

Every serve-level fault takes ``times`` (default varies per injector;
``None`` = unlimited): the fault disarms itself after firing that many
times, so a bounded injection provably recovers.
"""

from __future__ import annotations

import threading
import time as _time
from contextlib import contextmanager
from dataclasses import dataclass
from pathlib import Path

import jax.numpy as jnp
from jax import lax


class FaultInjected(RuntimeError):
    """Raised at executor build/trace time by an armed compile-failure
    fault (the stand-in for a schedule that cannot compile)."""


@dataclass
class _Fault:
    kind: str                 # corrupt_wire | nan_input | saturate | compile_fail
                              # | slow_collective | executor_crash
                              # | cache_corruption | request_burst
    stage: int | None = None  # exchange index (execution order); None = any
    engine: str | None = None
    codec: str | None = None
    label: str | None = None  # corrupt_wire: "payload" | "scale"
    value: float = 0.0
    times: int | None = None  # max fires before the fault disarms (None = ∞)


#: the armed FaultPlan (module-global: tests arm exactly one plan at a time)
_ACTIVE: "FaultPlan | None" = None

#: trace-time context the executor sets per exchange stage — **per thread**:
#: the serving engine traces its fallback executor concurrently with a
#: background retune thread re-tracing the primary schedule, and a shared
#: dict would leak one thread's (stage, engine, codec) into the other's
#: trace (a bf16-targeted fault would hit a complex64 fallback stage)
_CTX_LOCAL = threading.local()


def _ctx() -> dict:
    if not hasattr(_CTX_LOCAL, "ctx"):
        _CTX_LOCAL.ctx = {"stage": None, "engine": None, "codec": None}
    return _CTX_LOCAL.ctx


class FaultPlan:
    """Armed set of deterministic faults (see module docstring).

    Use as a context manager; injector methods return ``self`` so they
    chain.  ``fired`` records every injection that actually happened (at
    trace time), with the (stage, engine, codec) context it matched.
    """

    def __init__(self):
        self._faults: list[_Fault] = []
        self.fired: list[dict] = []

    # -- injectors ----------------------------------------------------------

    def corrupt_wire(self, *, stage=None, engine=None, codec=None,
                     label="payload"):
        self._faults.append(_Fault("corrupt_wire", stage, engine, codec, label))
        return self

    def nan_input(self, *, stage=None, engine=None, codec=None,
                  value=float("nan")):
        self._faults.append(_Fault("nan_input", stage, engine, codec,
                                   None, value))
        return self

    def saturate(self, *, stage=None, engine=None, factor=64.0):
        self._faults.append(_Fault("saturate", stage, engine, "int8",
                                   None, factor))
        return self

    def fail_compile(self, *, stage=None, engine=None, codec=None):
        self._faults.append(_Fault("compile_fail", stage, engine, codec))
        return self

    # -- serve-level injectors (host-side request lifecycle) ----------------

    def slow_collective(self, *, seconds=1.0, times=None):
        """Stall matching plan executions by ``seconds`` (a wedged/slow
        collective as the serving engine experiences it)."""
        self._faults.append(_Fault("slow_collective", value=seconds,
                                   times=times))
        return self

    def executor_crash(self, *, times=1):
        """Raise :class:`FaultInjected` from ``times`` plan execution
        attempts (a crashed executor; the retry path's test hook)."""
        self._faults.append(_Fault("executor_crash", times=times))
        return self

    def cache_corruption(self, *, mode="garbage", times=1):
        """Corrupt the shared schedule DB between requests: ``"garbage"``
        writes unparseable bytes, ``"truncate"`` empties the file."""
        if mode not in ("garbage", "truncate"):
            raise ValueError(f"unknown cache_corruption mode {mode!r}")
        self._faults.append(_Fault("cache_corruption", label=mode, times=times))
        return self

    def request_burst(self, *, factor=4, times=1):
        """Tell the load harness to multiply its offered load by ``factor``
        for ``times`` waves (admission-control / load-shedding pressure)."""
        self._faults.append(_Fault("request_burst", value=float(factor),
                                   times=times))
        return self

    @staticmethod
    def poison_cache(path, plan, schedule, *, nfields: int = 1) -> str:
        """Write a structurally valid tuner-cache entry for ``plan``'s key
        naming ``schedule`` (which the tuner never timed); returns the key."""
        from repro.core import tuner

        key = tuner.plan_key(plan, nfields=nfields)
        entry = {"schedule": [list(s) for s in schedule],
                 "timings": {"poisoned": {}}}
        tuner.save_cache(path, {key: entry})
        return key

    # -- context ------------------------------------------------------------

    def __enter__(self):
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already active")
        _ACTIVE = self
        return self

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = None
        return False


@contextmanager
def stage_context(stage, engine, codec):
    """Executor hook: scope the (stage, engine, codec) the taps match
    against.  Free when no FaultPlan is armed."""
    if _ACTIVE is None:
        yield
        return
    ctx = _ctx()
    prev = dict(ctx)
    ctx.update(stage=stage, engine=engine, codec=codec)
    try:
        yield
    finally:
        ctx.update(prev)


def _matching(kind: str, label: str | None = None):
    if _ACTIVE is None:
        return []
    out = []
    ctx = _ctx()
    for f in _ACTIVE._faults:
        if f.kind != kind:
            continue
        if f.times is not None and f.times <= 0:
            continue  # bounded fault already used up its fires
        if f.stage is not None and f.stage != ctx["stage"]:
            continue
        if f.engine is not None and f.engine != ctx["engine"]:
            continue
        if f.codec is not None and f.codec != ctx["codec"]:
            continue
        if label is not None and f.label is not None and f.label != label:
            continue
        out.append(f)
    return out


def _fire(f: _Fault, **note):
    if f.times is not None:
        f.times -= 1
    _ACTIVE.fired.append({"kind": f.kind, **dict(_ctx()), **note})


# -- taps (each is a no-op tracing zero eqns when nothing matches) ----------


def check_compile(engine: str, codec: str):
    """Raise :class:`FaultInjected` if a compile-failure fault matches the
    current stage context (called while the executor traces)."""
    for f in _matching("compile_fail"):
        _fire(f)
        raise FaultInjected(
            f"injected schedule-compile failure (engine={engine!r}, "
            f"codec={codec!r}, stage={_ctx()['stage']})")


def tap_stage_input(block):
    """Poison element 0 of a matching exchange stage's input block."""
    for f in _matching("nan_input"):
        _fire(f, value=f.value)
        flat = block.reshape(-1)
        flat = flat.at[0].set(jnp.asarray(f.value, dtype=block.dtype))
        block = flat.reshape(block.shape)
    return block


def scale_div():
    """Combined scale divisor armed saturation faults impose on the int8
    codec (None when none match)."""
    div = 1.0
    for f in _matching("saturate"):
        _fire(f, factor=f.value)
        div *= f.value
    return div if div != 1.0 else None


_UINT = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}
#: exponent-burst masks: OR-ing forces the exponent field to all ones
#: (Inf/NaN) for float payloads; int8 flips a magnitude bit (bounded)
_BURST = {jnp.dtype(jnp.float32): (4, 0x7F800000),
          jnp.dtype(jnp.bfloat16): (2, 0x7F80),
          jnp.dtype(jnp.int8): (1, 0x40)}


def tap_wire(x, label: str = "payload"):
    """Corrupt element 0 of a received wire buffer (post-collective,
    pre-decode) when a matching corrupt_wire fault is armed."""
    for f in _matching("corrupt_wire", label):
        _fire(f, label=label, dtype=str(x.dtype))
        x = _burst(x)
    return x


def _burst(x):
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        return lax.complex(_burst(jnp.real(x)), jnp.imag(x))
    size, mask = _BURST[jnp.dtype(x.dtype)]
    u = lax.bitcast_convert_type(x, _UINT[size]).reshape(-1)
    if x.dtype == jnp.int8:
        u = u.at[0].set(u[0] ^ mask)  # single bit flip: bounded by the codec
    else:
        u = u.at[0].set(u[0] | mask)  # stuck-at-ones exponent burst -> Inf/NaN
    return lax.bitcast_convert_type(u.reshape(x.shape), x.dtype)


# -- serve-level taps (host side; free no-ops when nothing matches) ---------


def tap_serve_execute():
    """Serving-engine hook, called at the top of every plan execution
    attempt: an armed ``slow_collective`` stalls the dispatch, then an
    armed ``executor_crash`` raises :class:`FaultInjected`.  The crash is
    raised *after* any stall so a slow-then-dead executor is modelable by
    arming both."""
    for f in _matching("slow_collective"):
        _fire(f, seconds=f.value)
        _time.sleep(f.value)
    for f in _matching("executor_crash"):
        _fire(f)
        raise FaultInjected("injected executor crash")


def tap_serve_cache(path):
    """Serving-engine hook, called between request waves: an armed
    ``cache_corruption`` fault scribbles over the shared schedule DB at
    ``path`` (the torn write a crashed replica could leave).  Returns True
    when a corruption fired."""
    fired = False
    for f in _matching("cache_corruption"):
        _fire(f, mode=f.label, path=str(path))
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text("" if f.label == "truncate" else '{"schema": 6, "trunca')
        fired = True
    return fired


def serve_burst() -> int:
    """Load-harness hook: the offered-load multiplier armed
    ``request_burst`` faults impose this wave (1 when none match)."""
    factor = 1.0
    for f in _matching("request_burst"):
        _fire(f, factor=f.value)
        factor *= f.value
    return max(1, int(factor))
