"""Runtime health: fused guard statistics and the HealthReport.

Two halves:

* **Traced guard ops** (:func:`output_probe`, :func:`payload_stats`,
  :func:`block_energy`, :func:`zero_stats`, :func:`add_stats`,
  :func:`pack_stats`) — reductions the plan executor runs when
  ``ParallelFFT(guard != "off")``, sized so the lossless hot path stays
  within a few percent of the unguarded plan:

  - always: the :func:`output_probe`, a single-plane sum that witnesses
    any non-finite value the execution produced (each 1-D transform mixes
    every input of a line into each output mode, so NaN/Inf anywhere
    upstream of the final FFT stage reaches the probe plane) at ~1/n the
    cost of a full scan;
  - only for schedules with lossy wire stages (:func:`schedule_is_lossy`):
    the block-energy Parseval bracket (full reductions before/after the
    plan — lossy codecs can corrupt *finitely*, e.g. a bad int8 scale, so
    an energy-conservation check is required there), per-stage non-finite
    counts over bf16 payloads, and the int8 saturation count (piggybacked
    on the codec's clip, see :func:`repro.core.quant.quantize_int8`).

  Lossless (complex64) stages carry no per-stage scan — their only
  corruption mode is non-finite values, which the probe catches globally.
  The executor emits NO collective for the stats either — each shard
  returns its local packed vector and the runner sums the partials on the
  host, keeping the guarded hot path free of extra all-reduces.  These
  ops live in this module so planlint's source attribution can prove
  they are present exactly when guarding is on (PLAN008): guard="off"
  compiles to the bit-identical unguarded jaxpr.

* **Host-side evaluation** (:func:`unpack_partials`,
  :func:`build_report`) — sums the per-shard stat vectors one execution
  produced and turns them into a :class:`HealthReport`: per-stage
  :class:`StageHealth` rows, trip codes, and the Parseval relative error
  where it applies (all-c2c plans, where energy is conserved up to the
  unnormalized-FFT factor ``prod(shape)``).

This module must not import :mod:`repro.core` at module scope (the plan
executor imports it); the one plan-shape helper does so lazily.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np
from jax import lax

#: guard modes ParallelFFT accepts
GUARD_MODES = ("off", "strict", "degrade")

#: int8 saturation fraction above which a stage trips (per-block max-abs
#: scaling saturates ~1 element per block in healthy runs; a meaningful
#: fraction of the payload at ±127 means the dynamic range collapsed)
SAT_FRACTION_TRIP = 0.05

#: per-stage Parseval tolerance contribution by wire payload (the lossy
#: codecs' documented round-trip error bounds, with headroom)
PARSEVAL_TOL = {"complex64": 1e-3, "bf16": 5e-2, "int8": 2e-1}


# ---------------------------------------------------------------------------
# traced guard ops (run inside shard_map; keep them in THIS module so
# planlint attributes their eqns to robustness/health.py)
# ---------------------------------------------------------------------------


def count_nonfinite(x) -> jnp.ndarray:
    """f32 scalar count of non-finite elements (complex: either part)."""
    return jnp.sum(~jnp.isfinite(x), dtype=jnp.float32)


def payload_stats(x) -> dict:
    """Guard stats for a bf16 exchange payload: non-finite count only
    (saturation is an int8-codec concept; the codec reports its own)."""
    return {"nonfinite": count_nonfinite(x), "saturated": jnp.zeros((), jnp.float32)}


def output_probe(block, axis: int | None) -> jnp.ndarray:
    """Near-free non-finite detector for the executor's output block: the
    sum over the index-0 plane along the final FFT stage's ``axis``.

    Every 1-D transform the executor runs (c2c/r2c/DCT/DST, pruned or
    not) mixes *all* inputs of a line into each retained output mode, so
    a single non-finite element anywhere upstream of the last FFT stage
    contaminates that stage's entire transform line.  The index-0 plane
    intersects every such line, so its sum goes NaN/Inf iff the execution
    produced any non-finite value — at ~1/n the cost of a full-block
    scan, which is what keeps the guarded lossless hot path under the
    overhead budget.  ``axis=None`` (a plan whose last stage is not an
    FFT — none of the current plan shapes) falls back to summing the
    whole block."""
    plane = block if axis is None else lax.index_in_dim(block, 0, axis=axis,
                                                        keepdims=False)
    s = jnp.sum(plane)
    if jnp.iscomplexobj(s):
        s = jnp.real(s) + jnp.imag(s)
    return s.astype(jnp.float32)


def block_energy(x) -> jnp.ndarray:
    """f32 scalar sum |x|^2 over one shard (zero padding contributes 0, so
    padded and logical blocks have identical energy).  Computed as
    ``re^2 + im^2`` rather than ``abs(x)^2`` — complex abs lowers to a
    per-element hypot (libm sqrt) on CPU, several times the cost of the
    two multiplies this needs."""
    if jnp.iscomplexobj(x):
        r, i = jnp.real(x), jnp.imag(x)
        return (jnp.sum(r * r) + jnp.sum(i * i)).astype(jnp.float32)
    x = x.astype(jnp.float32)
    return jnp.sum(x * x)


def zero_stats() -> dict:
    return {"nonfinite": jnp.zeros((), jnp.float32),
            "saturated": jnp.zeros((), jnp.float32)}


def add_stats(a: dict, b: dict) -> dict:
    return {k: a[k] + b[k] for k in a}


def pack_stats(per_stage: list, energy_in, energy_out, probe) -> jnp.ndarray:
    """Pack one shard's guard stats into the executor's flat f32 output
    vector ``[energy_in, energy_out, probe, nonfinite_0..S-1,
    saturated_0..S-1]`` (``S`` exchange stages).  One vector per shard, no
    collective: the runner gathers the shards and :func:`unpack_partials`
    sums them.  Lives here (not in the executor) so the concatenate it
    emits is attributed to robustness/ — planlint must not count it
    against the exchange engine's realignment contract (PLAN004)."""
    parts = [jnp.stack([energy_in, energy_out, probe])]
    if per_stage:
        parts.append(jnp.stack([s["nonfinite"] for s in per_stage]))
        parts.append(jnp.stack([s["saturated"] for s in per_stage]))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_partials(raw, nstages: int) -> dict:
    """Sum the per-shard packed stat vectors (host side, outside the
    compiled hot path) back into the stats dict :func:`build_report`
    evaluates.  ``raw`` is the executor's stats output: the shard-local
    vectors concatenated along axis 0 by the sharded out_spec."""
    width = 3 + 2 * nstages
    vec = np.asarray(raw, np.float64).reshape(-1, width).sum(axis=0)
    return {"energy_in": vec[0], "energy_out": vec[1], "probe": vec[2],
            "nonfinite": vec[3:3 + nstages],
            "saturated": vec[3 + nstages:]}


def schedule_is_lossy(entries) -> bool:
    """True when any schedule entry ships a lossy wire payload.  The full
    Parseval energy bracket only runs for such schedules: lossless wire is
    bit-exact, so its only corruption mode is non-finite values — which
    :func:`output_probe` catches without the two full-block reductions."""
    return any(e[2] in ("bf16", "int8") for e in entries)


# ---------------------------------------------------------------------------
# host-side report
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StageHealth:
    """One exchange stage's guard outcome (counts are global: summed over
    every shard's partial stats).  Lossless (complex64) stages always show
    zero counts — their corruption surfaces as the global
    ``output:nonfinite``/``parseval`` trips instead."""

    stage: int
    method: str
    comm_dtype: str
    nonfinite: int
    saturated: int
    elems: int  # payload elements the counters ran over (all ranks)
    tripped: tuple[str, ...] = ()

    @property
    def sat_fraction(self) -> float:
        return self.saturated / max(self.elems, 1)

    def to_dict(self) -> dict:
        return {"stage": self.stage, "method": self.method,
                "comm_dtype": self.comm_dtype, "nonfinite": self.nonfinite,
                "saturated": self.saturated, "elems": self.elems,
                "sat_fraction": self.sat_fraction,
                "tripped": list(self.tripped)}


@dataclass(frozen=True)
class HealthReport:
    """Guard outcome of one guarded plan execution.

    ``tripped`` collects every trip code: per-stage ``"stage{i}:nonfinite"``
    / ``"stage{i}:saturation"``, plus the global ``"input:nonfinite"``,
    ``"output:nonfinite"`` and ``"parseval"``.  ``energy_in`` /
    ``energy_out`` / ``parseval_rel_err`` are None for all-lossless
    schedules — there the always-on :func:`output_probe` is the (global)
    corruption detector and the two full-block energy reductions are not
    paid (see :func:`schedule_is_lossy`).  ``transitions`` records every
    degradation-ladder step the runner took to produce this (clean)
    result; ``attempts`` is the execution count including the final one.
    """

    guard: str
    direction: str
    nfields: int
    schedule: tuple
    stages: tuple[StageHealth, ...]
    energy_in: float | None
    energy_out: float | None
    parseval_rel_err: float | None
    parseval_tol: float | None
    tripped: tuple[str, ...]
    transitions: tuple = ()
    attempts: int = 1
    fired_faults: tuple = field(default=(), compare=False)

    @property
    def ok(self) -> bool:
        return not self.tripped

    def tripped_stage_indices(self) -> tuple[int, ...]:
        """Exchange-stage indices named by per-stage trip codes (empty when
        only global codes tripped)."""
        out = []
        for code in self.tripped:
            if code.startswith("stage") and ":" in code:
                out.append(int(code.split(":")[0][len("stage"):]))
        return tuple(sorted(set(out)))

    @property
    def has_global_trip(self) -> bool:
        return any(not c.startswith("stage") for c in self.tripped)

    def to_dict(self) -> dict:
        return {
            "guard": self.guard, "direction": self.direction,
            "nfields": self.nfields,
            "schedule": [list(e) for e in self.schedule],
            "stages": [s.to_dict() for s in self.stages],
            "energy_in": self.energy_in, "energy_out": self.energy_out,
            "parseval_rel_err": self.parseval_rel_err,
            "parseval_tol": self.parseval_tol,
            "tripped": list(self.tripped),
            "transitions": [dict(t) for t in self.transitions],
            "attempts": self.attempts,
        }


def _walk(plan, direction: str):
    """(stages, pencils, dtypes) in execution order for ``direction``."""
    from repro.core.pfft import _reverse_plan

    if direction == "forward":
        return plan.stages, plan.pencil_trace, plan.dtype_trace
    stages, pencils = _reverse_plan(plan.stages, plan.pencil_trace)
    return stages, pencils, plan.dtype_trace[::-1]


def parseval_factor(plan, direction: str) -> float | None:
    """Expected ``energy_out / energy_in`` ratio, or None when the plan
    does not conserve energy analytically (any non-c2c axis: r2c halves the
    stored spectrum, pruning drops modes, DCT/DST carry other norms).  The
    repo's unnormalized forward multiplies energy by ``prod(shape)``; the
    normalized backward divides it back out."""
    if any(sp.kind != "c2c" for sp in plan.transforms):
        return None
    n = float(math.prod(plan.shape))
    return n if direction == "forward" else 1.0 / n


def build_report(plan, *, direction: str, nfields: int, schedule, stats,
                 guard: str, transitions=(), attempts: int = 1,
                 fired_faults=()) -> HealthReport:
    """Evaluate one execution's summed guard stats into a HealthReport.

    ``stats`` is :func:`unpack_partials`' output: per-exchange-stage
    ``nonfinite``/``saturated`` vectors plus scalar ``energy_in`` /
    ``energy_out``, summed over all shards.  Payload element counts come
    analytically from the pencil/dtype traces — nothing here touches
    devices."""
    from repro.core.pfft import ExchangeStage

    stages, pencils, dtypes = _walk(plan, direction)
    # schedule arrives in forward plan order; stats/stage rows are in
    # execution order, so a backward walk reads it reversed
    entries = list(schedule) if direction == "forward" else list(schedule)[::-1]
    lossy = schedule_is_lossy(entries)
    nonfinite = [float(v) for v in stats["nonfinite"]]
    saturated = [float(v) for v in stats["saturated"]]
    e_in = float(stats["energy_in"])
    e_out = float(stats["energy_out"])
    probe = float(stats.get("probe", 0.0))

    rows: list[StageHealth] = []
    tripped: list[str] = []
    ex_i = 0
    for i, st in enumerate(stages):
        if not isinstance(st, ExchangeStage):
            continue
        method, _, comm_dtype = entries[ex_i][0], entries[ex_i][1], entries[ex_i][2]
        # the codec sees the physical (padded) block as re/im planes; count
        # the same elements the traced reductions saw, across all ranks
        planes = 2 if dtypes[i] == jnp.complex64 else 1
        elems = max(1, nfields) * planes * math.prod(pencils[i].physical)
        codes = []
        if nonfinite[ex_i] > 0:
            codes.append(f"stage{ex_i}:nonfinite")
        if comm_dtype == "int8" and saturated[ex_i] / elems > SAT_FRACTION_TRIP:
            codes.append(f"stage{ex_i}:saturation")
        rows.append(StageHealth(
            stage=ex_i, method=method, comm_dtype=comm_dtype,
            nonfinite=int(nonfinite[ex_i]), saturated=int(saturated[ex_i]),
            elems=elems, tripped=tuple(codes)))
        tripped.extend(codes)
        ex_i += 1

    # the energy bracket only runs for lossy schedules (see
    # schedule_is_lossy); the probe is the always-on output detector
    if lossy and not math.isfinite(e_in):
        tripped.append("input:nonfinite")
    if (lossy and not math.isfinite(e_out)) or not math.isfinite(probe):
        tripped.append("output:nonfinite")

    factor = parseval_factor(plan, direction) if lossy else None
    rel_err = tol = None
    if factor is not None and math.isfinite(e_in) and math.isfinite(e_out):
        want = factor * e_in
        rel_err = abs(e_out - want) / max(want, 1e-30)
        tol = max(1e-3, sum(PARSEVAL_TOL.get(e[2], 1e-3) for e in entries))
        if rel_err > tol:
            tripped.append("parseval")

    return HealthReport(
        guard=guard, direction=direction, nfields=nfields,
        schedule=tuple(tuple(e) for e in entries), stages=tuple(rows),
        energy_in=e_in if lossy else None,
        energy_out=e_out if lossy else None,
        parseval_rel_err=rel_err, parseval_tol=tol, tripped=tuple(tripped),
        transitions=tuple(transitions), attempts=attempts,
        fired_faults=tuple(fired_faults))
