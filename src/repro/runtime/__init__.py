from repro.runtime.trainer import Trainer, TrainConfig

__all__ = ["Trainer", "TrainConfig"]
