"""Fault-tolerant training runtime.

Capabilities (the 1000+-node posture, exercised at container scale):

* **checkpoint/restart** — async checkpoints every ``ckpt_every`` steps via
  ``CheckpointManager``; on start the trainer resumes from the latest valid
  checkpoint (atomic manifests make torn writes invisible).  The data
  pipeline is a pure function of step, so the token stream replays exactly.
* **elastic restart** — checkpoints save logical arrays + spec strings;
  ``Trainer`` re-device_puts into *its* mesh on load, so the same checkpoint
  restores onto a different mesh shape (tested in tests/test_runtime.py).
* **preemption** — SIGTERM/SIGINT request a final synchronous checkpoint at
  the next step boundary (emergency save), then exit cleanly.
* **straggler detection** — per-step wall times go into a rolling window; a
  step slower than ``straggler_factor``x the window median emits a
  SLOW_STEP event to the heartbeat log.  On a real cluster this heartbeat
  is the input to the coordinator's evict/re-shard decision; the detection
  and the hook live here.
* **overlap** — async checkpoint write happens off-thread while the next
  steps run; batches for step+1 are staged with ``device_put`` while step
  executes (host->device overlap).
"""

from __future__ import annotations

import json
import signal
import time
from collections import deque
from dataclasses import dataclass
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint
from repro.core.meshutil import set_mesh, shard_map as _shard_map
from repro.data import SyntheticLMData, make_batch_specs
from repro.models.lm import LM
from repro.optim import AdamW, OptState, cosine_schedule


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "ckpt"
    log_every: int = 10
    lr: float = 3e-4
    warmup: int = 20
    straggler_factor: float = 3.0
    straggler_window: int = 32
    keep_ckpts: int = 3
    # "none" | "int8": int8 error-feedback gradient reduction over the data
    # axis (explicit-DP path: params replicated over data, TP untouched —
    # the regime where the DP all-reduce dominates; see optim/compress.py)
    grad_compression: str = "none"


class Trainer:
    def __init__(self, lm: LM, data: SyntheticLMData, tc: TrainConfig):
        self.lm, self.data, self.tc = lm, data, tc
        self.mesh = lm.mesh
        self.opt = AdamW(lr=cosine_schedule(tc.lr, tc.warmup, tc.steps))
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.keep_ckpts)
        self._stop = False
        self._times: deque[float] = deque(maxlen=tc.straggler_window)
        self.heartbeat_path = Path(tc.ckpt_dir) / "heartbeat.log"

        pshard = lm.param_shardings()
        oshard = OptState(jax.sharding.NamedSharding(self.mesh, jax.sharding.PartitionSpec()),
                          pshard, pshard)
        bshard = make_batch_specs(self.mesh, lm.axes.dp, data.global_batch)

        if tc.grad_compression == "int8":
            from jax.sharding import PartitionSpec as P

            from repro.optim.compress import (ErrorFeedback, compressed_psum,
                                              reduce_local_roundtrip)

            dp = lm.axes.dp
            lm_local = LM(lm.cfg, lm.mesh, lm.axes, q_block=lm.q_block,
                          xent_chunks=lm.xent_chunks, perf=lm.perf,
                          batch_sharded=False, local_mode=True)

            def step_fn(params, opt_state, err, batch):
                def shard_loss_grads(p, e, b):
                    # per-DP-shard grads on replicated params; e carries a
                    # leading per-rank dim (error feedback is rank-local)
                    (loss, _), g = jax.value_and_grad(
                        lm_local.loss, has_aux=True)(p, b)
                    e = jax.tree.map(lambda x: x[0], e)
                    g, err2 = ErrorFeedback.apply(
                        g, e, lambda c: compressed_psum(c, self.mesh, dp[-1]),
                        local_fn=lambda c: reduce_local_roundtrip(
                            c, self.mesh, dp[-1]))
                    loss = jax.lax.pmean(loss, dp[-1])
                    err2 = jax.tree.map(lambda x: x[None], err2)
                    return loss, g, err2

                aparams = jax.tree.map(lambda x: P(), params)
                espec = jax.tree.map(lambda x: P(dp[-1], *(None,) * (x.ndim - 1)),
                                     err)
                bspec = jax.tree.map(lambda x: P(dp, *(None,) * (x.ndim - 1)), batch)
                loss, grads, err2 = _shard_map(
                    shard_loss_grads, mesh=self.mesh,
                    in_specs=(aparams, espec, bspec),
                    out_specs=(P(), aparams, espec), check_vma=False)(
                        params, err, batch)
                ndp = self.mesh.shape[dp[-1]]
                grads = jax.tree.map(lambda g: g / ndp, grads)
                params2, opt_state, om = self.opt.update(grads, opt_state, params)
                return params2, opt_state, err2, {"loss": loss, "xent": loss, **om}

            self._err_feedback = True
            self.train_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        else:
            def step_fn(params, opt_state, batch):
                (loss, metrics), grads = jax.value_and_grad(lm.loss, has_aux=True)(params, batch)
                params, opt_state, om = self.opt.update(grads, opt_state, params)
                return params, opt_state, {"loss": loss, **metrics, **om}

            self._err_feedback = False
            self.train_step = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
        self.pshard, self.oshard, self.bshard = pshard, oshard, bshard

    # -- state ----------------------------------------------------------------

    def init_state(self, seed: int = 0):
        with set_mesh(self.mesh):
            params = jax.jit(self.lm.init_params, out_shardings=self.pshard)(
                jax.random.PRNGKey(seed))
            opt_state = jax.jit(self.opt.init, out_shardings=self.oshard)(params)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0):
        last = self.ckpt.latest_step()
        if last is None:
            return self.init_state(seed)
        params, opt_state, step = self.init_state(seed)  # abstract targets
        tree = {"params": params, "opt": opt_state}
        shards = {"params": self.pshard, "opt": self.oshard}
        restored, manifest = load_checkpoint(self.tc.ckpt_dir, tree, shardings=shards)
        return restored["params"], restored["opt"], manifest["step"]

    # -- loop -------------------------------------------------------------------

    def _heartbeat(self, record: dict):
        with open(self.heartbeat_path, "a") as f:
            f.write(json.dumps(record) + "\n")

    def _signal(self, *_):
        self._stop = True

    def run(self, seed: int = 0, on_metrics=None):
        tc = self.tc
        Path(tc.ckpt_dir).mkdir(parents=True, exist_ok=True)
        old1 = signal.signal(signal.SIGTERM, self._signal)
        old2 = signal.signal(signal.SIGINT, self._signal)
        params, opt_state, start = self.restore_or_init(seed)
        history = []
        err = None
        if self._err_feedback:
            ndp = self.mesh.shape[self.lm.axes.dp[-1]]
            err = jax.tree.map(
                lambda p: jax.numpy.zeros((ndp, *p.shape), jax.numpy.float32),
                params)
        try:
            staged = jax.device_put(self.data.host_local_batch(start), self.bshard)
            for step in range(start, tc.steps):
                t0 = time.perf_counter()
                batch = staged
                if self._err_feedback:
                    params, opt_state, err, metrics = self.train_step(
                        params, opt_state, err, batch)
                else:
                    params, opt_state, metrics = self.train_step(params, opt_state, batch)
                if step + 1 < tc.steps:  # stage next batch while step executes
                    staged = jax.device_put(self.data.host_local_batch(step + 1), self.bshard)
                loss = float(metrics["loss"])  # sync point
                dt = time.perf_counter() - t0
                median = float(np.median(self._times)) if self._times else dt
                slow = dt > tc.straggler_factor * median and len(self._times) >= 8
                self._times.append(dt)
                self._heartbeat({"step": step, "t": dt, "loss": loss,
                                 **({"event": "SLOW_STEP"} if slow else {})})
                history.append({"step": step, "loss": loss, "time": dt,
                                "grad_norm": float(metrics["grad_norm"])})
                if on_metrics:
                    on_metrics(history[-1])
                if (step + 1) % tc.ckpt_every == 0:
                    self.ckpt.save_async(step + 1, {"params": params, "opt": opt_state})
                if self._stop:
                    self.ckpt.wait()
                    self.ckpt.save_async(step + 1, {"params": params, "opt": opt_state})
                    self.ckpt.wait()
                    self._heartbeat({"step": step, "event": "PREEMPTED_CLEAN_EXIT"})
                    break
            else:
                self.ckpt.wait()
                self.ckpt.save_async(tc.steps, {"params": params, "opt": opt_state})
                self.ckpt.wait()
        finally:
            signal.signal(signal.SIGTERM, old1)
            signal.signal(signal.SIGINT, old2)
        return params, opt_state, history
