from repro.data.pipeline import SyntheticLMData, make_batch_specs, spectral_field

__all__ = ["SyntheticLMData", "make_batch_specs", "spectral_field"]
