"""Deterministic, shard-aware synthetic data pipeline.

Fault-tolerance contract: every batch is a pure function of ``(seed, step)``
(counter-based RNG via ``fold_in``), so a restarted job replays the exact
token stream from its checkpointed step — no data-loader state to persist.
Each host materializes only its addressable shard (``host_local_batch``),
which is how the real multi-host feed works; on this single-process
container that shard is the full batch.

``spectral_field`` generates smooth periodic fields for the FFT/PDE
examples (band-limited random Fourier modes), on the pencil layout.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class SyntheticLMData:
    """Zipf-ish token stream with a learnable bigram structure.

    Tokens are drawn from a power-law marginal; each next token is offset by
    a deterministic function of the previous one so models can reduce loss
    below the unigram entropy (useful to check training actually learns).
    """

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # power-law marginal via inverse-CDF on uniform
        u = jax.random.uniform(key, (B, S + 1), minval=1e-6)
        base = jnp.floor(jnp.power(u, 3.0) * V).astype(jnp.int32) % V
        # bigram structure: x_{t+1} = (base_{t+1} + 7 * x_t) % V  (mixing)
        def mix(prev, b):
            cur = (b + 7 * prev) % V
            return cur, cur
        _, toks = jax.lax.scan(mix, base[:, 0], base[:, 1:].T)
        toks = toks.T  # (B, S)
        inp = jnp.concatenate([base[:, :1], toks[:, :-1]], axis=1)
        return {
            "tokens": inp,
            "targets": toks,
            "mask": jnp.ones((B, S), jnp.float32),
        }

    def host_local_batch(self, step: int, *, process_index: int = 0,
                         process_count: int = 1):
        """The shard of ``batch(step)`` owned by this host (data-parallel
        contiguous slice of the batch dim)."""
        full = self.batch(step)
        B = self.global_batch
        per = B // process_count
        sl = slice(process_index * per, (process_index + 1) * per)
        return jax.tree.map(lambda x: x[sl], full)


def make_batch_specs(mesh, dp_axes, global_batch: int):
    """NamedShardings for an LM batch dict."""
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    b = dp_axes if global_batch % dp == 0 and global_batch >= dp else None
    tok = NamedSharding(mesh, P(b, None))
    return {"tokens": tok, "targets": tok, "mask": tok}


def spectral_field(key, shape, *, modes: int = 8, dtype=jnp.float32):
    """Smooth periodic field: sum of ``modes`` random Fourier modes/axis."""
    d = len(shape)
    ks = jax.random.split(key, 3)
    amp = jax.random.normal(ks[0], (modes,) * d)
    kvec = [jnp.fft.fftfreq(n) * n for n in shape]
    field = jnp.zeros(shape, jnp.complex64)
    spec = jnp.zeros(shape, jnp.complex64)
    idx = tuple(jnp.meshgrid(*[jnp.arange(modes)] * d, indexing="ij"))
    phase = jax.random.uniform(ks[1], (modes,) * d) * 2 * jnp.pi
    spec = spec.at[idx].set(amp * jnp.exp(1j * phase))
    field = jnp.real(jnp.fft.ifftn(spec)) * float(np.prod(shape)) ** 0.5
    return field.astype(dtype)
