"""Mixture-of-Experts with expert-parallel fused all-to-all dispatch.

The token->expert redistribution is the paper's v->w exchange in disguise:
each EP rank holds a (experts, capacity, d) send buffer whose leading axis is
split across the EP group and concatenated back — one fused
``lax.all_to_all`` each way, no local packing pass beyond the unavoidable
argsort (DESIGN.md §3).  Two execution paths:

``moe_apply_a2a``   — EP dispatch via two fused all-to-alls (train/prefill;
                      needs seq divisible by the EP group).
``moe_apply_local`` — each rank runs its *local* experts on all its tokens,
                      masked by the router, then psums over the EP axis
                      (decode path: for one-token steps the a2a round trip
                      costs more than E_local token-FFNs).

Routing: softmax -> top-k -> renormalize (DeepSeek-V2 style), fp32 router,
GShard capacity with overflow dropping, load-balance aux loss + router
z-loss.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.meshutil import axis_size as _axis_size, shard_map as _shard_map

from repro.models.layers import dense_init, mlp_apply, mlp_init


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def moe_init(key, d: int, cfg, mlp_kind: str, dtype=jnp.bfloat16):
    """cfg: models.config.MoEConfig."""
    ks = jax.random.split(key, 4)
    mult = 3 if mlp_kind in ("swiglu", "geglu") else 2
    ff = cfg.d_ff_expert

    def stack(key, d_in, d_out):
        keys = jax.random.split(key, cfg.n_experts)
        return jnp.stack([dense_init(k, d_in, d_out, dtype) for k in keys])

    p = {"router": dense_init(ks[0], d, cfg.n_experts, jnp.float32)}
    if mult == 3:
        p["w_gate"] = stack(ks[1], d, ff)
        p["w_up"] = stack(ks[2], d, ff)
        p["w_down"] = stack(ks[3], ff, d)
    else:
        p["w_up"] = stack(ks[1], d, ff)
        p["w_down"] = stack(ks[2], ff, d)
    if cfg.n_shared:
        p["shared"] = mlp_init(jax.random.fold_in(key, 7), d,
                               cfg.n_shared * ff, mlp_kind, dtype)
    return p


def _expert_ffn(p, x, kind: str):
    """x: (E_loc, C, D) through per-expert FFN weights (E_loc, D, F)."""
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", x, p["w_gate"])) * jnp.einsum(
            "ecd,edf->ecf", x, p["w_up"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", x, p["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def route(router_w, x, top_k: int):
    """x: (N, D) -> gates (N, k), expert ids (N, k), aux metrics.

    Softmax over experts, take top-k, renormalize the selected gates.
    """
    logits = x.astype(jnp.float32) @ router_w  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # load-balance aux (Switch/GShard): E * sum_e f_e * P_e
    E = router_w.shape[-1]
    f = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1)) * top_k
    pbar = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pbar)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gates, idx, aux, zloss


# ---------------------------------------------------------------------------
# EP dispatch via fused all-to-all (the paper's exchange)
# ---------------------------------------------------------------------------


def _dispatch_shard(p, x, *, top_k: int, n_experts: int, mlp_kind: str,
                    ep_axis: str, capacity_factor: float):
    """Per-shard body (inside shard_map): x (B_loc, S_loc, D)."""
    B, S, D = x.shape
    N = B * S
    ep = _axis_size(ep_axis)
    E, E_loc = n_experts, n_experts // ep
    xt = x.reshape(N, D)

    gates, idx, aux, zloss = route(p["router"], xt, top_k)
    cap = int(np.ceil(N * top_k * capacity_factor / E))
    cap = max(cap, 1)

    flat_e = idx.reshape(-1)                       # (N*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(N), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_g = flat_g[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(N * top_k) - first[sorted_e]
    keep = pos < cap
    pos = jnp.where(keep, pos, cap)                # cap -> dropped by mode="drop"

    buf = jnp.zeros((E, cap, D), x.dtype)
    buf = buf.at[sorted_e, pos].set(xt[sorted_t], mode="drop")

    # ---- the paper's fused exchange: (E, cap, D) -> experts local ---------
    buf = buf.reshape(ep, E_loc * cap, D)
    buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0, tiled=True)
    buf = buf.reshape(ep, E_loc, cap, D).transpose(1, 0, 2, 3).reshape(E_loc, ep * cap, D)

    out = _expert_ffn(p, buf, mlp_kind)

    # ---- return trip -------------------------------------------------------
    out = out.reshape(E_loc, ep, cap, D).transpose(1, 0, 2, 3).reshape(ep, E_loc * cap, D)
    out = lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0, tiled=True)
    out = out.reshape(E, cap, D)

    y_sorted = out[sorted_e, jnp.minimum(pos, cap - 1)] * (keep & (pos < cap))[:, None]
    y = jnp.zeros((N, D), jnp.float32).at[sorted_t].add(
        y_sorted.astype(jnp.float32) * sorted_g[:, None])
    aux = lax.pmean(aux, (ep_axis,))
    zloss = lax.pmean(zloss, (ep_axis,))
    return y.astype(x.dtype).reshape(B, S, D), aux, zloss


def moe_apply_a2a(p, x, mesh, *, cfg, mlp_kind: str, dp_axes, ep_axis: str,
                  batch_sharded: bool = True):
    """x: (B, S, D), S divisible by |ep_axis|.  Returns (y, aux, zloss)."""
    bspec = dp_axes if batch_sharded else None
    xspec = P(bspec, ep_axis, None)
    pspec = jax.tree.map(lambda _: P(), p)
    pspec = dict(pspec)
    for k in ("w_gate", "w_up", "w_down"):
        if k in pspec and k != "shared":
            pspec[k] = P(ep_axis, None, None)
    if "shared" in p:
        pspec["shared"] = jax.tree.map(lambda _: P(), p["shared"])

    fn = _shard_map(
        partial(_dispatch_shard, top_k=cfg.top_k, n_experts=cfg.n_experts,
                mlp_kind=mlp_kind, ep_axis=ep_axis,
                capacity_factor=cfg.capacity_factor),
        mesh=mesh,
        in_specs=(pspec, xspec),
        out_specs=(xspec, P(), P()),
        check_vma=False,
    )
    y, aux, zloss = fn(p, x)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, mlp_kind)
    return y, aux, zloss


# ---------------------------------------------------------------------------
# Local-experts path (decode) — no all-to-all, psum combine
# ---------------------------------------------------------------------------


def _local_shard(p, x, *, top_k: int, n_experts: int, mlp_kind: str, ep_axis: str):
    B, S, D = x.shape
    N = B * S
    ep = _axis_size(ep_axis)
    E_loc = n_experts // ep
    r = lax.axis_index(ep_axis)
    xt = x.reshape(N, D)
    gates, idx, aux, zloss = route(p["router"], xt, top_k)
    # dense gate matrix restricted to local experts
    e0 = r * E_loc
    g_full = jnp.zeros((N, n_experts), jnp.float32)
    g_full = g_full.at[jnp.arange(N)[:, None], idx].set(gates)
    g_loc = lax.dynamic_slice_in_dim(g_full, e0, E_loc, axis=1)  # (N, E_loc)
    xin = jnp.broadcast_to(xt[None], (E_loc, N, D))
    yout = _expert_ffn(p, xin, mlp_kind)            # (E_loc, N, D)
    y = jnp.einsum("ne,end->nd", g_loc, yout.astype(jnp.float32))
    y = lax.psum(y, ep_axis)
    return y.astype(x.dtype).reshape(B, S, D), lax.pmean(aux, ep_axis), lax.pmean(zloss, ep_axis)


def moe_apply_local(p, x, mesh, *, cfg, mlp_kind: str, dp_axes, ep_axis: str,
                    batch_sharded: bool = True):
    """Decode path: x (B, S, D) with S tiny; experts local, psum combine."""
    bspec = dp_axes if batch_sharded else None
    xspec = P(bspec, None, None)
    pspec = dict(jax.tree.map(lambda _: P(), p))
    for k in ("w_gate", "w_up", "w_down"):
        if k in pspec:
            pspec[k] = P(ep_axis, None, None)
    if "shared" in p:
        pspec["shared"] = jax.tree.map(lambda _: P(), p["shared"])
    fn = _shard_map(
        partial(_local_shard, top_k=cfg.top_k, n_experts=cfg.n_experts,
                mlp_kind=mlp_kind, ep_axis=ep_axis),
        mesh=mesh, in_specs=(pspec, xspec), out_specs=(xspec, P(), P()),
        check_vma=False,
    )
    y, aux, zloss = fn(p, x)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, mlp_kind)
    return y, aux, zloss


# ---------------------------------------------------------------------------
# Meshless dense path (explicit-DP / local_mode: all experts resident)
# ---------------------------------------------------------------------------


def moe_apply_dense(p, x, *, cfg, mlp_kind: str):
    """Every token through every expert, gate-masked — O(E/k) extra compute,
    used only in local_mode (explicit-DP training, smoke tests)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    gates, idx, aux, zloss = route(p["router"], xt, cfg.top_k)
    g_full = jnp.zeros((B * S, cfg.n_experts), jnp.float32)
    g_full = g_full.at[jnp.arange(B * S)[:, None], idx].set(gates)
    xin = jnp.broadcast_to(xt[None], (cfg.n_experts, B * S, D))
    yout = _expert_ffn(p, xin, mlp_kind)
    y = jnp.einsum("ne,end->nd", g_full, yout.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, D)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, mlp_kind)
    return y, aux, zloss
