"""State-space layers: Mamba1 (selective scan) and Mamba2 (SSD).

Both are written **chunked**: a sequential ``lax.scan`` over sequence chunks
carrying the SSM state, with the within-chunk work either an associative
scan (Mamba1) or decay-masked matmuls (Mamba2/SSD — MXU-native, the same
"express the recurrence as dense contractions" doctrine the four-step DFT
kernel uses).  Chunk bodies are ``jax.checkpoint``-ed so the backward pass
stores only the per-chunk carried state, never (B, T, d_inner, d_state).

Decode is O(1) in sequence length: conv ring state + SSM state per layer —
this is what makes ``long_500k`` runnable for the ssm/hybrid archs.

TP: d_inner (and Mamba2 heads) shard over the model axis; states inherit it.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# Causal depthwise conv (shared by both variants)
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (K, C) depthwise taps; left-padded causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):  # K is 4 — unrolled taps beat a conv HLO here
        # xp[:, t+k] is x[t - (K-1-k)]: the newest input meets the LAST tap
        out = out + xp[:, k : k + x.shape[1]].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_tail(x: jax.Array, K: int) -> jax.Array:
    """Last K-1 raw inputs of (B, T, C) — the decode conv ring state."""
    B, T, C = x.shape
    if T >= K - 1:
        return x[:, T - (K - 1):]
    return jnp.pad(x, ((0, 0), (K - 1 - T, 0), (0, 0)))


def causal_conv_step(state: jax.Array, x_t: jax.Array, w: jax.Array, b: jax.Array):
    """Decode: state (B, K-1, C) holds the last K-1 inputs; x_t (B, C)."""
    K = w.shape[0]
    window = jnp.concatenate([state, x_t[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(x_t.dtype)
    return window[:, 1:], y


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_init(key, d: int, cfg, dtype=jnp.bfloat16):
    di = cfg.expand * d
    dtr = cfg.dt_rank or -(-d // 16)
    N = cfg.d_state
    ks = jax.random.split(key, 6)
    # S4D-real init for A; dt bias so softplus(dt) spans [1e-3, 1e-1]
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (di, 1))
    dt = jnp.exp(jax.random.uniform(ks[4], (di,)) * (math.log(0.1) - math.log(1e-3))
                 + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di)) / math.sqrt(cfg.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype, scale=dtr**-0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _ssm_combine(l, r):
    """Associative combine for h_t = a_t h_{t-1} + b_t (l earlier than r)."""
    a_l, b_l = l
    a_r, b_r = r
    return a_l * a_r, a_r * b_l + b_r


def selective_scan(x, dt, A, Bm, Cm, *, chunk: int, h0=None):
    """Diagonal selective scan, chunked.

    x, dt: (B, T, Di); A: (Di, N); Bm, Cm: (B, T, N).
    Returns y (B, T, Di) fp32 and final state (B, Di, N) fp32.
    """
    B, T, Di = x.shape
    N = A.shape[-1]
    Lc = min(chunk, T)
    pad = -T % Lc
    if pad:
        x, dt, Bm, Cm = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
                         for a in (x, dt, Bm, Cm))
    nch = (T + pad) // Lc
    xs = tuple(a.reshape(B, nch, Lc, -1).swapaxes(0, 1) for a in (x, dt, Bm, Cm))
    h = jnp.zeros((B, Di, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    @jax.checkpoint
    def body(h, xs_c):
        x_c, dt_c, B_c, C_c = xs_c
        dt_f = dt_c.astype(jnp.float32)
        dA = jnp.exp(dt_f[..., None] * A)                         # (B, Lc, Di, N)
        dBx = dt_f[..., None] * B_c.astype(jnp.float32)[:, :, None, :] \
            * x_c.astype(jnp.float32)[..., None]
        a_sc, b_sc = lax.associative_scan(_ssm_combine, (dA, dBx), axis=1)
        hs = b_sc + a_sc * h[:, None]                             # (B, Lc, Di, N)
        y_c = jnp.einsum("blin,bln->bli", hs, C_c.astype(jnp.float32))
        return hs[:, -1], y_c

    h, ys = lax.scan(body, h, xs)
    y = ys.swapaxes(0, 1).reshape(B, T + pad, Di)[:, :T]
    return y, h


def mamba1_apply(p, u, *, cfg, state=None):
    """u: (B, T, D).  state=None for train/prefill; returns (y, new_state).

    ``state`` is {"conv": (B, K-1, Di), "ssm": (B, Di, N)} for decode.
    """
    di = p["D"].shape[0]
    N = cfg.d_state
    dtr = p["dt_proj"].shape[0]
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        conv_state = conv_tail(x, cfg.d_conv)
        x = causal_conv(x, p["conv_w"], p["conv_b"])
    else:
        conv_state, x1 = causal_conv_step(state["conv"], x[:, 0], p["conv_w"], p["conv_b"])
        x = x1[:, None]
    x = jax.nn.silu(x)

    proj = x @ p["x_proj"]
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if state is None:
        y, h = selective_scan(x, dt, A, Bm, Cm, chunk=cfg.chunk)
        new_state = {"ssm": h, "conv": conv_state}
    else:
        dA = jnp.exp(dt[:, 0, :, None] * A)
        dBx = dt[:, 0, :, None] * Bm.astype(jnp.float32)[:, 0, None, :] \
            * x.astype(jnp.float32)[:, 0, :, None]
        h = dA * state["ssm"] + dBx
        y = jnp.einsum("bin,bn->bi", h, Cm.astype(jnp.float32)[:, 0])[:, None]
        new_state = {"ssm": h, "conv": conv_state}

    y = y + p["D"] * x.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["out_proj"], new_state


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_init(key, d: int, cfg, dtype=jnp.bfloat16):
    di = cfg.expand * d
    nh = di // cfg.headdim
    N = cfg.d_state
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * N
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) * (math.log(0.1) - math.log(1e-3))
                 + math.log(1e-3))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * N + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_dim)) / math.sqrt(cfg.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


def ssd_scan(xh, dt, a_log, Bm, Cm, *, chunk: int, s0=None):
    """SSD chunked recurrence (Mamba2).

    xh: (B, T, H, P) inputs per head; dt: (B, T, H) (post-softplus);
    a_log = -exp(A_log) per head; Bm, Cm: (B, T, N) (single group).
    h_t = a_t h_{t-1} + dt_t * B_t (x_t dt already applied? no: b_t = dt_t x_t B_t).
    Returns y (B, T, H, P) fp32 and final state (B, H, P, N) fp32.
    """
    B, T, H, Pd = xh.shape
    N = Bm.shape[-1]
    Lc = min(chunk, T)
    pad = -T % Lc
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nch = (T + pad) // Lc
    xs = (xh.reshape(B, nch, Lc, H, Pd).swapaxes(0, 1),
          dt.reshape(B, nch, Lc, H).swapaxes(0, 1),
          Bm.reshape(B, nch, Lc, N).swapaxes(0, 1),
          Cm.reshape(B, nch, Lc, N).swapaxes(0, 1))
    s = jnp.zeros((B, H, Pd, N), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    @jax.checkpoint
    def body(s, xs_c):
        x_c, dt_c, B_c, C_c = (a.astype(jnp.float32) for a in xs_c)
        la = dt_c * a_log                                   # (B, Lc, H) log decay
        cum = jnp.cumsum(la, axis=1)                        # s_i
        xb = x_c * dt_c[..., None]                          # dt-weighted input
        # intra-chunk: att[i,j] = (C_i . B_j) exp(s_i - s_j) for j <= i
        att = jnp.einsum("bin,bjn->bij", C_c, B_c)[:, None] \
            * jnp.exp(cum.transpose(0, 2, 1)[..., :, None] - cum.transpose(0, 2, 1)[..., None, :])
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        att = jnp.where(tri[None, None], att, 0.0)          # (B, H, Lc, Lc)
        y = jnp.einsum("bhij,bjhp->bihp", att, xb)
        # inter-chunk: y_i += C_i . (exp(s_i) s_prev)
        y = y + jnp.einsum("bin,bhpn,bih->bihp", C_c, s, jnp.exp(cum))
        # state update: s' = exp(s_last) s + sum_j exp(s_last - s_j) B_j (x)_j
        w = jnp.exp(cum[:, -1:, :] - cum)                    # (B, Lc, H)
        s_new = s * jnp.exp(cum[:, -1])[:, :, None, None] \
            + jnp.einsum("bjn,bjhp,bjh->bhpn", B_c, xb, w)
        return s_new, y

    s, ys = lax.scan(body, s, xs)
    y = ys.swapaxes(0, 1).reshape(B, T + pad, H, Pd)[:, :T]
    return y, s


def mamba2_apply(p, u, *, cfg, state=None):
    """u: (B, T, D); Mamba2 block.  state for decode: conv + ssm (B,H,P,N)."""
    di = p["norm_w"].shape[0]
    N = cfg.d_state
    H = di // cfg.headdim
    Pd = cfg.headdim
    B, T, _ = u.shape
    proj = u @ p["in_proj"]
    z, x, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)

    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    if state is None:
        conv_state = conv_tail(xbc, cfg.d_conv)
        xbc = causal_conv(xbc, p["conv_w"], p["conv_b"])
    else:
        conv_state, xbc1 = causal_conv_step(state["conv"], xbc[:, 0], p["conv_w"], p["conv_b"])
        xbc = xbc1[:, None]
    xbc = jax.nn.silu(xbc)
    x, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    a_log = -jnp.exp(p["A_log"])                                 # (H,)
    xh = x.reshape(B, T, H, Pd)

    if state is None:
        y, s = ssd_scan(xh, dt, a_log, Bm, Cm, chunk=cfg.chunk)
        new_state = {"ssm": s, "conv": conv_state}
    else:
        a = jnp.exp(dt[:, 0] * a_log)                            # (B, H)
        xb = xh.astype(jnp.float32)[:, 0] * dt[:, 0, :, None]
        s = state["ssm"] * a[..., None, None] \
            + jnp.einsum("bn,bhp->bhpn", Bm.astype(jnp.float32)[:, 0], xb)
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32)[:, 0], s)[:, None]
        new_state = {"ssm": s, "conv": conv_state}

    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, di)
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype), p["norm_w"], 1e-5)
    return y @ p["out_proj"], new_state
