"""Shared neural-net layers: norms, RoPE, MLPs, embeddings, chunked loss.

Everything is a pure function over param pytrees (nested dicts).  Weight
init uses truncated-normal fan-in scaling.  Compute dtype is bf16 with fp32
accumulation/softmax; norms run in fp32.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: float | None = None):
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -3, 3, (d_in, d_out), jnp.float32) * std).astype(dtype)


def rmsnorm(x, w, eps: float):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., T, Dh); positions: (..., T) int32. Rotates pairs (even, odd)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.stack([out1, out2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, ff: int, kind: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, ff, dtype),
            "w_up": dense_init(ks[1], d, ff, dtype),
            "w_down": dense_init(ks[2], ff, d, dtype),
        }
    return {"w_up": dense_init(ks[0], d, ff, dtype), "w_down": dense_init(ks[1], ff, d, dtype)}


def mlp_apply(p, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        raise ValueError(kind)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Loss: chunked softmax cross-entropy (never materializes (B,T,V) at once)
# ---------------------------------------------------------------------------


@partial(jax.checkpoint, static_argnums=())
def _xent_chunk(h, w_out, targets, mask):
    logits = (h @ w_out).astype(jnp.float32)  # (B, Tc, V)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = (logz - gold) * mask
    return jnp.sum(loss), jnp.sum(mask)


def chunked_xent(h, w_out, targets, mask, n_chunks: int):
    """Mean token cross-entropy, scanning over T chunks (bwd recomputes
    per-chunk logits — remat keeps peak memory at one (B,Tc,V) tile)."""
    b, t, d = h.shape
    assert t % n_chunks == 0, (t, n_chunks)
    tc = t // n_chunks
    hs = h.reshape(b, n_chunks, tc, d).swapaxes(0, 1)
    ts = targets.reshape(b, n_chunks, tc).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunks, tc).swapaxes(0, 1)

    def body(acc, xs):
        hc, tg, mk = xs
        s, n = _xent_chunk(hc, w_out, tg, mk)
        return (acc[0] + s, acc[1] + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)
