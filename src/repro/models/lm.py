"""Unified LM covering all assigned families.

families: dense | moe | audio (enc-dec backbone) | vlm (backbone+stub
frontend) | hybrid (Mamba2 + shared attention) | ssm (pure Mamba1).

Structure doctrine:
* params are pure pytrees; per-layer params are **stacked** on a leading L
  axis and the layer stack runs under ``lax.scan`` with a ``jax.checkpoint``
  -ed body (one compiled layer body; per-layer remat).
* every hot activation gets a ``with_sharding_constraint``; params carry
  NamedSharding via ``param_specs()`` (FSDP over "data", TP over "model" —
  see models/sharding.py).
* decode caches: attention KV is **sequence-sharded over TP**
  (flash-decoding layout); SSM states are d_inner-sharded.

Entry points (all pure, all jit-able):
  ``loss(params, batch)``                       -> scalar    (train)
  ``prefill(params, batch)``                    -> (cache, logits_last)
  ``decode_step(params, cache, token, cur_len)``-> (cache, logits)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig
from repro.models.layers import (chunked_xent, dense_init, layernorm, mlp_apply,
                                 mlp_init, rmsnorm)
from repro.models.sharding import Axes


def _norm_init(cfg, d):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32)}


def _norm_apply(cfg, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    """Beyond-baseline optimizations (EXPERIMENTS.md §Perf).  Defaults are
    the paper-faithful/naive baseline; the dry-run's --opt flag enables all.

    bf16_attention       — QK^T/PV contract bf16 operands with fp32
                           accumulation instead of materializing fp32
                           copies of K/V (and, on decode, of the whole
                           cache).
    exact_causal_prefill — serving prefill uses triangular-tile attention
                           (exact causal FLOPs) instead of masked full-KV.
    remat_policy         — "full": recompute everything in backward;
                           "dots": save matmul outputs, recompute the rest
                           (jax dots_with_no_batch_dims_saveable).
    """

    bf16_attention: bool = False
    exact_causal_prefill: bool = False
    remat_policy: str = "full"
    # head-major (B, Hkv, S, dh) KV cache: decode contracts without the
    # per-layer-per-step layout transpose the (B, S, Hkv, dh) layout costs
    hmajor_cache: bool = False
    # Megatron-SP hypothesis: keep the residual stream sequence-sharded over
    # TP between blocks so activation collectives become bf16 reduce-scatter/
    # all-gather pairs instead of fp32 all-reduces (§Perf iteration 3).
    seq_sharded_residual: bool = False


OPTIMIZED = PerfFlags(bf16_attention=True, exact_causal_prefill=True,
                      remat_policy="dots", hmajor_cache=True)


class LM:
    """One model = (ArchConfig, Mesh, Axes).  Mesh may be a trivial (1,1)."""

    def __init__(self, cfg: ArchConfig, mesh: Mesh, axes: Axes, *,
                 q_block: int = 512, xent_chunks: int = 8,
                 sp_mode: str = "none", batch_sharded: bool = True,
                 perf: PerfFlags | None = None, local_mode: bool = False):
        self.cfg, self.mesh, self.axes = cfg, mesh, axes
        self.q_block, self.xent_chunks = q_block, xent_chunks
        self.sp_mode = sp_mode
        self.batch_sharded = batch_sharded
        self.perf = perf if perf is not None else PerfFlags()
        # local_mode: run as a pure per-shard function (no sharding
        # constraints, no nested shard_map) — the explicit-DP/compressed-
        # gradient path wraps the whole loss in its own shard_map.
        self.local_mode = local_mode
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.tp = mesh.shape[axes.tp]
        # vocab padded so embed/lm_head shard evenly on any production mesh
        # (MaxText-style; targets always index the true vocab prefix)
        gran = max(self.tp * mesh.shape[axes.fsdp], 1)
        self.vocab_padded = -(-cfg.vocab // gran) * gran

    # -- helpers -------------------------------------------------------------

    def cs(self, x, spec: P):
        if self.local_mode:
            return x
        return lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def _ckpt(self, f):
        if self.perf.remat_policy == "dots":
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(f)

    @property
    def head_dim(self):
        return self.cfg.resolved_head_dim

    # =========================================================================
    # Parameter init
    # =========================================================================

    def init_params(self, key):
        cfg = self.cfg
        d, dt = cfg.d_model, self.dtype
        ks = jax.random.split(key, 8)
        params = {"embed": dense_init(ks[0], self.vocab_padded, d, dt, scale=1.0),
                  "final_norm": _norm_init(cfg, d)}
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], d, self.vocab_padded, dt)

        def stacked(init_fn, n, key):
            return jax.vmap(init_fn)(jax.random.split(key, n))

        if cfg.family in ("dense", "vlm", "moe"):
            n_dense = cfg.moe.first_k_dense if cfg.moe else 0
            n_rest = cfg.n_layers - n_dense
            params["blocks"] = stacked(lambda k: self._block_init(k, moe=bool(cfg.moe)),
                                       n_rest, ks[2])
            if n_dense:
                ff0 = cfg.moe.dense_ff or cfg.d_ff
                params["dense0"] = stacked(lambda k: self._block_init(k, moe=False, ff=ff0),
                                           n_dense, ks[3])
        elif cfg.family == "audio":
            params["enc_blocks"] = stacked(lambda k: self._block_init(k, moe=False),
                                           cfg.n_encoder_layers, ks[2])
            params["enc_norm"] = _norm_init(cfg, d)
            params["dec_blocks"] = stacked(lambda k: self._block_init(k, moe=False, cross=True),
                                           cfg.n_layers, ks[3])
        elif cfg.family == "ssm":
            params["blocks"] = stacked(
                lambda k: {"ln": _norm_init(cfg, d),
                           "mamba": ssm_mod.mamba1_init(k, d, cfg.ssm, dt)},
                cfg.n_layers, ks[2])
        elif cfg.family == "hybrid":
            n_groups = cfg.n_layers // cfg.attn_every
            params["blocks"] = stacked(
                lambda k: jax.vmap(lambda k2: {
                    "ln": _norm_init(cfg, d),
                    "mamba": ssm_mod.mamba2_init(k2, d, cfg.ssm, dt)})(
                        jax.random.split(k, cfg.attn_every)),
                n_groups, ks[2])
            # ONE shared attention+MLP block (zamba2), input = concat(x, emb0)
            kk = jax.random.split(ks[3], 4)
            params["shared"] = {
                "w_in": dense_init(kk[0], 2 * d, d, dt),
                "ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d),
                "attn": attn.gqa_init(kk[1], d, cfg.n_heads, cfg.n_kv_heads,
                                      self.head_dim, qkv_bias=cfg.qkv_bias, dtype=dt),
                "mlp": mlp_init(kk[2], d, cfg.d_ff, cfg.mlp, dt),
            }
        else:
            raise ValueError(cfg.family)
        return params

    def _block_init(self, key, *, moe: bool, ff: int | None = None, cross: bool = False):
        cfg = self.cfg
        d, dt = cfg.d_model, self.dtype
        ks = jax.random.split(key, 6)
        if cfg.mla is not None:
            a = attn.mla_init(ks[0], d, cfg.n_heads, cfg.mla, dt)
        else:
            a = attn.gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, self.head_dim,
                              qkv_bias=cfg.qkv_bias, dtype=dt)
        p = {"ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d), "attn": a}
        if cross:
            p["ln_x"] = _norm_init(cfg, d)
            p["cross"] = attn.gqa_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                       self.head_dim, qkv_bias=False, dtype=dt)
        if moe:
            p["moe"] = moe_mod.moe_init(ks[2], d, cfg.moe, cfg.mlp, dt)
        else:
            p["mlp"] = mlp_init(ks[2], d, ff or cfg.d_ff, cfg.mlp, dt)
        return p

    def abstract_params(self):
        return jax.eval_shape(self.init_params, jax.random.PRNGKey(0))

    # =========================================================================
    # Sharding specs
    # =========================================================================

    def param_specs(self):
        ax = self.axes
        fsdp, tp = ax.fsdp, ax.tp

        def block_spec(p, stack_dims: int = 1):
            """Spec for one (stacked) block dict by leaf name and rank."""
            s = (None,) * stack_dims

            def leaf(path, x):
                name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
                r = x.ndim - stack_dims
                col = P(*s, fsdp, tp)
                row = P(*s, tp, fsdp)
                repl_in = P(*s, fsdp, None)
                if name in ("wq", "w_gate", "w_up", "in_proj"):
                    return col
                if name in ("wo", "w_down", "out_proj", "dt_proj"):
                    return row
                if name in ("wk", "wv", "w_dkv", "x_proj"):
                    # kv-head / latent dims stay unsharded on TP (kv < tp)
                    return repl_in if r == 2 else P(*s, None)
                if name in ("w_uk", "w_uv"):
                    return P(*s, None, tp)
                if name == "router":
                    return repl_in
                if name in ("bq", "w_in"):
                    return P(*s, fsdp, tp) if r == 2 else P(*s, tp)
                if name in ("A_log", "D", "conv_w", "conv_b", "dt_bias", "norm_w"):
                    return P(*s, *(None,) * r)
                if name in ("w", "b", "kv_norm", "bk", "bv"):
                    return P(*s, *(None,) * r)
                return P(*s, *(None,) * r)

            return jax.tree_util.tree_map_with_path(leaf, p)

        aparams = self.abstract_params()
        specs = {}
        for k, v in aparams.items():
            if k == "embed":
                specs[k] = P(tp, fsdp)
            elif k == "lm_head":
                specs[k] = P(fsdp, tp)
            elif k in ("final_norm", "enc_norm"):
                specs[k] = jax.tree.map(lambda _: P(), v)
            elif k == "shared":
                specs[k] = block_spec(v, stack_dims=0)
            elif k == "blocks" and self.cfg.family == "hybrid":
                specs[k] = block_spec(v, stack_dims=2)
            else:  # blocks / dense0 / enc_blocks / dec_blocks
                sp = block_spec(v, stack_dims=1)
                if self.cfg.family == "moe" and k == "blocks":
                    # expert-stacked weights: (L, E, D, F) -> experts on TP
                    for name in ("w_gate", "w_up", "w_down"):
                        if name in sp["moe"]:
                            sp["moe"][name] = P(None, tp, fsdp, None)
                specs[k] = sp
        return specs

    def param_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), self.param_specs(),
                            is_leaf=lambda x: isinstance(x, P))

    # =========================================================================
    # Forward (train)
    # =========================================================================

    def _embed(self, params, tokens):
        e = jnp.take(params["embed"], tokens, axis=0)
        return e.astype(self.dtype)

    def _logits_loss(self, params, h, targets, mask):
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        h = _norm_apply(self.cfg, params["final_norm"], h)
        return chunked_xent(h, w, targets, mask, self.xent_chunks)

    def _attn_block(self, p, x, positions, *, causal=True, kv=None):
        """Pre-norm attention sub-block (GQA or MLA).  kv: cross-attn source."""
        cfg = self.cfg
        h = _norm_apply(cfg, p["ln1"] if kv is None else p["ln_x"], x)
        if cfg.mla is not None and kv is None:
            ap = p["attn"]
            return x + attn.mla_attention_train(
                ap, h, n_heads=cfg.n_heads, mla=cfg.mla, positions=positions,
                rope_theta=cfg.rope_theta, q_block=self.q_block,
                bf16_compute=self.perf.bf16_attention)
        ap = p["attn"] if kv is None else p["cross"]
        if kv is None:
            q, k, v = attn.gqa_qkv(ap, h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                   head_dim=self.head_dim, positions=positions,
                                   rope_theta=cfg.rope_theta)
        else:
            qkv = attn.gqa_qkv(ap, h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                               head_dim=self.head_dim, positions=positions,
                               rope_theta=cfg.rope_theta)
            q = qkv[0]
            kv_pos = jnp.broadcast_to(jnp.arange(kv.shape[1], dtype=jnp.int32),
                                      kv.shape[:2])
            _, k, v = attn.gqa_qkv(ap, kv, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                   head_dim=self.head_dim, positions=kv_pos,
                                   rope_theta=cfg.rope_theta)
        if self.sp_mode == "ulysses" and kv is None and cfg.n_heads % self.tp == 0:
            o = attn.ulysses_attention(q, k, v, self.mesh, tp_axis=self.axes.tp,
                                       causal=causal, q_block=self.q_block)
        else:
            q = self.cs(q, self.axes.act_heads())
            o = attn.blockwise_attention(q, k, v, causal=causal, q_block=self.q_block,
                                         bf16_compute=self.perf.bf16_attention)
        B, S = x.shape[:2]
        return x + o.reshape(B, S, -1) @ (p["attn"] if kv is None else p["cross"])["wo"]

    def _ffn_block(self, p, x, *, use_moe: bool, decode: bool = False):
        cfg = self.cfg
        h = _norm_apply(cfg, p["ln2"], x)
        if not use_moe:
            return x + mlp_apply(p["mlp"], h, cfg.mlp), 0.0, 0.0
        if self.local_mode:
            y, aux, z = moe_mod.moe_apply_dense(p["moe"], h, cfg=cfg.moe,
                                                mlp_kind=cfg.mlp)
            return x + y, aux, z
        S = h.shape[1]
        fn = moe_mod.moe_apply_local if (decode or S % self.tp != 0 or S < self.tp) \
            else moe_mod.moe_apply_a2a
        y, aux, z = fn(p["moe"], h, self.mesh, cfg=cfg.moe, mlp_kind=cfg.mlp,
                       dp_axes=self.axes.dp, ep_axis=self.axes.tp,
                       batch_sharded=self.batch_sharded)
        return x + y, aux, z

    def _decoder_stack(self, params, x, positions, *, enc_out=None):
        """Scan the (dense/moe/audio-decoder) layer stack over x."""
        cfg = self.cfg
        use_moe = cfg.moe is not None
        bspec = self.axes.act_btd() if self.batch_sharded else P(None, None, None)

        def layer(x, p):
            x = self.cs(x, bspec)
            x = self._attn_block(p, x, positions, causal=True)
            if enc_out is not None:
                x = self._attn_block(p, x, positions, kv=enc_out)
            x, aux, z = self._ffn_block(p, x, use_moe=use_moe)
            if self.perf.seq_sharded_residual and self.batch_sharded:
                x = self.cs(x, self.axes.act_btd_sp())
            return x, (aux, z)

        if "dense0" in params:
            def layer0(x, p):
                x = self.cs(x, bspec)
                x = self._attn_block(p, x, positions, causal=True)
                x, _, _ = self._ffn_block(p, x, use_moe=False)
                return x, (0.0, 0.0)
            x, _ = lax.scan(self._ckpt(layer0), x,
                            params["dense0"])
        blocks = params["dec_blocks"] if cfg.family == "audio" else params["blocks"]
        x, (auxs, zs) = lax.scan(self._ckpt(layer), x, blocks)
        return x, jnp.sum(jnp.asarray(auxs)), jnp.sum(jnp.asarray(zs))

    def _ssm_stack(self, params, x):
        def layer(x, p):
            x = self.cs(x, self.axes.act_btd() if self.batch_sharded else P())
            h = _norm_apply(self.cfg, p["ln"], x)
            y, _ = ssm_mod.mamba1_apply(p["mamba"], h, cfg=self.cfg.ssm)
            return x + y, None
        x, _ = lax.scan(self._ckpt(layer), x, params["blocks"])
        return x

    def _hybrid_stack(self, params, x, x0, positions):
        cfg = self.cfg
        shared = params["shared"]

        def group(x, p):
            x = self.cs(x, self.axes.act_btd() if self.batch_sharded else P())
            # shared attention block on concat(x, emb0)
            xin = jnp.concatenate([x, x0], axis=-1) @ shared["w_in"]
            xin = self._attn_block(shared, xin, positions, causal=True)
            xin, _, _ = self._ffn_block(shared, xin, use_moe=False)
            x = x + xin

            def mlayer(x, pl):
                h = _norm_apply(cfg, pl["ln"], x)
                y, _ = ssm_mod.mamba2_apply(pl["mamba"], h, cfg=cfg.ssm)
                return x + y, None
            x, _ = lax.scan(self._ckpt(mlayer), x, p)
            return x, None

        x, _ = lax.scan(group, x, params["blocks"])
        return x

    def loss(self, params, batch):
        """batch: tokens (B,S) int32, targets (B,S), mask (B,S) f32,
        optional frontend (B,F,D) [vlm: prepended; audio: encoder input]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        aux = z = 0.0

        if cfg.family == "vlm":
            fe = batch["frontend"].astype(self.dtype)
            Fk = fe.shape[1]
            x = jnp.concatenate([fe, x], axis=1)
            positions = jnp.broadcast_to(jnp.arange(Fk + S, dtype=jnp.int32), (B, Fk + S))
            x, aux, z = self._decoder_stack(params, x, positions)
            x = x[:, Fk:]
        elif cfg.family == "audio":
            enc = batch["frontend"].astype(self.dtype)
            Se = enc.shape[1]
            epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

            def enc_layer(h, p):
                h = self.cs(h, self.axes.act_btd() if self.batch_sharded else P(None, None, None))
                h = self._attn_block(p, h, epos, causal=False)
                h, _, _ = self._ffn_block(p, h, use_moe=False)
                return h, None
            enc, _ = lax.scan(self._ckpt(enc_layer), enc, params["enc_blocks"])
            enc = _norm_apply(cfg, params["enc_norm"], enc)
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            x, aux, z = self._decoder_stack(params, x, positions, enc_out=enc)
        elif cfg.family == "ssm":
            x = self._ssm_stack(params, x)
        elif cfg.family == "hybrid":
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            x = self._hybrid_stack(params, x, x, positions)
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            x, aux, z = self._decoder_stack(params, x, positions)

        xent = self._logits_loss(params, x, batch["targets"], batch["mask"])
        total = xent
        if cfg.moe is not None:
            total = total + cfg.moe.aux_coef * aux + cfg.moe.zloss_coef * z
        return total, {"xent": xent, "aux": aux}

    # =========================================================================
    # Serving: prefill + decode (KV cache seq-sharded over TP)
    # =========================================================================

    def _last_logits(self, params, x):
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        h = _norm_apply(self.cfg, params["final_norm"], x[:, -1:])
        return (h @ w).astype(jnp.float32)

    def _serving_causal(self, q, k, v):
        if self.perf.exact_causal_prefill:
            return attn.triangular_causal_attention(
                q, k, v, q_block=self.q_block,
                bf16_compute=self.perf.bf16_attention)
        return attn.blockwise_attention(q, k, v, causal=True, q_block=self.q_block,
                                        bf16_compute=self.perf.bf16_attention)

    def _cache_layout(self, kv, M: int):
        """(B, S, Hkv, dh) -> padded cache in the configured layout."""
        if self.perf.hmajor_cache:
            kv = kv.transpose(0, 2, 1, 3)          # (B, Hkv, S, dh)
            pads = [(0, 0)] * 4
            pads[2] = (0, M - kv.shape[2])
            return jnp.pad(kv, pads) if pads[2][1] else kv
        return _pad_seq(kv, M)

    def _attn_prefill(self, p, x, positions, M: int):
        """Attention sub-block that also emits its padded-to-M KV cache."""
        cfg = self.cfg
        h = _norm_apply(cfg, p["ln1"], x)
        B, S = x.shape[:2]
        if cfg.mla is not None:
            ap = p["attn"]
            ckv, krope = attn.mla_latents(ap, h, mla=cfg.mla, positions=positions,
                                          rope_theta=cfg.rope_theta)
            qn, qr = attn.mla_queries(ap, h, n_heads=cfg.n_heads, mla=cfg.mla,
                                      positions=positions, rope_theta=cfg.rope_theta)
            k, v = attn.mla_expand_kv(ap, ckv, krope, n_heads=cfg.n_heads, mla=cfg.mla)
            q = jnp.concatenate([qn, qr], -1)
            o = self._serving_causal(q, k, v)
            x = x + o.reshape(B, S, -1) @ ap["wo"]
            cache = {"ckv": _pad_seq(ckv, M), "krope": _pad_seq(krope[:, :, 0], M)}
            return x, cache
        ap = p["attn"]
        q, k, v = attn.gqa_qkv(ap, h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                               head_dim=self.head_dim, positions=positions,
                               rope_theta=cfg.rope_theta)
        q = self.cs(q, self.axes.act_heads())
        o = self._serving_causal(q, k, v)
        x = x + o.reshape(B, S, -1) @ ap["wo"]
        return x, {"k": self._cache_layout(k, M), "v": self._cache_layout(v, M)}

    def _attn_decode(self, p, x, cache, cur_len, *, absorbed: bool = True):
        """One-token attention against a cache; returns (x, new_cache)."""
        cfg = self.cfg
        B = x.shape[0]
        h = _norm_apply(cfg, p["ln1"], x)
        pos = jnp.broadcast_to(cur_len.astype(jnp.int32), (B, 1))
        if cfg.mla is not None:
            ap = p["attn"]
            ckv_new, krope_new = attn.mla_latents(ap, h, mla=cfg.mla, positions=pos,
                                                  rope_theta=cfg.rope_theta)
            ckv = lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, cur_len, axis=1)
            krope = lax.dynamic_update_slice_in_dim(cache["krope"], krope_new[:, :, 0],
                                                    cur_len, axis=1)
            if absorbed:
                o = attn.mla_decode_absorbed(ap, h, ckv, krope, cur_len + 1,
                                             n_heads=cfg.n_heads, mla=cfg.mla,
                                             positions=pos, rope_theta=cfg.rope_theta,
                                             bf16_compute=self.perf.bf16_attention)
                return x + o, {"ckv": ckv, "krope": krope}
            k, v = attn.mla_expand_kv(ap, ckv, krope[:, :, None], n_heads=cfg.n_heads,
                                      mla=cfg.mla)
            qn, qr = attn.mla_queries(ap, h, n_heads=cfg.n_heads, mla=cfg.mla,
                                      positions=pos, rope_theta=cfg.rope_theta)
            q = jnp.concatenate([qn, qr], -1)
            o = attn.decode_attention(q, k, v, cur_len + 1,
                                      bf16_compute=self.perf.bf16_attention)
            return x + o.reshape(B, 1, -1) @ ap["wo"], {"ckv": ckv, "krope": krope}
        ap = p["attn"]
        q, k_new, v_new = attn.gqa_qkv(ap, h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                       head_dim=self.head_dim, positions=pos,
                                       rope_theta=cfg.rope_theta)
        if self.perf.hmajor_cache:
            k = lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.transpose(0, 2, 1, 3), cur_len, axis=2)
            v = lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.transpose(0, 2, 1, 3), cur_len, axis=2)
            layout = "bhsd"
        else:
            k = lax.dynamic_update_slice_in_dim(cache["k"], k_new, cur_len, axis=1)
            v = lax.dynamic_update_slice_in_dim(cache["v"], v_new, cur_len, axis=1)
            layout = "bskd"
        o = attn.decode_attention(q, k, v, cur_len + 1, layout=layout,
                                  bf16_compute=self.perf.bf16_attention)
        return x + o.reshape(B, 1, -1) @ ap["wo"], {"k": k, "v": v}

    def prefill(self, params, batch, *, max_len: int | None = None):
        """Process a full prompt; returns (cache, last-token fp32 logits).

        batch: tokens (B, S); vlm adds frontend (B,F,D); audio uses frontend
        as the encoder input.  Cache seq capacity = max_len or S(+F).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        cache = {}

        if cfg.family == "vlm":
            fe = batch["frontend"].astype(self.dtype)
            Fk = fe.shape[1]
            x = jnp.concatenate([fe, x], axis=1)
            S = S + Fk
        M = max_len or S
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        bspec = self.axes.act_btd() if self.batch_sharded else P(None, None, None)

        if cfg.family == "ssm":
            def layer(x, p):
                x = self.cs(x, bspec)
                h = _norm_apply(cfg, p["ln"], x)
                y, st = ssm_mod.mamba1_apply(p["mamba"], h, cfg=cfg.ssm)
                return x + y, st
            x, states = lax.scan(self._ckpt(layer), x, params["blocks"])
            cache = states
        elif cfg.family == "hybrid":
            x, cache = self._hybrid_prefill(params, x, positions, M)
        elif cfg.family == "audio":
            enc = batch["frontend"].astype(self.dtype)
            Se = enc.shape[1]
            epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

            def enc_layer(h, p):
                h = self.cs(h, bspec)
                h = self._attn_block(p, h, epos, causal=False)
                h, _, _ = self._ffn_block(p, h, use_moe=False)
                return h, None
            enc, _ = lax.scan(self._ckpt(enc_layer), enc, params["enc_blocks"])
            enc = _norm_apply(cfg, params["enc_norm"], enc)

            def dec_layer(x, p):
                x = self.cs(x, bspec)
                x, kv = self._attn_prefill(p, x, positions, M)
                xh = _norm_apply(cfg, p["ln_x"], x)
                _, ck, cv = attn.gqa_qkv(p["cross"], enc, n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv_heads, head_dim=self.head_dim,
                                         positions=epos, rope_theta=cfg.rope_theta)
                ckl = self._cache_layout(ck, ck.shape[1])
                cvl = self._cache_layout(cv, cv.shape[1])
                qx, _, _ = attn.gqa_qkv(p["cross"], xh, n_heads=cfg.n_heads,
                                        n_kv=cfg.n_kv_heads, head_dim=self.head_dim,
                                        positions=positions, rope_theta=cfg.rope_theta)
                ox = attn.blockwise_attention(qx, ck, cv, causal=False, q_block=self.q_block)
                x = x + ox.reshape(B, S, -1) @ p["cross"]["wo"]
                x, _, _ = self._ffn_block(p, x, use_moe=False)
                return x, {**kv, "ck": ckl, "cv": cvl}
            x, cache = lax.scan(self._ckpt(dec_layer), x, params["dec_blocks"])
        else:
            use_moe = cfg.moe is not None

            def layer(x, p, moe_here: bool):
                x = self.cs(x, bspec)
                x, kv = self._attn_prefill(p, x, positions, M)
                x, _, _ = self._ffn_block(p, x, use_moe=moe_here)
                return x, kv
            if "dense0" in params:
                x, kv0 = lax.scan(self._ckpt(partial(layer, moe_here=False)),
                                  x, params["dense0"])
                cache["dense0"] = kv0
            x, kv = lax.scan(self._ckpt(partial(layer, moe_here=use_moe)),
                             x, params["blocks"])
            cache["blocks"] = kv
        return cache, self._last_logits(params, x)

    def _hybrid_prefill(self, params, x, positions, M):
        cfg = self.cfg
        shared = params["shared"]
        x0 = x
        B, S = x.shape[:2]

        def group(x, p):
            xin = jnp.concatenate([x, x0], axis=-1) @ shared["w_in"]
            xin, kv = self._attn_prefill(shared, xin, positions, M)
            xin, _, _ = self._ffn_block(shared, xin, use_moe=False)
            x = x + xin

            def mlayer(x, pl):
                h = _norm_apply(cfg, pl["ln"], x)
                y, st = ssm_mod.mamba2_apply(pl["mamba"], h, cfg=cfg.ssm)
                return x + y, st
            x, states = lax.scan(self._ckpt(mlayer), x, p)
            return x, {**kv, "states": states}
        x, cache = lax.scan(group, x, params["blocks"])
        return x, cache

    def decode_step(self, params, cache, token, cur_len):
        """token: (B,) int32; cur_len: scalar int32 (current cache length).
        Returns (new_cache, fp32 logits (B, vocab))."""
        cfg = self.cfg
        B = token.shape[0]
        x = self._embed(params, token[:, None])
        cur_len = jnp.asarray(cur_len, jnp.int32)

        if cfg.family == "ssm":
            def layer(x, xs):
                p, st = xs
                h = _norm_apply(cfg, p["ln"], x)
                y, st2 = ssm_mod.mamba1_apply(p["mamba"], h, cfg=cfg.ssm, state=st)
                return x + y, st2
            x, cache = lax.scan(layer, x, (params["blocks"], cache))
        elif cfg.family == "hybrid":
            shared = params["shared"]
            x0 = x

            def group(x, xs):
                p, c = xs
                xin = jnp.concatenate([x, x0], axis=-1) @ shared["w_in"]
                kvc = {k: c[k] for k in c if k != "states"}
                xin, kv = self._attn_decode(shared, xin, kvc, cur_len)
                xin, _, _ = self._ffn_block(shared, xin, use_moe=False, decode=True)
                x = x + xin

                def mlayer(x, xs2):
                    pl, st = xs2
                    h = _norm_apply(cfg, pl["ln"], x)
                    y, st2 = ssm_mod.mamba2_apply(pl["mamba"], h, cfg=cfg.ssm, state=st)
                    return x + y, st2
                x, states = lax.scan(mlayer, x, (p, c["states"]))
                return x, {**kv, "states": states}
            x, cache = lax.scan(group, x, (params["blocks"], cache))
        elif cfg.family == "audio":
            def dec_layer(x, xs):
                p, c = xs
                kvc = {k: c[k] for k in ("k", "v")}
                x, kv = self._attn_decode(p, x, kvc, cur_len)
                h = _norm_apply(cfg, p["ln_x"], x)
                pos = jnp.broadcast_to(cur_len, (B, 1))
                qx, _, _ = attn.gqa_qkv(p["cross"], h, n_heads=cfg.n_heads,
                                        n_kv=cfg.n_kv_heads, head_dim=self.head_dim,
                                        positions=pos, rope_theta=cfg.rope_theta)
                layout = "bhsd" if self.perf.hmajor_cache else "bskd"
                clen = c["ck"].shape[2] if self.perf.hmajor_cache else c["ck"].shape[1]
                ox = attn.decode_attention(qx, c["ck"], c["cv"], clen, layout=layout,
                                           bf16_compute=self.perf.bf16_attention)
                x = x + ox.reshape(B, 1, -1) @ p["cross"]["wo"]
                x, _, _ = self._ffn_block(p, x, use_moe=False, decode=True)
                return x, {**kv, "ck": c["ck"], "cv": c["cv"]}
            x, cache = lax.scan(dec_layer, x, (params["dec_blocks"], cache))
        else:
            use_moe = cfg.moe is not None
            new_cache = {}

            def layer(x, xs, moe_here: bool):
                p, c = xs
                x, kv = self._attn_decode(p, x, c, cur_len)
                x, _, _ = self._ffn_block(p, x, use_moe=moe_here, decode=True)
                return x, kv
            if "dense0" in params:
                x, kv0 = lax.scan(partial(layer, moe_here=False),
                                  x, (params["dense0"], cache["dense0"]))
                new_cache["dense0"] = kv0
            x, kv = lax.scan(partial(layer, moe_here=use_moe),
                             x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = kv
            cache = new_cache
        return cache, self._last_logits(params, x)[:, 0]

    # -- cache structure ------------------------------------------------------

    def cache_specs(self, cache_abstract):
        """PartitionSpec tree for a cache pytree (by leaf name + rank)."""
        ax = self.axes
        bspec = ax.dp if self.batch_sharded else None

        def leaf(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            lead = x.ndim - 4  # stacked layer dims before (B, M, ..., ...)
            if name in ("k", "v", "ck", "cv"):
                if self.perf.hmajor_cache:  # (..., B, Hkv, S, dh): shard seq
                    return P(*(None,) * lead, bspec, None, ax.tp, None)
                return P(*(None,) * lead, bspec, ax.tp, None, None)
            if name == "ckv":
                return P(*(None,) * (x.ndim - 3), bspec, ax.tp, None)
            if name == "krope":
                return P(*(None,) * (x.ndim - 3), bspec, ax.tp, None)
            if name == "ssm":
                # mamba1: (..., B, Di, N); mamba2: (..., B, H, P, N)
                if self.cfg.ssm is not None and self.cfg.ssm.kind == "mamba2":
                    return P(*(None,) * (x.ndim - 4), bspec, ax.tp, None, None)
                return P(*(None,) * (x.ndim - 3), bspec, ax.tp, None)
            if name == "conv":
                return P(*(None,) * (x.ndim - 3), bspec, None, ax.tp)
            return P(*(None,) * x.ndim)

        return jax.tree_util.tree_map_with_path(leaf, cache_abstract)


def _pad_seq(x, M: int):
    """Pad axis 1 (seq) of (B, S, ...) up to M."""
    S = x.shape[1]
    if S == M:
        return x
    pads = [(0, 0)] * x.ndim
    pads[1] = (0, M - S)
    return jnp.pad(x, pads)
