"""Attention: GQA + RoPE, MLA (DeepSeek-V2), blockwise-causal train path,
sequence-sharded-cache decode path, optional Ulysses sequence parallelism.

Memory doctrine (CPU container lowers the *full* configs, so this must be
structurally sound at 32k sequence):

* Train/prefill attention is **blockwise over query blocks**: a
  ``lax.scan`` over q-blocks whose body is ``jax.checkpoint``-ed, so peak
  live memory is one (B, q_block, H, S) score tile and backward recomputes
  per-block.  Q-blocks are independent — no cross-step carry, so remat
  costs only one extra forward of each block.
* The masked full-KV contraction per q-block computes ~2x the causal
  minimum FLOPs; the Pallas flash kernel (kernels/flash) with true
  triangular block skip is the optimized path (§Perf).
* Decode attends a (B, S_cache, Hkv, dh) cache whose **sequence axis is
  TP-sharded** (flash-decoding layout).  Softmax over the sharded axis is
  expressed in plain jnp; GSPMD lowers the max/sum/PV reductions to
  all-reduces over the model axis — the collective-fused analogue of the
  paper's "let the communication layer do the rearrangement".

Ulysses SP (``ulysses_attention``) is the paper's v->w exchange applied to
attention: seq-sharded activations are redistributed to head-sharded via one
fused ``lax.all_to_all`` (split heads / concat sequence) and back — the same
primitive as ``repro.core.redistribute.exchange_shard``.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_rope

_NEG_INF = -1e30


def _dots(q_like, k_like, eq, *, bf16_compute: bool):
    """Score/PV contraction helper: baseline casts operands to fp32
    (materializes fp32 copies — visible in the HLO traffic); the optimized
    path keeps operands bf16 and accumulates in fp32 on the MXU
    (preferred_element_type), which is the TPU-native mixed precision."""
    if bf16_compute:
        return jnp.einsum(eq, q_like, k_like, preferred_element_type=jnp.float32)
    return jnp.einsum(eq, q_like.astype(jnp.float32), k_like.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Blockwise attention (train / prefill)
# ---------------------------------------------------------------------------


def blockwise_attention(
    q: jax.Array,  # (B, Sq, Hq, dh)
    k: jax.Array,  # (B, Skv, Hkv, dh)
    v: jax.Array,  # (B, Skv, Hkv, dv)
    *,
    causal: bool,
    q_block: int = 512,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,  # optional valid-prefix length of k/v
    bf16_compute: bool = False,
) -> jax.Array:
    """Numerically-safe blockwise attention; scan over q blocks, remat body.

    ``q_offset`` is the absolute position of q[0] (decode/prefill-continue).
    ``kv_len`` masks the KV suffix (padded caches).  Returns (B, Sq, Hq, dv).
    ``bf16_compute``: keep QK^T/PV operands bf16 with fp32 accumulation
    (optimized path; baseline materializes fp32 copies).
    """
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, dv = v.shape[0], v.shape[1], v.shape[2], v.shape[3]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    qb = min(q_block, Sq)
    if Sq % qb != 0:  # pad q to a block multiple (logits for pads discarded)
        pad = -Sq % qb
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = blockwise_attention(q, k, v, causal=causal, q_block=qb,
                                  q_offset=q_offset, kv_len=kv_len,
                                  bf16_compute=bf16_compute)
        return out[:, :Sq]
    nq = Sq // qb
    scale = 1.0 / math.sqrt(dh)
    kv_pos = jnp.arange(Skv)

    qs = jnp.moveaxis(q.reshape(B, nq, qb, Hkv, G, dh), 1, 0)  # (nq,B,qb,Hkv,G,dh)

    @jax.checkpoint
    def body(_, xs):
        qi, i = xs
        # scores: (B, Hkv, G, qb, Skv), fp32 accumulation either way
        s = _dots((qi * scale).astype(qi.dtype), k, "bqhgd,bkhd->bhgqk",
                  bf16_compute=bf16_compute)
        mask = jnp.ones((qb, Skv), dtype=bool)
        if causal:
            q_pos = q_offset + i * qb + jnp.arange(qb)
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - jax.lax.stop_gradient(m))
        l = jnp.sum(p, axis=-1, keepdims=True)
        p = p / jnp.maximum(l, 1e-30)
        if bf16_compute:
            p = p.astype(v.dtype)
        o = _dots(p, v, "bhgqk,bkhd->bqhgd", bf16_compute=bf16_compute)
        return (), o.reshape(B, qb, Hq, dv).astype(v.dtype)

    _, outs = lax.scan(body, (), (qs, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, dv)


def decode_attention(
    q: jax.Array,        # (B, 1, Hq, dh)
    k_cache: jax.Array,  # (B, S_cache, Hkv, dh)  — seq axis may be TP-sharded
    v_cache: jax.Array,  # (B, S_cache, Hkv, dv)
    cur_len: jax.Array,  # valid cache length (scalar int32)
    *,
    bf16_compute: bool = False,
    layout: str = "bskd",  # "bskd" (B,S,Hkv,dh) | "bhsd" (B,Hkv,S,dh)
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) cache.

    Plain-jnp online-softmax form: GSPMD turns the max/sum/PV contractions
    over the sharded seq axis into all-reduces over the model axis, which is
    exactly the flash-decoding partial-merge schedule.  ``bf16_compute``
    avoids materializing an fp32 copy of the whole cache (§Perf).
    """
    B, _, Hq, dh = q.shape
    hmajor = layout == "bhsd"
    Hkv = k_cache.shape[1] if hmajor else k_cache.shape[2]
    S_cache = k_cache.shape[2] if hmajor else k_cache.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    qq = (q.reshape(B, 1, Hkv, G, dh) * scale).astype(q.dtype)
    # head-major (B, Hkv, S, dh) caches contract without a layout copy —
    # the bshd layout costs a materialized (B, Hkv, dh, S) transpose per
    # layer per step (§Perf: llava decode_32k iteration 2)
    k_eq = "bqhgd,bhkd->bhgqk" if hmajor else "bqhgd,bkhd->bhgqk"
    v_eq = "bhgqk,bhkd->bqhgd" if hmajor else "bhgqk,bkhd->bqhgd"
    s = _dots(qq, k_cache, k_eq, bf16_compute=bf16_compute)
    mask = jnp.arange(S_cache)[None, None, None, None, :] < cur_len
    s = jnp.where(mask, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / l
    if bf16_compute:
        p = p.astype(v_cache.dtype)
    o = _dots(p, v_cache, v_eq, bf16_compute=bf16_compute)
    return o.reshape(B, 1, Hq, -1).astype(v_cache.dtype)


def triangular_causal_attention(
    q: jax.Array,  # (B, S, Hq, dh)
    k: jax.Array,  # (B, S, Hkv, dh)
    v: jax.Array,  # (B, S, Hkv, dv)
    *,
    q_block: int = 512,
    bf16_compute: bool = True,
) -> jax.Array:
    """Exact-FLOPs causal attention: only the nq(nq+1)/2 lower-triangular
    (q-block, kv-block) tiles are contracted, vs blockwise_attention's
    masked full-KV rectangles (~2x the causal minimum at large S).

    Forward-only by design (the scan carries the output accumulator, which
    is hostile to reverse-mode remat) — used on the *serving* prefill path
    where there is no backward.  This is the XLA-expressible analogue of a
    Pallas flash kernel's ``pl.when`` triangular block skip (§Perf).
    """
    B, S, Hq, dh = q.shape
    Hkv, dv = k.shape[2], v.shape[3]
    G = Hq // Hkv
    qb = min(q_block, S)
    pad = -S % qb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nq = Sp // qb
    scale = 1.0 / math.sqrt(dh)
    qs = (q.reshape(B, nq, qb, Hkv, G, dh) * scale).astype(q.dtype)
    ks = k.reshape(B, nq, qb, Hkv, dh)
    vs = v.reshape(B, nq, qb, Hkv, dv)
    # triangular tile list (static)
    import numpy as _np
    pi = _np.concatenate([_np.full(i + 1, i) for i in range(nq)]).astype(_np.int32)
    pj = _np.concatenate([_np.arange(i + 1) for i in range(nq)]).astype(_np.int32)

    o0 = jnp.zeros((B, nq, qb, Hkv, G, dv), jnp.float32)
    m0 = jnp.full((B, nq, qb, Hkv, G), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, qb, Hkv, G), jnp.float32)
    pos = jnp.arange(qb)

    def body(carry, ij):
        o, mstat, lstat = carry
        i, j = ij
        qi = lax.dynamic_index_in_dim(qs, i, axis=1, keepdims=False)
        kj = lax.dynamic_index_in_dim(ks, j, axis=1, keepdims=False)
        vj = lax.dynamic_index_in_dim(vs, j, axis=1, keepdims=False)
        s = _dots(qi, kj, "bqhgd,bkhd->bqhgk", bf16_compute=bf16_compute)
        diag = i == j
        mask = jnp.where(diag, pos[:, None] >= pos[None, :], True)
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        m_old = lax.dynamic_index_in_dim(mstat, i, axis=1, keepdims=False)
        l_old = lax.dynamic_index_in_dim(lstat, i, axis=1, keepdims=False)
        o_old = lax.dynamic_index_in_dim(o, i, axis=1, keepdims=False)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_old - m_new)
        l_new = l_old * alpha + jnp.sum(p, axis=-1)
        if bf16_compute:
            p = p.astype(v.dtype)
        pv = _dots(p, vj, "bqhgk,bkhd->bqhgd", bf16_compute=bf16_compute)
        o_new = o_old * alpha[..., None] + pv
        upd = lambda buf, val: lax.dynamic_update_index_in_dim(buf, val, i, axis=1)
        return (upd(o, o_new), upd(mstat, m_new), upd(lstat, l_new)), None

    (o, _, l), _ = lax.scan(body, (o0, m0, l0),
                            (jnp.asarray(pi), jnp.asarray(pj)))
    out = (o / jnp.maximum(l[..., None], 1e-30)).reshape(B, Sp, Hq, dv)
    return out[:, :S].astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA projections
# ---------------------------------------------------------------------------


def gqa_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, *,
             qkv_bias: bool, dtype=jnp.bfloat16):
    from repro.models.layers import dense_init

    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def gqa_qkv(p, x, *, n_heads: int, n_kv: int, head_dim: int,
            positions, rope_theta: float):
    """Project + RoPE.  x: (B, S, D) -> q (B,S,Hq,dh), k/v (B,S,Hkv,dh)."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None], rope_theta).swapaxes(1, 2)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None], rope_theta).swapaxes(1, 2)
    return q, k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key, d: int, n_heads: int, mla, dtype=jnp.bfloat16):
    from repro.models.layers import dense_init

    ks = jax.random.split(key, 5)
    dn, dr, r, dv = mla.qk_nope_dim, mla.qk_rope_dim, mla.kv_lora_rank, mla.v_head_dim
    return {
        "wq": dense_init(ks[0], d, n_heads * (dn + dr), dtype),
        "w_dkv": dense_init(ks[1], d, r + dr, dtype),
        "kv_norm": jnp.ones((r,), jnp.float32),
        "w_uk": dense_init(ks[2], r, n_heads * dn, dtype),
        "w_uv": dense_init(ks[3], r, n_heads * dv, dtype),
        "wo": dense_init(ks[4], n_heads * dv, d, dtype),
    }


def mla_latents(p, x, *, mla, positions, rope_theta: float):
    """x -> (c_kv, k_rope): the compressed KV (what MLA caches)."""
    from repro.models.layers import rmsnorm

    dr, r = mla.qk_rope_dim, mla.kv_lora_rank
    a = x @ p["w_dkv"]  # (B, S, r + dr)
    c_kv = rmsnorm(a[..., :r], p["kv_norm"], 1e-6)
    k_rope = a[..., r:].reshape(*x.shape[:2], 1, dr)
    k_rope = apply_rope(k_rope.swapaxes(1, 2), positions[:, None], rope_theta).swapaxes(1, 2)
    return c_kv, k_rope


def mla_queries(p, x, *, n_heads: int, mla, positions, rope_theta: float):
    dn, dr = mla.qk_nope_dim, mla.qk_rope_dim
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None], rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def mla_expand_kv(p, c_kv, k_rope, *, n_heads: int, mla):
    """Decompress latents to per-head K (nope||rope) and V."""
    dn, dv = mla.qk_nope_dim, mla.v_head_dim
    B, S, _ = c_kv.shape
    k_nope = (c_kv.astype(p["w_uk"].dtype) @ p["w_uk"]).reshape(B, S, n_heads, dn)
    v = (c_kv.astype(p["w_uv"].dtype) @ p["w_uv"]).reshape(B, S, n_heads, dv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, n_heads, k_rope.shape[-1]))], -1)
    return k, v


def mla_attention_train(p, x, *, n_heads: int, mla, positions, rope_theta: float,
                        q_block: int = 512, bf16_compute: bool = False):
    c_kv, k_rope = mla_latents(p, x, mla=mla, positions=positions, rope_theta=rope_theta)
    q_nope, q_rope = mla_queries(p, x, n_heads=n_heads, mla=mla,
                                 positions=positions, rope_theta=rope_theta)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k, v = mla_expand_kv(p, c_kv, k_rope, n_heads=n_heads, mla=mla)
    o = blockwise_attention(q, k, v, causal=True, q_block=q_block,
                            bf16_compute=bf16_compute)
    return o.reshape(*x.shape[:2], -1) @ p["wo"]


def mla_decode_absorbed(p, x, cache_ckv, cache_krope, cur_len, *, n_heads: int,
                        mla, positions, rope_theta: float,
                        bf16_compute: bool = False):
    """Weight-absorbed MLA decode: attention runs in the latent space.

    Scores = q_nope W_uk^T c_kv + q_rope k_rope; output = (P c_kv) W_uv.
    Never expands K/V for the whole cache — the MLA serving optimization
    (cache stays (B, S, r + dr) instead of (B, S, H, dn+dr+dv)).
    """
    dn, dr, r, dv = mla.qk_nope_dim, mla.qk_rope_dim, mla.kv_lora_rank, mla.v_head_dim
    B = x.shape[0]
    q_nope, q_rope = mla_queries(p, x, n_heads=n_heads, mla=mla,
                                 positions=positions, rope_theta=rope_theta)
    # absorb: q_lat[b,1,h,r] = q_nope[b,1,h,dn] @ W_uk[r, h*dn] (per head)
    w_uk = p["w_uk"].reshape(r, n_heads, dn)
    q_lat = _dots(q_nope, w_uk, "bqhd,rhd->bqhr", bf16_compute=bf16_compute)
    scale = 1.0 / math.sqrt(dn + dr)
    if bf16_compute:
        q_lat = q_lat.astype(x.dtype)
    s = _dots(q_lat, cache_ckv, "bqhr,bkr->bhqk", bf16_compute=bf16_compute)
    s = s + _dots(q_rope, cache_krope, "bqhd,bkd->bhqk", bf16_compute=bf16_compute)
    s = s * scale
    mask = jnp.arange(cache_ckv.shape[1])[None, None, None, :] < cur_len
    s = jnp.where(mask, s, _NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    if bf16_compute:
        p_attn = p_attn.astype(x.dtype)
    o_lat = _dots(p_attn, cache_ckv, "bhqk,bkr->bqhr", bf16_compute=bf16_compute)
    w_uv = p["w_uv"].reshape(r, n_heads, dv)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat.astype(jnp.float32),
                   w_uv.astype(jnp.float32))
    return (o.reshape(B, 1, n_heads * dv).astype(x.dtype)) @ p["wo"]


# ---------------------------------------------------------------------------
# Ulysses sequence parallelism (the paper's exchange applied to attention)
# ---------------------------------------------------------------------------


def ulysses_attention(q, k, v, mesh, *, tp_axis: str, causal: bool,
                      q_block: int = 512):
    """Seq-sharded -> head-sharded -> seq-sharded via two fused all-to-alls.

    q/k/v are (B, S, H, dh) jit-level arrays whose S axis is sharded over
    ``tp_axis``.  Requires Hq % tp == 0; KV heads are replicated up to tp
    first (the standard Ulysses-GQA adaptation).  The all-to-alls are the
    identical primitive to ``repro.core.redistribute.exchange_shard`` —
    the paper's fused redistribution reused verbatim (DESIGN.md §3).
    """
    tp = mesh.shape[tp_axis]
    Hq, Hkv = q.shape[2], k.shape[2]
    if Hq % tp != 0:
        raise ValueError(f"ulysses needs heads {Hq} % tp {tp} == 0")
    if Hkv % tp != 0:  # replicate kv heads up to tp
        rep = -(-tp // Hkv)
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    def inner(ql, kl, vl):
        # (B, S/tp, H, dh) -> (B, S, H/tp, dh): split heads, concat seq
        a2a = partial(lax.all_to_all, axis_name=tp_axis, split_axis=2,
                      concat_axis=1, tiled=True)
        ql, kl, vl = a2a(ql), a2a(kl), a2a(vl)
        o = blockwise_attention(ql, kl, vl, causal=causal, q_block=q_block)
        return lax.all_to_all(o, tp_axis, split_axis=1, concat_axis=2, tiled=True)

    from jax.sharding import PartitionSpec as P

    spec = P(None, tp_axis, None, None)
    from repro.core.meshutil import shard_map as _shard_map

    fn = _shard_map(inner, mesh=mesh, in_specs=(spec, spec, spec),
                    out_specs=spec, check_vma=False)
    return fn(q, k, v)
