"""Architecture configuration schema covering all assigned families.

One ``ArchConfig`` describes any of: dense decoder LMs, MoE LMs (top-k,
shared experts, MLA), encoder–decoder (audio backbone), VLM backbones,
hybrid Mamba2+shared-attention, and pure-SSM models.  Concrete instances
live in ``repro/configs/<arch>.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0           # always-on shared experts (DeepSeekMoE)
    d_ff_expert: int = 0        # per-expert hidden size
    router_dtype: str = "float32"
    capacity_factor: float = 1.25
    first_k_dense: int = 0      # leading layers use a dense FFN instead
    dense_ff: int = 0           # its hidden size (0 = cfg.d_ff)
    aux_coef: float = 1e-2
    zloss_coef: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba1"        # mamba1 | mamba2
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64           # mamba2 only
    dt_rank: int = 0            # mamba1 only; 0 = ceil(d_model/16)
    chunk: int = 128            # scan chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 = d_model // n_heads
    rope_theta: float = 1e4
    qkv_bias: bool = False
    mlp: str = "swiglu"         # swiglu | relu2 | geglu | gelu
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (zamba-style): one *shared* attn+MLP block invoked every
    # ``attn_every`` layers; n_layers counts mamba layers + invocations.
    attn_every: int = 0
    encdec: bool = False        # seamless-style encoder-decoder
    n_encoder_layers: int = 0
    frontend: str | None = None  # None | audio | vision (stub embeddings)
    n_frontend_tokens: int = 0   # vision tokens prepended (anyres stub)
    dtype: str = "bfloat16"
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **kw) -> "ArchConfig":
        """Reduced-config variant for CPU smoke tests."""
        return replace(self, **kw)


def param_count(cfg: ArchConfig) -> int:
    """Total parameters (exact for our implementation; used for 6ND)."""
    d, v, L = cfg.d_model, cfg.vocab, cfg.n_layers
    hd = cfg.resolved_head_dim
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += v * d

    def attn_params() -> int:
        if cfg.mla is not None:
            m = cfg.mla
            p = d * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim)          # q
            p += d * (m.kv_lora_rank + m.qk_rope_dim)                       # kv_a
            p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)  # kv_b
            p += m.kv_lora_rank                                             # kv_a norm
            p += cfg.n_heads * m.v_head_dim * d                             # o
            return p
        p = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
        if cfg.qkv_bias:
            p += cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd
        return p

    def mlp_params(ff: int) -> int:
        mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        return mult * d * ff

    def moe_params() -> int:
        m = cfg.moe
        assert m is not None
        mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        p = d * m.n_experts                                   # router
        p += m.n_experts * mult * d * m.d_ff_expert           # routed
        p += m.n_shared * mult * d * m.d_ff_expert            # shared
        return p

    def mamba_params() -> int:
        s = cfg.ssm
        assert s is not None
        di = s.expand * d
        if s.kind == "mamba1":
            dtr = s.dt_rank or -(-d // 16)
            p = d * 2 * di                      # in_proj
            p += di * s.d_conv + di             # conv + bias
            p += di * (dtr + 2 * s.d_state)     # x_proj
            p += dtr * di + di                  # dt_proj
            p += di * s.d_state + di            # A_log, D
            p += di * d                         # out_proj
            return p
        nh = di // s.headdim
        p = d * (2 * di + 2 * s.d_state + nh)   # in_proj (x,z,B,C,dt)
        p += (di + 2 * s.d_state) * s.d_conv + (di + 2 * s.d_state)
        p += nh + nh                            # A_log, D per head
        p += di + di * d                        # norm gate + out_proj
        return p

    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + mlp_params(cfg.d_ff) + 2 * d
        total += L * per_layer
    elif cfg.family == "moe":
        m = cfg.moe
        assert m is not None
        total += m.first_k_dense * (attn_params() + mlp_params(m.dense_ff or cfg.d_ff) + 2 * d)
        total += (L - m.first_k_dense) * (attn_params() + moe_params() + 2 * d)
    elif cfg.family == "audio":
        enc_layer = attn_params() + mlp_params(cfg.d_ff) + 2 * d
        dec_layer = 2 * attn_params() + mlp_params(cfg.d_ff) + 3 * d  # +cross
        total += cfg.n_encoder_layers * enc_layer + L * dec_layer
    elif cfg.family == "ssm":
        total += L * (mamba_params() + d)
    elif cfg.family == "hybrid":
        n_shared_blocks = L // cfg.attn_every
        n_mamba = L - n_shared_blocks
        total += n_mamba * (mamba_params() + d)
        total += attn_params() + mlp_params(cfg.d_ff) + 2 * d  # ONE shared block
    else:
        raise ValueError(cfg.family)
    total += d  # final norm
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: only top-k + shared experts).
    Drives MODEL_FLOPS = 6 * N_active * D in the roofline (DESIGN.md §8)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    per_expert = mult * cfg.d_model * m.d_ff_expert
    inactive = (m.n_experts - m.top_k) * per_expert * (cfg.n_layers - m.first_k_dense)
    return total - inactive
