"""Sharding rules: map every param / activation / cache leaf to a PartitionSpec.

Mesh axes (launch/mesh.py):
  single-pod  (data=16, model=16)
  multi-pod   (pod=2, data=16, model=16)

Conventions (MaxText-style 2-D "FSDP x TP"):
  DP axis   = ("pod", "data") when the mesh has a pod axis, else ("data",).
  FSDP axis = "data"  — parameters/optimizer state sharded along a non-TP dim.
  TP axis   = "model" — Megatron column->row within each block; vocab for
              embeddings/logits; experts for MoE; heads for attention.

KV-head subtlety: several assigned archs have n_kv_heads < |model| (e.g.
glm4 kv=2 on TP16).  We deliberately leave KV projections *unconstrained* on
the head dim (GSPMD replicates/pads as needed) — the same choice Megatron
and vLLM make (KV replication when kv < tp).  Q heads are sharded; GSPMD
handles the 56-head (llava) case by internal padding.
"""

from __future__ import annotations

from jax.sharding import PartitionSpec as P


class Axes:
    """Resolved mesh-axis names for one mesh flavour."""

    def __init__(self, *, multi_pod: bool):
        self.dp = ("pod", "data") if multi_pod else ("data",)
        self.fsdp = "data"
        self.tp = "model"

    # -- activations ---------------------------------------------------------
    def act_btd(self) -> P:
        """(batch, seq, d_model) activations."""
        return P(self.dp, None, None)

    def act_btd_sp(self) -> P:
        """Sequence-parallel activations (batch, seq/model, d_model)."""
        return P(self.dp, self.tp, None)

    def act_heads(self) -> P:
        """(batch, seq, heads, head_dim) — heads are TP-sharded."""
        return P(self.dp, None, self.tp, None)

    def logits(self) -> P:
        """(batch, seq, vocab) — vocab TP-sharded."""
        return P(self.dp, None, self.tp)

    def tokens(self) -> P:
        return P(self.dp, None)

    # -- cache ----------------------------------------------------------------
    def kv_cache(self) -> P:
        """(layers, batch, seq, kv_heads, head_dim): seq TP-sharded
        (flash-decoding / sequence-sharded cache; see models/attention.py)."""
        return P(None, self.dp, self.tp, None, None)

    def ssm_cache(self) -> P:
        """(layers, batch, d_inner, d_state): d_inner TP-sharded."""
        return P(None, self.dp, self.tp, None)


def batch_spec(axes: Axes, global_batch: int, dp_size: int) -> P:
    """Batch dim spec — replicate when batch doesn't divide DP (long_500k B=1)."""
    return axes.dp if global_batch % dp_size == 0 and global_batch >= dp_size else None
