"""AdamW with cosine schedule and global-norm clipping.

Optimizer state is a pytree congruent with params, so it inherits the
params' NamedShardings (FSDP: optimizer state is sharded exactly like the
weights — the ZeRO-3 layout).  Moments are fp32 regardless of param dtype;
``update`` is pure and jit-friendly.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array     # int32 scalar
    mu: Any             # first moment  (fp32, like params)
    nu: Any             # second moment (fp32)


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


@dataclass(frozen=True)
class AdamW:
    lr: Any                      # float or callable(step)->lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu2 = b1 * mu + (1 - b1) * g
            nu2 = b2 * nu + (1 - b2) * jnp.square(g)
            mhat = mu2 / c1
            nhat = nu2 / c2
            delta = mhat / (jnp.sqrt(nhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only (standard practice)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state.mu)
        flat_nu = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        new_nu = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}
