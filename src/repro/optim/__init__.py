from repro.optim.adamw import AdamW, OptState, cosine_schedule, clip_by_global_norm

__all__ = ["AdamW", "OptState", "cosine_schedule", "clip_by_global_norm"]
