"""Int8 gradient compression with error feedback for the DP reduction.

Distributed-optimization trick for collective-bound training (EXPERIMENTS.md
§Perf): instead of letting GSPMD all-reduce fp32 gradients over the data
axis, gradients are reduced with an explicit shard_map ring:

    quantize(g + err) to int8 with a per-chunk fp16-ish scale
    -> all_to_all the int8 chunks (each rank owns 1/G of every tensor)
    -> local dequant + sum -> requantize the reduced shard
    -> all_gather int8 shards -> dequant

Payload on the wire: ~1 byte/element each way vs 4 (fp32 AR) — a 4x
collective-byte reduction at the cost of quantization noise, which the
**error-feedback** accumulator re-injects next step (Seide et al., 1-bit
SGD lineage; standard convergence-safe form).

This composes with the paper's doctrine: the reduction is expressed as the
same fused all-to-all primitive as the FFT exchange — one more user of
``lax.all_to_all`` over a mesh subgroup — and the quantizer is the repo's
single shared implementation in :mod:`repro.core.quant` (also the
``comm_dtype`` exchange-payload codec of :mod:`repro.core.redistribute`).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.core.meshutil import axis_size as _axis_size
from repro.core.quant import dequantize_int8 as _dequant, quantize_int8


def _quant(x):
    """Symmetric per-chunk int8 (chunks along axis 0); returns (q, scale)."""
    return quantize_int8(x, block_axis=0)


def _reduce_shard(flat, axis_name: str):
    """Per-shard body: int8 reduce-scatter + all-gather of one flat fp32
    vector whose length is divisible by the group size."""
    G = _axis_size(axis_name)
    n = flat.shape[0]
    chunks = flat.reshape(G, n // G)
    q, s = _quant(chunks)                                   # (G, n/G) int8 + (G,1)
    q = lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=True)
    s = lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0, tiled=True)
    part = jnp.sum(_dequant(q, s), axis=0)                  # my reduced chunk
    q2, s2 = _quant(part[None])
    q2 = lax.all_gather(q2[0], axis_name, axis=0, tiled=False)   # (G, n/G)
    s2 = lax.all_gather(s2[0], axis_name, axis=0, tiled=False)
    return _dequant(q2, s2).reshape(n)


def _flatten_padded(grads, G):
    """Flatten a pytree to one fp32 vector padded to a multiple of ``G``
    (the wire layout both the collective and its local estimate must share)."""
    flat, tdef = jax.tree.flatten(grads)
    vec = jnp.concatenate([x.reshape(-1).astype(jnp.float32) for x in flat])
    pad = -vec.size % G
    if pad:
        vec = jnp.pad(vec, (0, pad))
    return vec, flat, tdef


def _unflatten(out, flat, tdef):
    outs = []
    off = 0
    for x in flat:
        outs.append(out[off:off + x.size].reshape(x.shape).astype(x.dtype))
        off += x.size
    return tdef.unflatten(outs)


def compressed_psum(grads, mesh, axis_name: str = "data"):
    """All-reduce a grad pytree over ``axis_name`` with int8 payloads.

    Call inside shard_map/jit on *per-device partial* gradients (e.g. the
    per-microbatch grads before DP averaging).  Returns the summed tree.
    """
    vec, flat, tdef = _flatten_padded(grads, mesh.shape[axis_name])
    out = _reduce_shard(vec, axis_name)
    return _unflatten(out, flat, tdef)


def reduce_local_roundtrip(grads, mesh, axis_name: str = "data"):
    """This rank's contribution to :func:`compressed_psum` after the wire
    quantization: same flatten/pad/per-chunk-scale layout as
    ``_reduce_shard``, minus the collective.  This is the rank-local lossy
    estimate error feedback must take residuals against — NOT the reduced
    sum the collective returns."""
    G = mesh.shape[axis_name]
    vec, flat, tdef = _flatten_padded(grads, G)
    q, s = _quant(vec.reshape(G, vec.size // G))
    return _unflatten(_dequant(q, s).reshape(-1), flat, tdef)


class ErrorFeedback:
    """Error-feedback state: e <- (g + e) - Q(g + e), applied around any
    lossy ``compress_fn``.  Pure container; state is a grads-like pytree.

    When ``compress_fn`` also *reduces* over ranks (e.g.
    :func:`compressed_psum` returns the G-rank sum), pass ``local_fn`` —
    the rank-local lossy estimate of this rank's own contribution — so the
    residual is what *this rank's* channel dropped; taking it against the
    reduced sum would inject a -(G-1)·g bias that swamps learning."""

    @staticmethod
    def init(grads_like):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

    @staticmethod
    def apply(grads, err, compress_fn, local_fn=None):
        """Returns (compressed_estimate, new_err)."""
        corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)
        sent = compress_fn(corrected)
        local = sent if local_fn is None else local_fn(corrected)
        new_err = jax.tree.map(lambda c, s: c - s.astype(jnp.float32),
                               corrected, local)
        return sent, new_err


def quantize_roundtrip(grads):
    """The lossy channel alone (per-tensor int8) — used by tests and by the
    single-device error-feedback path."""
    def one(g):
        q, s = _quant(g.reshape(1, -1))
        return _dequant(q, s).reshape(g.shape).astype(g.dtype)
    return jax.tree.map(one, grads)
