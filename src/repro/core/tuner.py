"""Per-plan exchange-schedule autotuner (``ParallelFFT(method="auto")``).

The paper's single-collective formulation leaves the *engine* of each
exchange open — the MPI analogue is the library's freedom to implement
``MPI_ALLTOALLW`` however it likes, and FLUPS (arXiv:2211.07777) shows the
winning strategy is shape/topology dependent.  Here the candidate engines
per exchange stage are ``fused``, ``traditional`` and
``pipelined×chunks∈{2,4,8}`` (comm/compute overlap, arXiv:2306.16589
lineage); this module micro-benchmarks each candidate on the stage's real
shapes (the exchange plus the 1-D FFT it feeds, so overlap is priced in)
and caches the winning schedule on disk keyed by
(mesh shape, global shape, grid, dtype, real, impl).

Cache location: ``$REPRO_TUNER_CACHE`` or ``~/.cache/repro/fft_tuner.json``;
an in-process memo avoids re-reading the file per plan.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.meshutil import shard_map
from repro.core.redistribute import PIPELINE_CHUNK_CANDIDATES, exchange_shard

#: (method, chunks) candidates benchmarked per exchange stage
DEFAULT_CANDIDATES: tuple[tuple[str, int], ...] = (
    ("fused", 1),
    ("traditional", 1),
    *(("pipelined", c) for c in PIPELINE_CHUNK_CANDIDATES),
)

_MEMO: dict[str, tuple[tuple[str, int], ...]] = {}


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNER_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "fft_tuner.json"


def plan_key(plan, candidates=DEFAULT_CANDIDATES) -> str:
    """Cache key: everything that determines the stage shapes, the engines
    swept, and the hardware the timings are valid for."""
    mesh_sig = tuple(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
    dtype = "float32->complex64" if plan.real else "complex64"
    return json.dumps(
        {"mesh": mesh_sig, "shape": plan.shape, "grid": plan.grid,
         "dtype": dtype, "real": plan.real, "impl": plan.impl,
         "backend": jax.default_backend(),
         "candidates": sorted(f"{m}@{c}" for m, c in candidates)},
        sort_keys=True, default=str)


def load_cache(path: Path) -> dict:
    try:
        return json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}


def save_cache(path: Path, data: dict) -> bool:
    try:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(data, indent=1))
        return True
    except OSError:
        return False  # read-only FS etc.: tuning still works, just uncached


def get_or_tune(plan, *, cache_path: str | None = None,
                candidates=DEFAULT_CANDIDATES) -> tuple[tuple[str, int], ...]:
    """Return the tuned (method, chunks) per exchange stage for ``plan``,
    consulting the in-process memo, then the disk cache, then benchmarking."""
    path = Path(cache_path) if cache_path else default_cache_path()
    key = plan_key(plan, candidates)
    memo_key = f"{path}|{key}"
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    disk = load_cache(path)
    if key in disk:
        sched = tuple((str(m), int(c)) for m, c in disk[key]["schedule"])
    else:
        sched, timings = tune_plan(plan, candidates=candidates)
        disk[key] = {"schedule": [list(s) for s in sched], "timings": timings}
        save_cache(path, disk)
    _MEMO[memo_key] = sched
    return sched


def tune_plan(plan, *, candidates=DEFAULT_CANDIDATES, repeats: int = 3,
              inner: int = 2):
    """Micro-benchmark every candidate engine for every exchange stage of
    ``plan`` (each stage timed together with the 1-D FFT it feeds, so a
    pipelined candidate gets credit for overlap) and return
    (schedule, timings) with ``timings[stage][method@chunks] = seconds``."""
    from repro.core.pfft import ExchangeStage

    schedule: list[tuple[str, int]] = []
    timings: dict[str, dict[str, float]] = {}
    for si, st in enumerate(plan.stages):
        if not isinstance(st, ExchangeStage):
            continue
        per = {}
        for method, chunks in candidates:
            try:
                per[f"{method}@{chunks}"] = _time_stage(
                    plan, si, method, chunks, repeats=repeats, inner=inner)
            except Exception as e:  # candidate invalid for this shape
                per[f"{method}@{chunks}"] = float("inf")
                per[f"{method}@{chunks}:error"] = repr(e)[:200]
        best = min((k for k in per if ":" not in k), key=lambda k: per[k])
        method, chunks = best.split("@")
        schedule.append((method, int(chunks)))
        timings[f"stage{si}"] = per  # errors kept: an inf needs its reason
    return tuple(schedule), timings


def _time_stage(plan, si: int, method: str, chunks: int, *, repeats: int,
                inner: int) -> float:
    """Wall-time one exchange stage (+ its following FFT) under one engine."""
    from repro.core import fftcore
    from repro.core.pfft import FFTStage, _exchange_then_fft, _fft_padded_axis

    st = plan.stages[si]
    before = plan.pencil_trace[si]
    follow = plan.stages[si + 1] if si + 1 < len(plan.stages) else None
    has_fft = isinstance(follow, FFTStage) and follow.axis == st.w
    out_pen = plan.pencil_trace[si + 2] if has_fft else plan.pencil_trace[si + 1]

    def run(block):
        if has_fft and method == "pipelined" and chunks > 1:
            return _exchange_then_fft(
                block, st, follow, plan.pencil_trace[si + 1], out_pen,
                chunks=chunks, impl=plan.impl, sign=fftcore.FORWARD)
        block = exchange_shard(block, st.v, st.w, st.group,
                               method=method, chunks=chunks)
        if has_fft:
            block = _fft_padded_axis(block, follow, plan.pencil_trace[si + 1],
                                     out_pen, impl=plan.impl, sign=fftcore.FORWARD)
        return block

    fn = jax.jit(shard_map(run, mesh=plan.mesh, in_specs=before.spec,
                           out_specs=out_pen.spec, check_vma=False))
    x = jax.device_put(jnp.zeros(before.physical, jnp.complex64), before.sharding)
    jax.block_until_ready(fn(x))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            y = fn(x)
        jax.block_until_ready(y)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best
