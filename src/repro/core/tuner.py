"""Per-plan exchange-schedule autotuner (``ParallelFFT(method="auto")``).

The paper's single-collective formulation leaves the *engine* of each
exchange open — the MPI analogue is the library's freedom to implement
``MPI_ALLTOALLW`` however it likes, and FLUPS (arXiv:2211.07777) shows the
winning strategy is shape/topology dependent.  Here the candidate space per
exchange stage is the cross product of

* engine: ``fused``, ``traditional``, ``pipelined×chunks∈{2,4,8}``
  (comm/compute overlap, arXiv:2306.16589 lineage), and
* wire payload (``comm_dtype``): every payload no lossier than the plan's
  accuracy budget (see :mod:`repro.core.redistribute`) — ``complex64``
  only for the default lossless budget, ``{complex64, bf16}`` for
  ``comm_dtype="bf16"``, ``{complex64, bf16, int8}`` for ``"int8"``.
  int8 is expected to win only on firmly ICI-bound stages: the narrowed
  payload must buy back the codec's two extra HBM passes over the block.

This module micro-benchmarks each candidate on the stage's real shapes (the
exchange plus the 1-D FFT it feeds, so overlap is priced in) and caches the
winning schedule on disk.

Cache schema v3: each entry maps a :func:`plan_key` — mesh shape, global
shape, grid, the per-axis transform tags (so a dealiased/pruned or DCT plan
never collides with the plain c2c plan of the same shape), impl, backend
*and device kind* (so timings from different TPU generations under the same
``backend`` string never collide), the candidate set, and ``schema: 3`` —
to ``{"schedule": [[method, chunks, comm_dtype], ...], "timings": {...}}``.
v1/v2 entries (no transforms field / older schema tags) have incompatible
keys and are simply never matched; stale entries are harmless.  Writes are atomic (temp file + ``os.replace``) so
concurrent benchmark workers sharing a cache cannot interleave partial
JSON.

Cache location: ``$REPRO_TUNER_CACHE`` or ``~/.cache/repro/fft_tuner.json``;
an in-process memo avoids re-reading the file per plan.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.meshutil import shard_map
from repro.core.quant import canonical_comm_dtype
from repro.core.redistribute import PIPELINE_CHUNK_CANDIDATES, exchange_shard

#: cache schema version (bump when the key or entry layout changes)
SCHEMA_VERSION = 3

#: (method, chunks) engine candidates benchmarked per exchange stage
ENGINE_CANDIDATES: tuple[tuple[str, int], ...] = (
    ("fused", 1),
    ("traditional", 1),
    *(("pipelined", c) for c in PIPELINE_CHUNK_CANDIDATES),
)

#: payloads allowed under each accuracy budget, lossless first
COMM_DTYPE_LADDER = {
    "complex64": ("complex64",),
    "bf16": ("complex64", "bf16"),
    "int8": ("complex64", "bf16", "int8"),
}


def candidates_for(comm_dtype=None) -> tuple[tuple[str, int, str], ...]:
    """Full (method, chunks, comm_dtype) candidate set for an accuracy
    budget: every engine × every payload no lossier than ``comm_dtype``."""
    ladder = COMM_DTYPE_LADDER[canonical_comm_dtype(comm_dtype)]
    return tuple((m, c, d) for d in ladder for m, c in ENGINE_CANDIDATES)


#: default candidate set (lossless budget)
DEFAULT_CANDIDATES = candidates_for("complex64")

_MEMO: dict[str, tuple[tuple[str, int, str], ...]] = {}

#: per-candidate stage timings memo shared across accuracy budgets in one
#: process: a --compare sweep tuning the same plan under complex64, bf16
#: and int8 budgets re-times only the candidates it has not seen yet
_STAGE_MEMO: dict[tuple[str, int, str], float] = {}


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNER_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "fft_tuner.json"


def _key_fields(plan) -> dict:
    """Everything that determines the stage shapes and the hardware the
    timings are valid for (the candidate-set-independent part of the key)."""
    mesh_sig = tuple(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:  # no devices (analysis-only contexts)
        device_kind = "unknown"
    return {"schema": SCHEMA_VERSION, "mesh": mesh_sig, "shape": plan.shape,
            "grid": plan.grid,
            "transforms": tuple(sp.tag() for sp in plan.transforms),
            "impl": plan.impl, "backend": jax.default_backend(),
            "device_kind": device_kind}


def plan_key(plan, candidates=None) -> str:
    """Cache key: everything that determines the stage shapes, the engines
    and payloads swept, and the hardware the timings are valid for."""
    if candidates is None:
        candidates = candidates_for(getattr(plan, "comm_dtype", None))
    fields = _key_fields(plan)
    fields["candidates"] = sorted(f"{m}@{c}@{d}" for m, c, d in candidates)
    return json.dumps(fields, sort_keys=True, default=str)


def load_cache(path: Path) -> dict:
    try:
        return json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}


def save_cache(path: Path, data: dict) -> bool:
    """Atomically replace the cache file: write a temp file in the same
    directory, then ``os.replace`` — concurrent benchmark workers can race
    on last-writer-wins but can never interleave partial JSON."""
    try:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps(data, indent=1))
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
        return True
    except OSError:
        return False  # read-only FS etc.: tuning still works, just uncached


def get_or_tune(plan, *, cache_path: str | None = None,
                candidates=None) -> tuple[tuple[str, int, str], ...]:
    """Return the tuned (method, chunks, comm_dtype) per exchange stage for
    ``plan``, consulting the in-process memo, then the disk cache, then
    benchmarking.  The default candidate set is every engine × every
    payload within the plan's ``comm_dtype`` accuracy budget."""
    if candidates is None:
        candidates = candidates_for(getattr(plan, "comm_dtype", None))
    path = Path(cache_path) if cache_path else default_cache_path()
    key = plan_key(plan, candidates)
    memo_key = f"{path}|{key}"
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    disk = load_cache(path)
    if key in disk:
        sched = tuple((str(m), int(c), str(d)) for m, c, d in disk[key]["schedule"])
    else:
        sched, timings = tune_plan(plan, candidates=candidates)
        disk[key] = {"schedule": [list(s) for s in sched], "timings": timings}
        save_cache(path, disk)
    _MEMO[memo_key] = sched
    return sched


def tune_plan(plan, *, candidates=None, repeats: int = 3, inner: int = 2):
    """Micro-benchmark every candidate (engine, chunks, comm_dtype) for
    every exchange stage of ``plan`` (each stage timed together with the
    1-D FFT it feeds, so a pipelined candidate gets credit for overlap) and
    return (schedule, timings) with
    ``timings[stage][method@chunks@comm_dtype] = seconds``."""
    from repro.core.pfft import ExchangeStage

    if candidates is None:
        candidates = candidates_for(getattr(plan, "comm_dtype", None))
    base_key = json.dumps(_key_fields(plan), sort_keys=True, default=str)
    schedule: list[tuple[str, int, str]] = []
    timings: dict[str, dict[str, float]] = {}
    for si, st in enumerate(plan.stages):
        if not isinstance(st, ExchangeStage):
            continue
        per = {}
        for method, chunks, comm_dtype in candidates:
            tag = f"{method}@{chunks}@{comm_dtype}"
            memo_key = (base_key, si, tag)
            if memo_key in _STAGE_MEMO:
                per[tag] = _STAGE_MEMO[memo_key]
                continue
            try:
                per[tag] = _time_stage(plan, si, method, chunks, comm_dtype,
                                       repeats=repeats, inner=inner)
                _STAGE_MEMO[memo_key] = per[tag]
            except Exception as e:  # candidate invalid for this shape
                per[tag] = float("inf")
                per[f"{tag}:error"] = repr(e)[:200]
        best = min((k for k in per if ":" not in k), key=lambda k: per[k])
        method, chunks, comm_dtype = best.split("@")
        schedule.append((method, int(chunks), comm_dtype))
        timings[f"stage{si}"] = per  # errors kept: an inf needs its reason
    return tuple(schedule), timings


def _time_stage(plan, si: int, method: str, chunks: int, comm_dtype: str, *,
                repeats: int, inner: int) -> float:
    """Wall-time one exchange stage (+ its following FFT) under one engine
    and payload."""
    from repro.core import fftcore
    from repro.core.pfft import FFTStage, _exchange_then_fft, _fft_padded_axis

    st = plan.stages[si]
    before = plan.pencil_trace[si]
    follow = plan.stages[si + 1] if si + 1 < len(plan.stages) else None
    has_fft = isinstance(follow, FFTStage) and follow.axis == st.w
    out_pen = plan.pencil_trace[si + 2] if has_fft else plan.pencil_trace[si + 1]

    def run(block):
        if has_fft and method == "pipelined" and chunks > 1:
            return _exchange_then_fft(
                block, st, follow, plan.pencil_trace[si + 1], out_pen,
                chunks=chunks, comm_dtype=comm_dtype, impl=plan.impl,
                sign=fftcore.FORWARD)
        block = exchange_shard(block, st.v, st.w, st.group,
                               method=method, chunks=chunks, comm_dtype=comm_dtype)
        if has_fft:
            block = _fft_padded_axis(block, follow, plan.pencil_trace[si + 1],
                                     out_pen, impl=plan.impl, sign=fftcore.FORWARD)
        return block

    fn = jax.jit(shard_map(run, mesh=plan.mesh, in_specs=before.spec,
                           out_specs=out_pen.spec, check_vma=False))
    # time at the stage's true dtype: exchanges before any complex-producing
    # transform (all-real DCT/DST plans) ship f32, not complex64
    x = jax.device_put(jnp.zeros(before.physical, plan.dtype_trace[si]),
                       before.sharding)
    jax.block_until_ready(fn(x))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            y = fn(x)
        jax.block_until_ready(y)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best
