"""Per-plan exchange-schedule autotuner (``ParallelFFT(method="auto")``).

The paper's single-collective formulation leaves the *engine* of each
exchange open — the MPI analogue is the library's freedom to implement
``MPI_ALLTOALLW`` however it likes, and FLUPS (arXiv:2211.07777) shows the
winning strategy is shape/topology dependent.  Here the candidate space per
exchange stage is the cross product of

* engine: ``fused``, ``traditional``, ``pipelined×chunks∈{2,4,8}``
  (comm/compute overlap, arXiv:2306.16589 lineage), and
* wire payload (``comm_dtype``): every payload no lossier than the plan's
  accuracy budget (see :mod:`repro.core.redistribute`) — ``complex64``
  only for the default lossless budget, ``{complex64, bf16}`` for
  ``comm_dtype="bf16"``, ``{complex64, bf16, int8}`` for ``"int8"``.
  int8 is expected to win only on firmly ICI-bound stages: the narrowed
  payload must buy back the codec's two extra HBM passes over the block.

* batch fusion (multi-field executions, ``nfields > 1``): how the stacked
  fields traverse the stage — ``stacked`` (one collective ships all
  fields), ``pipelined-across-fields`` (field i's collective emitted under
  field i-1's FFT), or ``per-field`` (serialized baseline).  Latency-bound
  small grids favor stacked; compute-heavy stages favor
  pipelined-across-fields.

* exchange-local impl (``StageEntry.impl``): the jnp reference pack/codec
  vs the fused Pallas exchange kernels of :mod:`repro.kernels.exchange`.
  Pallas candidates are swept only when the plan's ``exchange_impl``
  budget is ``"pallas"`` *and* the payload is lossy (a lossless exchange
  has no local pass for the kernels to fuse away — see
  ``pallas_applicable``), so ``method="auto"`` picks the kernels per
  stage only where they actually win.

This module micro-benchmarks each candidate on the stage's real shapes (the
exchange plus the 1-D FFT it feeds, so overlap is priced in) and caches the
winning schedule on disk.

Cache schema v6: each entry maps a :func:`plan_key` — mesh shape, global
shape, grid, the per-axis transform tags (so a dealiased/pruned or DCT plan
never collides with the plain c2c plan of the same shape), impl, backend
*and device kind* (so timings from different TPU generations under the same
``backend`` string never collide), **the batch size** (``nfields`` — a
3-field schedule must never be replayed for a 16-field execution), the
candidate set, and ``schema: 6`` — to ``{"schedule": [[method, chunks,
comm_dtype, impl, batch_fusion], ...], "timings": {...}}`` (full
:class:`~repro.core.planconfig.StageEntry` rows).  Entry health marks
(since v5): :func:`quarantine` sets ``entry["bad"] = {"reason": ...}``
(and bumps ``entry["quarantines"]``) when a guarded execution catches the
entry's schedule failing at runtime; a marked entry is never replayed —
:func:`_parse_entry` rejects it, forcing a retune whose fresh timings
(under whatever fault made the old winner lose) replace the mark.

v5 entries (3/4-field schedule rows, ``schema: 5`` keys) are **migrated,
not retuned**: a v6 default-candidate miss whose exchange-impl budget is
"jnp" reconstructs the plan's exact v5 key, upgrades a healthy legacy
entry through :func:`~repro.core.planconfig.StageEntry.make` (every old
row gains ``impl="jnp"``), and re-saves it under the v6 key — the v5
timings stay valid because the jnp-only candidate space is unchanged.  A
"pallas" budget never migrates: its candidate set contains kernels the v5
sweep never measured.  v1–v4 entries have incompatible keys and are simply
never matched; stale entries are harmless and a corrupt or non-dict cache
file is silently treated as empty and rewritten — a stale cache must never
raise.  Writes are atomic (temp file + ``os.replace``) and **merge** by
default: the writer re-reads the file and overlays only its own keys, so
concurrent workers tuning *different* plans no longer clobber each other's
entries (last-writer-wins now applies per key, not per file).

Cache location: ``$REPRO_TUNER_CACHE`` or ``~/.cache/repro/fft_tuner.json``;
an in-process memo avoids re-reading the file per plan.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
import time
from pathlib import Path

try:  # POSIX advisory locks; absent on some platforms (lock becomes a no-op)
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

import jax
import jax.numpy as jnp

from repro.core import modelfit
from repro.core.meshutil import shard_map
from repro.core.planconfig import BATCH_FUSIONS, StageEntry, as_schedule
from repro.core.quant import canonical_comm_dtype
from repro.core.redistribute import PIPELINE_CHUNK_CANDIDATES
from repro.kernels.exchange import pallas_applicable

#: cache schema version (bump when the key or entry layout changes)
SCHEMA_VERSION = 6

#: how many times a guarded execution may quarantine-and-retune one cache
#: entry before the runner gives up and raises (see repro.robustness.runner)
MAX_QUARANTINE_RETUNES = 3

#: (method, chunks) engine candidates benchmarked per exchange stage
ENGINE_CANDIDATES: tuple[tuple[str, int], ...] = (
    ("fused", 1),
    ("traditional", 1),
    *(("pipelined", c) for c in PIPELINE_CHUNK_CANDIDATES),
)

#: payloads allowed under each accuracy budget, lossless first
COMM_DTYPE_LADDER = {
    "complex64": ("complex64",),
    "bf16": ("complex64", "bf16"),
    "int8": ("complex64", "bf16", "int8"),
}


def candidates_for(comm_dtype=None, exchange_impl: str = "jnp",
                   ) -> tuple[StageEntry, ...]:
    """Full :class:`StageEntry` candidate set for an accuracy budget: every
    engine × every payload no lossier than ``comm_dtype``; an
    ``exchange_impl="pallas"`` budget additionally sweeps the fused Pallas
    kernels for every candidate they apply to (lossy payloads)."""
    ladder = COMM_DTYPE_LADDER[canonical_comm_dtype(comm_dtype)]
    out = [StageEntry(m, c, d) for d in ladder for m, c in ENGINE_CANDIDATES]
    if exchange_impl == "pallas":
        out += [StageEntry(m, c, d, "pallas") for d in ladder
                for m, c in ENGINE_CANDIDATES if pallas_applicable(m, d)]
    return tuple(out)


def batched_candidates_for(comm_dtype=None, exchange_impl: str = "jnp",
                           ) -> tuple[StageEntry, ...]:
    """Batch-aware candidate set for a multi-field execution: every
    single-field candidate × every batch fusion mode."""
    return tuple(e._replace(batch_fusion=f) for f in BATCH_FUSIONS
                 for e in candidates_for(comm_dtype, exchange_impl))


def _default_candidates(plan, nfields: int):
    budget = getattr(plan, "comm_dtype", None)
    impl_budget = getattr(plan, "exchange_impl", "jnp")
    return (candidates_for(budget, impl_budget) if nfields <= 1
            else batched_candidates_for(budget, impl_budget))


def _tag(cand) -> str:
    return "@".join(str(p) for p in cand)


#: default candidate set (lossless budget)
DEFAULT_CANDIDATES = candidates_for("complex64")

_MEMO: dict[str, tuple[StageEntry, ...]] = {}

#: per-candidate stage timings memo shared across accuracy budgets in one
#: process: a --compare sweep tuning the same plan under complex64, bf16
#: and int8 budgets re-times only the candidates it has not seen yet
_STAGE_MEMO: dict[tuple[str, int, str], float] = {}


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_TUNER_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "fft_tuner.json"


def _key_fields(plan, nfields: int = 1) -> dict:
    """Everything that determines the stage shapes and the hardware the
    timings are valid for (the candidate-set-independent part of the key).
    ``nfields`` is part of the identity: batched stage shapes (and the
    stacked-vs-per-field trade) change with the batch size."""
    mesh_sig = tuple(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:  # no devices (analysis-only contexts)
        device_kind = "unknown"
    return {"schema": SCHEMA_VERSION, "mesh": mesh_sig, "shape": plan.shape,
            "grid": plan.grid,
            "transforms": tuple(sp.tag() for sp in plan.transforms),
            "impl": plan.impl, "backend": jax.default_backend(),
            "device_kind": device_kind, "nfields": nfields}


def plan_key(plan, candidates=None, *, nfields: int = 1) -> str:
    """Cache key: everything that determines the stage shapes, the engines,
    payloads and batch fusions swept, the batch size, and the hardware the
    timings are valid for."""
    if candidates is None:
        candidates = _default_candidates(plan, nfields)
    fields = _key_fields(plan, nfields)
    fields["candidates"] = sorted(_tag(c) for c in candidates)
    return json.dumps(fields, sort_keys=True, default=str)


def load_cache(path: Path) -> dict:
    """Read a schedule cache, returning ``{}`` for anything unusable — a
    missing file, unreadable bytes, invalid JSON, or a JSON payload that is
    not an object (a stale or corrupt cache must never raise: it is simply
    retuned and rewritten)."""
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return {}
    return data if isinstance(data, dict) else {}


@contextlib.contextmanager
def _file_lock(path: Path):
    """Cross-process advisory lock (``fcntl.flock`` on ``<path>.lock``)
    serializing the read-merge-write cycle against concurrent serve
    replicas sharing one schedule DB.  Atomic replace alone only prevents
    torn *reads*; two processes interleaving read→merge→replace can still
    drop each other's keys.  No-op when ``fcntl`` is unavailable or the
    lock file cannot be created (read-only FS) — behavior then degrades to
    the previous merge-on-save semantics, never an error.  flock is held
    per open-file-description, so callers must not nest this for the same
    path within one process (see :func:`quarantine` → ``lock=False``)."""
    if fcntl is None:
        yield
        return
    try:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        fd = os.open(str(path) + ".lock", os.O_RDWR | os.O_CREAT, 0o644)
    except OSError:
        yield
        return
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)  # closing releases the flock


def save_cache(path: Path, data: dict, *, merge: bool = True,
               lock: bool = True) -> bool:
    """Atomically write cache entries: write a temp file in the same
    directory, then ``os.replace`` — readers can never observe partial
    JSON.  With ``merge=True`` (default) the writer first re-reads the file
    and overlays only the keys in ``data``, so a worker that tuned plan A
    no longer erases the entry a concurrent worker just wrote for plan B
    (the pre-v5 last-writer-wins clobber).  The read-merge-write cycle
    runs under :func:`_file_lock` (``lock=True``), closing the remaining
    cross-process interleave where two racing writers both read the same
    snapshot and the second replace drops the first writer's keys; pass
    ``lock=False`` only when the caller already holds the lock.
    ``merge=False`` replaces the whole file (tests / explicit resets)."""
    try:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with _file_lock(path) if lock else contextlib.nullcontext():
            if merge:
                current = load_cache(path)
                current.update(data)
                data = current
            fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(json.dumps(data, indent=1))
                os.replace(tmp, path)
            except BaseException:
                os.unlink(tmp)
                raise
        return True
    except OSError:
        return False  # read-only FS etc.: tuning still works, just uncached


def get_or_tune(plan, *, cache_path: str | None = None,
                candidates=None, nfields: int = 1):
    """Return the tuned schedule for ``plan`` — a :class:`StageEntry` per
    exchange stage — consulting the in-process memo, then the disk cache
    (including a v5-entry migration, see module docstring), then
    benchmarking.  The default candidate set is every engine × every
    payload within the plan's ``comm_dtype`` accuracy budget × every
    exchange impl within its ``exchange_impl`` budget (× every batch
    fusion mode for a batched plan).  A stale-schema or otherwise
    malformed cache entry is ignored and overwritten, never raised on."""
    defaults = candidates is None
    if defaults:
        candidates = _default_candidates(plan, nfields)
    candidates = as_schedule(candidates)
    path = Path(cache_path) if cache_path else default_cache_path()
    key = plan_key(plan, candidates, nfields=nfields)
    memo_key = f"{path}|{key}"
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    disk = load_cache(path)
    sched = _parse_entry(disk.get(key), plan.n_exchanges, candidates=candidates)
    if sched is None and defaults:
        migrated = _migrate_v5_entry(plan, disk, nfields)
        if migrated is not None:
            sched, legacy = migrated
            save_cache(path, {key: {"schedule": [list(s) for s in sched],
                                    "timings": legacy.get("timings", {}),
                                    "migrated_from_schema": 5}})
    if sched is None:
        sched, timings = tune_plan(plan, candidates=candidates, nfields=nfields)
        entry = {"schedule": [list(s) for s in sched], "timings": timings}
        prev = disk.get(key)
        if isinstance(prev, dict) and prev.get("quarantines"):
            # retune after a quarantine: clear the bad mark, keep the count
            # so a still-failing entry eventually exhausts the runner's cap
            entry["quarantines"] = int(prev["quarantines"])
        save_cache(path, {key: entry})  # delta write: merge keeps other plans
    _MEMO[memo_key] = sched
    return sched


def _legacy_v5_candidates(plan, nfields: int):
    """The exact (jnp-only) v5 candidate tuples for a plan's budget — the
    raw 3/4-field rows v5 swept, for key reconstruction and entry
    validation during migration."""
    ladder = COMM_DTYPE_LADDER[canonical_comm_dtype(getattr(plan, "comm_dtype", None))]
    flat = tuple((m, c, d) for d in ladder for m, c in ENGINE_CANDIDATES)
    if nfields <= 1:
        return flat
    return tuple((m, c, d, f) for f in BATCH_FUSIONS for m, c, d in flat)


def _migrate_v5_entry(plan, disk: dict, nfields: int):
    """Look up this plan's schema-5 cache entry and upgrade it to a v6
    schedule (``(schedule, legacy_entry)``), or ``None`` when there is
    nothing migratable: no/unhealthy legacy entry, a legacy schedule
    outside the legacy candidate set, or an ``exchange_impl="pallas"``
    budget (whose v6 candidate set sweeps kernels v5 never measured — a
    migrated winner could be stale, so that case retunes)."""
    if getattr(plan, "exchange_impl", "jnp") != "jnp":
        return None
    legacy_cands = _legacy_v5_candidates(plan, nfields)
    fields = _key_fields(plan, nfields)
    fields["schema"] = 5
    fields["candidates"] = sorted(_tag(c) for c in legacy_cands)
    legacy_key = json.dumps(fields, sort_keys=True, default=str)
    entry = disk.get(legacy_key)
    sched = _parse_entry(entry, plan.n_exchanges, candidates=legacy_cands)
    if sched is None:
        return None
    return sched, entry


def quarantine(path, key: str, reason: str) -> int:
    """Mark the cache entry at ``key`` bad (a guarded execution caught its
    schedule failing at runtime): the entry stops parsing, so the next
    schedule resolve retunes.  Bumps and returns the entry's lifetime
    quarantine count; also drops the in-process memos — including the
    stage-timing memo, which may hold the faulted candidate's healthy-run
    timings — so the retune actually re-measures.

    The whole read-bump-write runs under one :func:`_file_lock` hold (the
    inner save passes ``lock=False``: flock is per open-file-description,
    so re-acquiring from a second fd in the same process would deadlock) —
    two serve replicas quarantining concurrently can't lose a count."""
    with _file_lock(path):
        disk = load_cache(path)
        entry = disk.get(key)
        if not isinstance(entry, dict):
            entry = {}
        entry["bad"] = {"reason": reason}
        entry["quarantines"] = int(entry.get("quarantines", 0)) + 1
        save_cache(path, {key: entry}, lock=False)
    for k in [k for k in _MEMO if k.endswith("|" + key)]:
        del _MEMO[k]
    _STAGE_MEMO.clear()
    return entry["quarantines"]


def _parse_entry(entry, n_exchanges: int, candidates=None):
    """Validate one disk-cache entry into a :class:`StageEntry` schedule,
    or ``None`` if missing/malformed — wrong stage count, junk types, or
    unknown engine/payload/impl/fusion *values* (a hand-edited or
    bit-rotted entry must retune, never raise later inside the executor).
    Legacy 3/4-field rows upgrade through :func:`StageEntry.make`.

    When ``candidates`` is given, every stage entry must additionally be a
    member of that *live* candidate set: an entry naming an engine, chunk
    count, payload, impl or fusion that has since been dropped from the
    sweep (e.g. a hand-edited chunks=16 after ``PIPELINE_CHUNK_CANDIDATES``
    shrank) is a retune, not a schedule the executor should replay.

    A quarantined entry (``entry["bad"]`` set, see :func:`quarantine`)
    never parses either — that is the whole point of the mark."""
    if not isinstance(entry, dict) or entry.get("bad"):
        return None
    try:
        sched = as_schedule(entry["schedule"])
        if len(sched) != n_exchanges:
            return None
        if candidates is not None:
            live = set(as_schedule(candidates))
            if any(e not in live for e in sched):
                return None
        return sched
    except (TypeError, KeyError, IndexError, ValueError):
        pass
    return None


#: with model priors armed, how many top-ranked candidates per stage the
#: tuner still micro-benchmarks (0 disables pruning: rank only)
PRIOR_TOPK_DEFAULT = 6


def _prior_stage_time(plan, si: int, entry: StageEntry, nfields: int,
                      coeffs: dict) -> float:
    """Modeled seconds for one stage candidate at the *fitted* hardware
    coefficients of a scaling-sweep fit report (see
    :mod:`repro.core.modelfit`) — the ranking key prior-guided tuning
    prunes the sweep with.  Mirrors :meth:`ParallelFFT.model_time_s`'s
    per-stage accounting: the exchange plus the 1-D FFT it feeds."""
    from repro.core.pfft import FFTStage
    from repro.core.redistribute import exchange_time_model

    st = plan.stages[si]
    follow = plan.stages[si + 1] if si + 1 < len(plan.stages) else None
    fft_s = 0.0
    if isinstance(follow, FFTStage) and follow.axis == st.w:
        ndev = int(plan.mesh.devices.size)
        fft_s = plan._stage_flops_at(si + 1) / ndev / coeffs["peak_flops"]
    return exchange_time_model(
        plan.pencil_trace[si], st.v, st.w, itemsize=plan._stage_itemsize(si),
        method=entry.method, chunks=entry.chunks, comm_dtype=entry.comm_dtype,
        impl=entry.impl, ici_bw=coeffs["ici_bw"], hbm_bw=coeffs["hbm_bw"],
        ici_latency_s=coeffs["ici_latency_s"], overlap_compute_s=fft_s,
        nfields=nfields, batch_fusion=entry.batch_fusion)


def tune_plan(plan, *, candidates=None, repeats: int = 3, inner: int = 2,
              nfields: int = 1):
    """Micro-benchmark every :class:`StageEntry` candidate for every
    exchange stage of ``plan`` (each stage timed together with the 1-D FFT
    it feeds, so pipelined candidates get credit for overlap; batched
    candidates run on the real stacked ``(nfields, …)`` stage shapes) and
    return (schedule, timings) with ``timings[stage][tag] = seconds``.

    With model priors armed (``$REPRO_MODEL_PRIORS`` names a
    :mod:`repro.core.modelfit` fit report), each stage's candidate set is
    first *ranked* by modeled time at the fitted coefficients and only the
    top ``$REPRO_TUNER_PRIOR_TOPK`` (default 6, ``0`` disables) are
    micro-benchmarked; pruned candidates keep their model estimate in the
    timings dict under a ``pruned:`` tag so the cache records what the
    prior skipped."""
    from repro.core.pfft import ExchangeStage

    if candidates is None:
        candidates = _default_candidates(plan, nfields)
    candidates = as_schedule(candidates)
    priors = modelfit.active_priors()
    try:
        topk = int(os.environ.get("REPRO_TUNER_PRIOR_TOPK",
                                  str(PRIOR_TOPK_DEFAULT)))
    except ValueError:
        topk = PRIOR_TOPK_DEFAULT
    base_key = json.dumps(_key_fields(plan, nfields), sort_keys=True, default=str)
    schedule = []
    timings: dict[str, dict[str, float]] = {}
    for si, st in enumerate(plan.stages):
        if not isinstance(st, ExchangeStage):
            continue
        per = {}
        by_tag = {}
        sweep = candidates
        if priors is not None and 0 < topk < len(candidates):
            est = {cand: _prior_stage_time(plan, si, cand, nfields, priors)
                   for cand in candidates}
            ranked = sorted(candidates, key=lambda c: est[c])
            sweep, skipped = ranked[:topk], ranked[topk:]
            for cand in skipped:
                per[f"pruned:{_tag(cand)}"] = est[cand]
        for cand in sweep:
            tag = _tag(cand)
            by_tag[tag] = cand
            memo_key = (base_key, si, tag)
            if memo_key in _STAGE_MEMO:
                per[tag] = _STAGE_MEMO[memo_key]
                continue
            try:
                per[tag] = _time_stage(plan, si, *cand, repeats=repeats,
                                       inner=inner, nfields=nfields)
                _STAGE_MEMO[memo_key] = per[tag]
            except Exception as e:  # candidate invalid for this shape
                per[tag] = float("inf")
                per[f"{tag}:error"] = repr(e)[:200]
        best = min((k for k in per if ":" not in k), key=lambda k: per[k])
        schedule.append(by_tag[best])
        timings[f"stage{si}"] = per  # errors kept: an inf needs its reason
    return tuple(schedule), timings


def _time_stage(plan, si: int, method: str, chunks: int, comm_dtype: str,
                impl: str = "jnp", batch_fusion: str = "stacked", *,
                repeats: int, inner: int, nfields: int = 1) -> float:
    """Wall-time one exchange stage (+ its following FFT) under one engine,
    payload, exchange impl, and — for a stacked ``nfields > 1`` input —
    batch fusion mode, via the same stage executor the plan runs
    (:func:`repro.core.pfft._run_exchange_stage`)."""
    from repro.core import fftcore
    from repro.core.pfft import FFTStage, _run_exchange_stage

    st = plan.stages[si]
    before = plan.pencil_trace[si]
    follow = plan.stages[si + 1] if si + 1 < len(plan.stages) else None
    has_fft = isinstance(follow, FFTStage) and follow.axis == st.w
    out_pen = plan.pencil_trace[si + 2] if has_fft else plan.pencil_trace[si + 1]
    nbatch = 1 if nfields > 1 else 0
    entry = StageEntry(method, chunks, comm_dtype, impl, batch_fusion)

    def run(block):
        out, _, _ = _run_exchange_stage(
            block, st, follow if has_fft else None, plan.pencil_trace[si + 1],
            out_pen if has_fft else None, entry, impl=plan.impl,
            sign=fftcore.FORWARD, nbatch=nbatch)
        return out

    fn = jax.jit(shard_map(run, mesh=plan.mesh,
                           in_specs=before.batched_spec(nbatch),
                           out_specs=out_pen.batched_spec(nbatch),
                           check_vma=False))
    # time at the stage's true dtype: exchanges before any complex-producing
    # transform (all-real DCT/DST plans) ship f32, not complex64
    x = jax.device_put(jnp.zeros((nfields,) * nbatch + tuple(before.physical),
                                 plan.dtype_trace[si]),
                       before.batched_sharding(nbatch))
    jax.block_until_ready(fn(x))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            y = fn(x)
        jax.block_until_ready(y)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best
