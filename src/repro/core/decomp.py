"""Balanced block-contiguous decompositions (paper Alg. 1 / Listing 1).

The paper uses the PETSc formula to split an index set of length ``N`` into
``M`` contiguous blocks whose lengths differ by at most one.  MPI's
ALLTOALLW handles such ragged blocks natively; XLA SPMD requires *equal*
shards, so we carry the paper's formula for bookkeeping (tests, oracles,
host-side planning) and add an explicit *padding policy* for the SPMD path:
an axis of logical length ``N`` distributed over ``M`` devices is stored with
physical length ``pad_to_multiple(N, M)`` and the pad region is masked out at
FFT boundaries (see core/pfft.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


def decompose(N: int, M: int, p: int) -> tuple[int, int]:
    """Balanced block-contiguous decomposition (paper Alg. 1).

    Returns ``(n, s)``: the number of elements and start offset of part ``p``
    when ``N`` elements are split into ``M`` contiguous balanced parts.
    """
    if N < 0:
        raise ValueError(f"N must be >= 0, got {N}")
    if M <= 0:
        raise ValueError(f"M must be > 0, got {M}")
    if not (0 <= p < M):
        raise ValueError(f"p must be in [0, {M}), got {p}")
    q, r = divmod(N, M)
    n = q + (1 if r > p else 0)
    s = q * p + min(r, p)
    return n, s


def local_lengths(N: int, M: int) -> list[int]:
    """All part lengths ``n_p`` for ``p = 0..M-1``."""
    return [decompose(N, M, p)[0] for p in range(M)]


def start_indices(N: int, M: int) -> list[int]:
    """All start offsets ``s_p`` for ``p = 0..M-1``."""
    return [decompose(N, M, p)[1] for p in range(M)]


def pad_to_multiple(N: int, M: int) -> int:
    """Smallest multiple of ``M`` that is >= ``N`` (SPMD equal-shard policy)."""
    if M <= 0:
        raise ValueError(f"M must be > 0, got {M}")
    return M * math.ceil(N / M) if N > 0 else 0


@dataclass(frozen=True)
class AxisDecomp:
    """One array axis distributed over one mesh-axis group.

    ``logical``  — true (paper) extent of the axis.
    ``parts``    — number of shards (= mesh axis size), 1 if not distributed.
    ``padded``   — stored global extent (equal-shard policy).
    """

    logical: int
    parts: int

    @property
    def padded(self) -> int:
        return pad_to_multiple(self.logical, self.parts)

    @property
    def shard(self) -> int:
        """Per-device (physical) extent."""
        return self.padded // self.parts

    @property
    def pad(self) -> int:
        return self.padded - self.logical

    def owner_slices(self) -> list[slice]:
        """Physical slice of the *global padded* axis owned by each part."""
        return [slice(p * self.shard, (p + 1) * self.shard) for p in range(self.parts)]

    def balanced_slices(self) -> list[slice]:
        """Paper's (ragged) balanced slices of the *logical* axis — oracle only."""
        out = []
        for p in range(self.parts):
            n, s = decompose(self.logical, self.parts, p)
            out.append(slice(s, s + n))
        return out
