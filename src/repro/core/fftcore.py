"""Local (serial) FFT dispatch — the paper's ``seqxfftn``.

The paper assumes a vendor serial FFT (FFTW/MKL/ESSL).  Here the "vendor"
choices are:

``impl="jnp"``     — ``jnp.fft`` (XLA FFT HLO).  Reference path; used for
                     oracles and the CPU container.
``impl="matmul"``  — four-step matmul DFT on the MXU via the Pallas kernel in
                     ``repro.kernels.fft`` (TPU-native adaptation, DESIGN.md
                     §4).  Falls back to a pure-jnp matmul DFT for axis
                     lengths the kernel does not tile.
"""

from __future__ import annotations

import jax.numpy as jnp

FORWARD = -1
BACKWARD = +1


def local_fft(x, axis: int, sign: int, *, impl: str = "jnp", real: str | None = None, n: int | None = None):
    """1-D transform along ``axis`` of a locally-complete (possibly padded
    elsewhere) block.  ``real`` ∈ {None, "r2c", "c2r"}; ``n`` is the logical
    length for c2r."""
    if impl == "jnp":
        if real == "r2c":
            assert sign == FORWARD
            return jnp.fft.rfft(x, axis=axis)
        if real == "c2r":
            assert sign == BACKWARD
            return jnp.fft.irfft(x, n=n, axis=axis)
        return jnp.fft.fft(x, axis=axis) if sign == FORWARD else jnp.fft.ifft(x, axis=axis)
    if impl == "matmul":
        from repro.kernels.fft import ops as fft_ops

        if real == "r2c":
            return fft_ops.rfft_matmul(x, axis=axis)
        if real == "c2r":
            return fft_ops.irfft_matmul(x, n=n, axis=axis)
        return fft_ops.fft_matmul(x, axis=axis, inverse=(sign == BACKWARD))
    raise ValueError(f"unknown fft impl {impl!r}")
