"""Local (serial) transform dispatch — the paper's ``seqxfftn``, generalized.

The paper assumes a vendor serial FFT (FFTW/MKL/ESSL) and promises the
machinery applies to "Fourier (or similar) transforms".  This module is
where that generality lives: a per-axis :class:`TransformSpec` describes
*which* 1-D transform each axis gets, and :func:`local_transform` executes
one stage of it in either direction.

Supported kinds (P3DFFT ships pruned/real transforms as first-class plan
options; FLUPS shows per-axis flexibility is what opens new solver
workloads):

``c2c``            — complex FFT/iFFT (``jnp.fft`` convention: forward
                     unnormalized, backward 1/n).
``r2c``            — real-input FFT, Hermitian-reduced to ``n//2+1`` bins;
                     backward is ``irfft(n=...)``.
``dct`` (II / III) — cosine transform via the FFT-based even/odd extension
                     trick (Makhoul), scipy's unnormalized convention;
                     backward is the exact inverse.  Real-to-real: applied
                     to a complex block it transforms re/im independently.
``dst`` (II / III) — sine transform, reduced to the DCT by
                     ``DST-II(x) = reverse(DCT-II((-1)^j x))``.
``pruned`` / ``n_keep`` — truncated spectrum: the forward transform keeps
                     only ``n_keep`` retained modes (centered ±k/2 split
                     for c2c, the leading bins for r2c); backward
                     zero-scatters them back before the inverse transform.
                     With ``n = 3·n_keep/2`` this is exactly the 3/2-rule
                     dealiased transform of pseudo-spectral solvers.

Local FFT "vendors":

``impl="jnp"``     — ``jnp.fft`` (XLA FFT HLO).  Reference path; used for
                     oracles and the CPU container.
``impl="matmul"``  — four-step matmul DFT on the MXU via the Pallas kernel
                     in ``repro.kernels.fft``; DCT/DST axes run as a single
                     transform-matrix matmul (``dct_matmul``/``dst_matmul``).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

FORWARD = -1
BACKWARD = +1

_KINDS = ("c2c", "r2c", "dct", "dst")


@dataclass(frozen=True)
class TransformSpec:
    """One axis's 1-D transform.

    ``kind``      — "c2c" | "r2c" | "dct" | "dst".
    ``trig_type`` — 2 or 3 (dct/dst only; the forward type — backward is
                    its exact inverse).
    ``n_keep``    — retained spectral modes (c2c/r2c only); ``None`` keeps
                    the full spectrum.
    """

    kind: str = "c2c"
    trig_type: int = 2
    n_keep: int | None = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown transform kind {self.kind!r}")
        if self.kind in ("dct", "dst") and self.trig_type not in (2, 3):
            raise ValueError(f"{self.kind} type must be 2 or 3, got {self.trig_type}")
        if self.n_keep is not None:
            if self.kind in ("dct", "dst"):
                raise ValueError("n_keep (pruning) applies to c2c/r2c axes only")
            if self.n_keep < 1:
                raise ValueError(f"n_keep must be >= 1, got {self.n_keep}")

    # -- factories ----------------------------------------------------------

    @staticmethod
    def c2c(n_keep: int | None = None) -> "TransformSpec":
        return TransformSpec("c2c", n_keep=n_keep)

    @staticmethod
    def r2c(n_keep: int | None = None) -> "TransformSpec":
        return TransformSpec("r2c", n_keep=n_keep)

    @staticmethod
    def dct(trig_type: int = 2) -> "TransformSpec":
        return TransformSpec("dct", trig_type=trig_type)

    @staticmethod
    def dst(trig_type: int = 2) -> "TransformSpec":
        return TransformSpec("dst", trig_type=trig_type)

    @staticmethod
    def pruned(n_keep: int) -> "TransformSpec":
        """Truncated complex spectrum (centered keep): with a grid of
        ``n = 3*n_keep//2`` points this is the 3/2-rule dealiased axis.

        Note (even ``n_keep`` in a plan with an r2c axis): the kept set
        {-n_keep/2, …, n_keep/2-1} is not symmetric — the -n_keep/2 mode
        has no +n_keep/2 partner, so the irfft's Hermitian projection
        halves its kz=0-plane content per round trip.  Valid spectra keep
        that row zero (what dealiased pseudo-spectral solvers do anyway;
        mpi4py-fft's padded transforms share this convention)."""
        return TransformSpec("c2c", n_keep=n_keep)

    # -- properties ---------------------------------------------------------

    @property
    def real_to_real(self) -> bool:
        """Transform maps real -> real (complex blocks: re/im separately)."""
        return self.kind in ("dct", "dst")

    def spectral_extent(self, n: int) -> int:
        """Logical length of the forward output for an ``n``-point axis."""
        base = n // 2 + 1 if self.kind == "r2c" else n
        if self.n_keep is not None:
            if self.n_keep > base:
                raise ValueError(f"n_keep={self.n_keep} exceeds spectrum length {base} (n={n})")
            return self.n_keep
        return base

    def tag(self) -> str:
        """Stable string form (tuner cache keys, benchmark reports)."""
        if self.kind in ("dct", "dst"):
            return f"{self.kind}{self.trig_type}"
        return self.kind if self.n_keep is None else f"{self.kind}[{self.n_keep}]"


def as_spec(s) -> TransformSpec:
    """Coerce a user-facing transform description to a TransformSpec:
    accepts a TransformSpec or a tag string ("c2c", "r2c", "dct2", "dct3",
    "dst2", "dst3")."""
    if isinstance(s, TransformSpec):
        return s
    if isinstance(s, str):
        if s in ("c2c", "r2c"):
            return TransformSpec(s)
        if s in ("dct2", "dct3", "dst2", "dst3"):
            return TransformSpec(s[:3], trig_type=int(s[3]))
        raise ValueError(f"unknown transform tag {s!r}")
    raise TypeError(f"cannot interpret {s!r} as a TransformSpec")


def dealias_grid(n_keep: int) -> int:
    """Physical grid size of the 3/2-rule dealiased axis keeping ``n_keep``
    modes (the M of M = 3N/2)."""
    return (3 * n_keep) // 2


# ---------------------------------------------------------------------------
# Transform application
# ---------------------------------------------------------------------------


def local_transform(x, axis: int, sign: int, spec: TransformSpec, *, n: int,
                    impl: str = "jnp", nbatch: int = 0):
    """One stage of the plan along a locally-complete ``axis``.

    Forward (``sign == FORWARD``): input logical length ``n`` ->
    ``spec.spectral_extent(n)``.  Backward: the exact reverse.  Pruning
    (``spec.n_keep``) is folded in here — the forward gather / backward
    zero-scatter is emitted adjacent to the transform so it fuses with the
    surrounding exchange unpack instead of costing a separate HBM pass.

    ``nbatch`` leading axes of ``x`` are stacked field/batch axes and
    ``axis`` stays field-relative (the batched plan executor transforms
    all N fields of a stacked block in one vectorized call — every kernel
    here is axis-generic, so the batch rides for free).
    """
    axis = axis + nbatch
    if spec.kind == "c2c":
        if sign == FORWARD:
            y = _fft(x, axis, FORWARD, impl)
            if spec.n_keep is not None:
                y = _keep_centered(y, axis, spec.n_keep)
            return y
        if spec.n_keep is not None:
            x = _scatter_centered(x, axis, n, spec.n_keep)
        return _fft(x, axis, BACKWARD, impl)

    if spec.kind == "r2c":
        nbins = n // 2 + 1
        if sign == FORWARD:
            y = _rfft(x, axis, impl)
            if spec.n_keep is not None:
                y = jnp.take(y, jnp.arange(spec.n_keep), axis=axis)
            return y
        if spec.n_keep is not None and spec.n_keep < nbins:
            pads = [(0, 0)] * x.ndim
            pads[axis] = (0, nbins - spec.n_keep)
            x = jnp.pad(x, pads)
        return _irfft(x, axis, n, impl)

    # dct / dst: real-to-real, forward type 2 or 3, backward its inverse
    inverse = sign == BACKWARD
    trig_type = spec.trig_type if not inverse else {2: 3, 3: 2}[spec.trig_type]
    fn = _dct_complex_safe if spec.kind == "dct" else _dst_complex_safe
    return fn(x, axis, trig_type, impl, scale=(1.0 / (2 * n)) if inverse else 1.0)


# -- FFT vendor dispatch ----------------------------------------------------


def _fft(x, axis, sign, impl):
    if impl == "jnp":
        return jnp.fft.fft(x, axis=axis) if sign == FORWARD else jnp.fft.ifft(x, axis=axis)
    if impl == "matmul":
        from repro.kernels.fft import ops as fft_ops

        return fft_ops.fft_matmul(x, axis=axis, inverse=(sign == BACKWARD))
    raise ValueError(f"unknown fft impl {impl!r}")


def _rfft(x, axis, impl):
    if impl == "jnp":
        return jnp.fft.rfft(x, axis=axis)
    if impl == "matmul":
        from repro.kernels.fft import ops as fft_ops

        return fft_ops.rfft_matmul(x, axis=axis)
    raise ValueError(f"unknown fft impl {impl!r}")


def _irfft(x, axis, n, impl):
    if impl == "jnp":
        return jnp.fft.irfft(x, n=n, axis=axis)
    if impl == "matmul":
        from repro.kernels.fft import ops as fft_ops

        return fft_ops.irfft_matmul(x, n=n, axis=axis)
    raise ValueError(f"unknown fft impl {impl!r}")


# -- pruning (truncated spectra / 3/2-rule dealiasing) ----------------------


def _keep_centered(y, axis, k):
    """Keep the ``k`` lowest-|frequency| modes of an fft-ordered axis:
    the first ceil(k/2) (non-negative) and last floor(k/2) (negative)."""
    n = y.shape[axis]
    if k == n:
        return y
    head = (k + 1) // 2
    tail = k - head
    lo = jnp.take(y, jnp.arange(head), axis=axis)
    if tail == 0:
        return lo
    hi = jnp.take(y, jnp.arange(n - tail, n), axis=axis)
    return jnp.concatenate([lo, hi], axis=axis)


def _scatter_centered(y, axis, n, k):
    """Inverse of :func:`_keep_centered`: zero-pad the retained modes back
    into an ``n``-long fft-ordered axis."""
    if k == n:
        return y
    head = (k + 1) // 2
    tail = k - head
    lo = jnp.take(y, jnp.arange(head), axis=axis)
    mid_shape = list(y.shape)
    mid_shape[axis] = n - k
    mid = jnp.zeros(mid_shape, y.dtype)
    if tail == 0:
        return jnp.concatenate([lo, mid], axis=axis)
    hi = jnp.take(y, jnp.arange(head, k), axis=axis)
    return jnp.concatenate([lo, mid, hi], axis=axis)


# -- DCT / DST via the FFT-based even/odd extension trick -------------------


def _dct_complex_safe(x, axis, trig_type, impl, scale=1.0):
    if jnp.iscomplexobj(x):
        return (_dct_real(jnp.real(x), axis, trig_type, impl)
                + 1j * _dct_real(jnp.imag(x), axis, trig_type, impl)) * scale
    y = _dct_real(x, axis, trig_type, impl)
    return y * scale if scale != 1.0 else y


def _dst_complex_safe(x, axis, trig_type, impl, scale=1.0):
    """DST-II/III via the DCT: DST-II(x) = reverse(DCT-II((-1)^j x)),
    DST-III(x) = (-1)^k DCT-III(reverse(x)).  The matmul impl skips the
    reduction and applies the sine matrix in one shot."""
    if impl == "matmul":
        from repro.kernels.fft import ops as fft_ops

        y = fft_ops.dst_matmul(x, axis=axis, trig_type=trig_type)
        return y * scale if scale != 1.0 else y
    n = x.shape[axis]
    sgn = _alternating(n, x.ndim, axis)
    if trig_type == 2:
        y = _dct_complex_safe(x * sgn, axis, 2, impl, scale=scale)
        return jnp.flip(y, axis=axis)
    y = _dct_complex_safe(jnp.flip(x, axis=axis), axis, 3, impl, scale=scale)
    return y * sgn


def _alternating(n, ndim, axis):
    s = (-1.0) ** jnp.arange(n, dtype=jnp.float32)
    return s.reshape([n if i == axis % ndim else 1 for i in range(ndim)])


def _dct_real(x, axis, trig_type, impl):
    """Unnormalized (scipy-convention) DCT-II or DCT-III of a real block."""
    if impl == "matmul":
        from repro.kernels.fft import ops as fft_ops

        return fft_ops.dct_matmul(x, axis=axis, trig_type=trig_type)
    n = x.shape[axis]
    xl = jnp.moveaxis(x, axis, -1)
    if trig_type == 2:
        # Makhoul: permute to v = [x0, x2, ..., x5, x3, x1], one length-n FFT
        v = jnp.concatenate([xl[..., ::2], xl[..., 1::2][..., ::-1]], axis=-1)
        vf = jnp.fft.fft(v, axis=-1)
        k = jnp.arange(n)
        y = jnp.real(2 * jnp.exp(-1j * jnp.pi * k / (2 * n)) * vf)
    else:
        # DCT-III = 2n x the inverse of DCT-II (verified vs scipy)
        k = jnp.arange(n)
        xr = jnp.concatenate([jnp.zeros_like(xl[..., :1]), xl[..., :0:-1]], axis=-1)
        vf = 0.5 * jnp.exp(1j * jnp.pi * k / (2 * n)) * (xl - 1j * xr)
        v = jnp.real(jnp.fft.ifft(vf, axis=-1)) * (2 * n)
        h = (n + 1) // 2
        y = jnp.zeros_like(xl)
        y = y.at[..., ::2].set(v[..., :h])
        y = y.at[..., 1::2].set(v[..., h:][..., ::-1])
    return jnp.moveaxis(y.astype(x.dtype), -1, axis)
