"""Distributed multidimensional FFT — paper Secs. 3.3, 3.5, 3.6.

``ParallelFFT`` plans a d-dimensional transform of a global array decomposed
on a k-dimensional Cartesian mesh subgrid (k ≤ d-1): slab (k=1), pencil
(k=2), or higher.  The plan is the paper's schedule:

  forward:  F_{d-1} … F_k (local trailing axes), then for i = k-1 … 0:
            exchange(v=i+1 → w=i over subgroup P_i); F_i
  backward: the exact reverse (paper Eq. 8 / Eqs. 26–32).

Every exchange is one call to :func:`repro.core.redistribute.exchange_shard`
— the same ~40-line routine regardless of dimensionality, which is the
paper's headline simplicity claim.  ``method`` selects the paper's fused
all-to-all ("fused"), the traditional transpose+all-to-all baseline
("traditional"), the sliced exchange interleaved with the next stage's 1-D
FFTs ("pipelined", comm/compute overlap), or the autotuned per-stage mix
("auto", see :mod:`repro.core.tuner`).

Per-axis transforms (``transforms=``): each axis carries a
:class:`repro.core.fftcore.TransformSpec` — c2c, r2c, DCT-II/III, DST-II/III,
or a pruned/truncated spectrum (``n_keep``).  ``real=True`` stays as sugar
for "r2c on the last axis, c2c elsewhere".  Pruned axes fold 3/2-rule
dealiasing into the plan itself: the truncation happens inside the FFT
stage right next to the exchange unpack, so downstream exchanges ship only
the retained modes (the dealiased Navier–Stokes pipeline pays *less* wire
traffic than the undealiased one, not an extra HBM pass).  Spectral extents
therefore differ stage by stage between the forward and backward plans;
``pencil_trace``/``dtype_trace`` record the (extent, dtype) state before
every stage and all analytic models read them.

The whole plan executes inside a single ``shard_map``, so XLA sees the
entire FFT↔collective pipeline and can schedule/overlap it (the TPU
equivalent of taking data rearrangement off the critical path).

Batched multi-field execution (``forward_many``/``backward_many``): real
spectral workloads run the *same* plan over many fields at once (the
Navier–Stokes example transforms u, v, w plus nonlinear products through
identical stages).  ``forward``/``backward`` accept a leading batch axis
and ``forward_many``/``backward_many`` additionally accept a pytree of
fields; the executor runs the whole batch through one ``shard_map`` whose
per-stage behavior is the plan's ``batch_fusion`` mode:

``"stacked"`` (default)        — every exchange ships the stacked payload
    of all N fields in **one** all-to-all (message aggregation; a lossy
    ``comm_dtype`` codec runs once over the stacked block), and FFT stages
    transform all fields in one vectorized call.  Bit-identical to the
    per-field loop for lossless payloads.  Wins when exchanges are
    latency-bound (small per-field messages).
``"pipelined-across-fields"``  — per-field collectives emitted interleaved
    with the previous field's 1-D FFT, so collective DMA overlaps MXU
    compute even when per-field slicing (``method="pipelined"``) is too
    fine.  Wins when stages are compute-heavy.
``"per-field"``                — N serialized exchange+FFT pairs inside
    one jit (the baseline the other modes are judged against).

``method="auto"`` prices all three: the tuned schedule is a
:class:`~repro.core.planconfig.StageEntry` — ``(method, chunks,
comm_dtype, impl, batch_fusion)`` — per stage, cached per batch size
(see :mod:`repro.core.tuner`).
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from functools import cached_property, partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import fftcore
from repro.core.fftcore import TransformSpec, as_spec
from repro.core.meshutil import shard_map
from repro.core.decomp import pad_to_multiple
from repro.core.pencil import Group, Pencil, group_names, group_size, make_pencil, pad_global, unpad_global
from repro.core.planconfig import PlanConfig, StageEntry, as_schedule
from repro.core.quant import canonical_comm_dtype
from repro.core.redistribute import exchange_shard, exchange_shard_sliced
from repro.robustness import faults as _faults, health as _health

#: StageEntry per ExchangeStage, in forward stage order (legacy raw
#: 3/4-tuples are upgraded on entry via StageEntry.make — see planconfig)
Schedule = tuple[StageEntry, ...]

#: alias kept for the batch-aware schedule of a multi-field execution
#: (see batched_schedule); since StageEntry carries batch_fusion, the two
#: schedule types are now the same shape
BatchedSchedule = tuple[StageEntry, ...]

_UNSET = object()

# once-per-process deprecation flags (module state, not per-plan)
_legacy_kwargs_warned = False
_real_kwarg_warned = False


def _warn_once(flag_name: str, msg: str):
    g = globals()
    if not g[flag_name]:
        g[flag_name] = True
        warnings.warn(msg, DeprecationWarning, stacklevel=3)

# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FFTStage:
    axis: int
    spec: TransformSpec
    n: int  # full (physical-grid) transform length; spectral extent is spec.spectral_extent(n)


@dataclass(frozen=True)
class ExchangeStage:
    v: int
    w: int
    group: Group


Stage = FFTStage | ExchangeStage


class ParallelFFT:
    """Plan + executor for a distributed d-dim transform.

    Args:
      mesh:   jax Mesh (any dimensionality; unrelated axes are untouched).
      shape:  logical global array shape (d axes) — the *physical-grid*
              extents; pruned axes emit fewer spectral modes than this.
      grid:   k mesh axis names (or tuples of names) decomposing array axes
              0..k-1, k ≤ d-1.  (C row-major convention, like the paper.)
      config: a :class:`~repro.core.planconfig.PlanConfig` carrying every
              execution knob — method, FFT impl, exchange_impl, chunks,
              comm_dtype, batch_fusion, tuner_cache, guard (see its
              docstring for field semantics).  This is the supported
              surface; ``config=None`` means ``PlanConfig()`` defaults.
      transforms: per-axis :class:`TransformSpec` (or tag strings "c2c",
              "r2c", "dct2", "dct3", "dst2", "dst3"), length d.  Transforms
              are applied in descending axis order; an r2c axis must come
              before any complex-producing axis in that order (i.e. every
              axis to its right is dct/dst), and at most one r2c is
              allowed.  Mutually exclusive with ``real=True``.

    Deprecated (still functional, each warns once per process):

      real:   sugar for ``transforms`` = all-c2c with r2c on the last
              axis; pass the explicit ``transforms=`` spec instead.
      method / impl / exchange_impl / chunks / comm_dtype / batch_fusion /
      tuner_cache / guard: the pre-PlanConfig kwarg sprawl.  Passing any
              of them forwards into ``PlanConfig.from_legacy_kwargs`` (so
              behavior is identical to the config= path); combining them
              with ``config=`` is an error.

    The resolved config is ``plan.config``; its fields stay mirrored as
    ``plan.method`` / ``plan.impl`` / ``plan.exchange_impl`` /
    ``plan.chunks`` / ``plan.comm_dtype`` / ``plan.batch_fusion`` /
    ``plan.tuner_cache`` / ``plan.guard`` for downstream consumers.
    Guarded plans' ``forward``/``backward`` (and the ``_many`` variants)
    return ``(result, HealthReport)``.
    """

    def __init__(
        self,
        mesh: Mesh,
        shape: tuple[int, ...],
        grid: tuple[Group, ...],
        *,
        config: PlanConfig | None = None,
        transforms=None,
        real: bool = False,
        method: str | None = None,
        impl: str | None = None,
        exchange_impl: str | None = None,
        chunks: int | None = None,
        comm_dtype=_UNSET,
        batch_fusion: str | None = None,
        tuner_cache=_UNSET,
        guard: str | None = None,
    ):
        d, k = len(shape), len(grid)
        if not 1 <= k <= d - 1:
            raise ValueError(f"need 1 <= len(grid)={k} <= d-1={d - 1}")
        legacy = {k_: v for k_, v in dict(
            method=method, impl=impl, exchange_impl=exchange_impl,
            chunks=chunks, batch_fusion=batch_fusion, guard=guard).items()
            if v is not None}
        if comm_dtype is not _UNSET:
            legacy["comm_dtype"] = comm_dtype
        if tuner_cache is not _UNSET:
            legacy["tuner_cache"] = tuner_cache
        if legacy:
            if config is not None:
                raise ValueError(
                    f"pass either config= or the legacy kwargs {sorted(legacy)}, not both")
            _warn_once(
                "_legacy_kwargs_warned",
                f"ParallelFFT execution kwargs ({sorted(legacy)}) are deprecated; "
                "pass config=PlanConfig(...) instead")
            config = PlanConfig.from_legacy_kwargs(**legacy)
        elif config is None:
            config = PlanConfig()
        if real:
            _warn_once(
                "_real_kwarg_warned",
                "ParallelFFT(real=True) is deprecated; pass transforms= "
                "('c2c', ..., 'r2c') instead")
        if transforms is not None:
            if real:
                raise ValueError("pass either real=True or transforms=, not both")
            specs = tuple(as_spec(s) for s in transforms)
            if len(specs) != d:
                raise ValueError(f"transforms must have one spec per axis: got {len(specs)}, need {d}")
        else:
            specs = tuple(
                TransformSpec.r2c() if (real and a == d - 1) else TransformSpec.c2c()
                for a in range(d)
            )
        # dtype legality in apply order (axis d-1 → 0): r2c must see real data
        seen_complex = False
        for a in range(d - 1, -1, -1):
            if specs[a].kind == "r2c":
                if seen_complex:
                    raise ValueError(
                        f"r2c on axis {a} would see complex data: every axis after it "
                        f"(higher index) must be dct/dst, and only one r2c is allowed")
                seen_complex = True
            elif specs[a].kind == "c2c":
                seen_complex = True
        self.transforms = specs
        self.mesh, self.shape, self.grid = mesh, tuple(shape), tuple(grid)
        # config is the source of truth; the mirrors keep every downstream
        # consumer (tuner, planlint, benchmarks, tests) on its old surface
        self.config = config
        self.method, self.impl = config.method, config.impl
        self.exchange_impl = config.exchange_impl
        self.chunks, self.tuner_cache = config.chunks, config.tuner_cache
        self.comm_dtype = config.comm_dtype
        self.batch_fusion = config.batch_fusion
        self.guard = config.guard
        self.d, self.k = d, k
        self._batched_sched_memo: dict[int, BatchedSchedule] = {}
        self._batched_exec: dict = {}
        self._guarded_exec: dict = {}

        sizes = [group_size(mesh, g) for g in grid]
        # Per-axis divisibility: every subgroup an axis is ever distributed
        # over, in either direction of the plan (see DESIGN.md §7).
        divisors = [1] * d
        for j in range(k):
            divisors[j] = math.lcm(divisors[j], sizes[j])  # initial placement
        for j in range(1, k + 1):
            divisors[j] = math.lcm(divisors[j], sizes[j - 1])  # gained at exchange
        # subgroup an axis is split over *after* its own transform (the one
        # the spectral extent must stay divisible by)
        future_div = [sizes[j - 1] if 1 <= j <= k else 1 for j in range(d)]

        placement: list[Group | None] = [grid[i] if i < k else None for i in range(d)]
        self.input_pencil = make_pencil(mesh, self.shape, tuple(placement), divisors=tuple(divisors))
        self._divisors = tuple(divisors)

        # input/spectral dtypes: real input iff the first applied transform
        # that produces complex output is r2c (or no axis ever goes complex)
        first_complex = next((specs[a].kind for a in range(d - 1, -1, -1)
                              if not specs[a].real_to_real), None)
        in_real = first_complex in (None, "r2c")
        out_real = first_complex is None

        # Forward schedule + pencil/dtype trace.  pencil_trace[i] /
        # dtype_trace[i] describe the block *before* stages[i].
        stages: list[Stage] = []
        pencils: list[Pencil] = [self.input_pencil]
        dtypes: list = [jnp.float32 if in_real else jnp.complex64]
        cur = self.input_pencil
        cur_dt = dtypes[0]

        def push_fft(axis: int):
            nonlocal cur, cur_dt
            sp = specs[axis]
            n = self.shape[axis]
            stages.append(FFTStage(axis, sp, n))
            ext = sp.spectral_extent(n)
            if ext != cur.logical[axis]:
                cur = cur.with_axis_extent(axis, ext)
                cur = _repad(cur, axis, future_div[axis])
            if not sp.real_to_real:
                cur_dt = jnp.complex64
            pencils.append(cur)
            dtypes.append(cur_dt)

        for axis in range(d - 1, k - 1, -1):  # trailing local axes
            push_fft(axis)
        for i in range(k - 1, -1, -1):
            stages.append(ExchangeStage(v=i + 1, w=i, group=grid[i]))
            cur = cur.exchanged(i + 1, i)
            pencils.append(cur)
            dtypes.append(cur_dt)
            push_fft(i)
        self.stages = tuple(stages)
        self.pencil_trace = tuple(pencils)
        self.dtype_trace = tuple(dtypes)
        self.output_pencil = cur
        self.input_dtype = dtypes[0]
        self.spectral_dtype = jnp.float32 if out_real else jnp.complex64

    # -- schedule ------------------------------------------------------------

    @property
    def n_exchanges(self) -> int:
        return sum(isinstance(s, ExchangeStage) for s in self.stages)

    @cached_property
    def schedule(self) -> Schedule:
        """:class:`StageEntry` per exchange stage, forward order.  Uniform
        for the explicit methods; tuned (and disk-cached) for
        method="auto", where ``comm_dtype`` is the per-stage payload the
        tuner picked within the plan's accuracy budget and ``impl`` is
        swept only within the plan's ``exchange_impl`` candidate budget."""
        if self.method == "auto":
            from repro.core import tuner

            return as_schedule(tuner.get_or_tune(self, cache_path=self.tuner_cache))
        entry = self.config.stage_entry()._replace(batch_fusion="stacked")
        return (entry,) * self.n_exchanges

    def batched_schedule(self, nfields: int) -> BatchedSchedule:
        """:class:`StageEntry` per exchange stage for an ``nfields``-field
        execution, forward order.  Explicit methods use the plan's uniform
        ``batch_fusion``; method="auto" tunes the full batch-aware
        candidate space per stage, cached per batch size."""
        if nfields <= 1:
            return tuple(e._replace(batch_fusion="stacked") for e in self.schedule)
        if nfields not in self._batched_sched_memo:
            if self.method == "auto":
                from repro.core import tuner

                sched = as_schedule(tuner.get_or_tune(
                    self, cache_path=self.tuner_cache, nfields=nfields))
            else:
                sched = (self.config.stage_entry(),) * self.n_exchanges
            self._batched_sched_memo[nfields] = sched
        return self._batched_sched_memo[nfields]

    # -- executors ----------------------------------------------------------

    @cached_property
    def _forward_shard(self):
        return partial(_run_stages, stages=self.stages, pencils=self.pencil_trace,
                       schedule=self.schedule, impl=self.impl, sign=fftcore.FORWARD)

    @cached_property
    def _backward_shard(self):
        stages, pencils = _reverse_plan(self.stages, self.pencil_trace)
        return partial(_run_stages, stages=stages, pencils=pencils,
                       schedule=self.schedule[::-1], impl=self.impl,
                       sign=fftcore.BACKWARD)

    @cached_property
    def forward_padded(self):
        """shard_map'd forward on *physical* (padded) global arrays."""
        return shard_map(
            self._forward_shard, mesh=self.mesh,
            in_specs=self.input_pencil.spec, out_specs=self.output_pencil.spec,
            check_vma=False,
        )

    @cached_property
    def backward_padded(self):
        return shard_map(
            self._backward_shard, mesh=self.mesh,
            in_specs=self.output_pencil.spec, out_specs=self.input_pencil.spec,
            check_vma=False,
        )

    def forward_many_padded(self, nfields: int):
        """shard_map'd batched forward on a ``(nfields, *physical)`` stacked
        block (leading batch axis replicated; built/cached per batch size)."""
        return self._many_padded(nfields, "forward")

    def backward_many_padded(self, nfields: int):
        return self._many_padded(nfields, "backward")

    def _many_padded(self, nfields: int, direction: str):
        key = (nfields, direction)
        if key not in self._batched_exec:
            schedule = self.batched_schedule(nfields)
            if direction == "forward":
                stages, pencils = self.stages, self.pencil_trace
                in_pen, out_pen, sign = self.input_pencil, self.output_pencil, fftcore.FORWARD
            else:
                stages, pencils = _reverse_plan(self.stages, self.pencil_trace)
                schedule = schedule[::-1]
                in_pen, out_pen, sign = self.output_pencil, self.input_pencil, fftcore.BACKWARD
            fn = partial(_run_stages, stages=stages, pencils=pencils,
                         schedule=schedule, impl=self.impl, sign=sign, nbatch=1)
            self._batched_exec[key] = shard_map(
                fn, mesh=self.mesh, in_specs=in_pen.batched_spec(),
                out_specs=out_pen.batched_spec(), check_vma=False)
        return self._batched_exec[key]

    def guarded_padded(self, direction: str = "forward", *, schedule=None,
                       nfields: int = 1):
        """shard_map'd guarded executor on physical (padded) blocks:
        returns ``fn(block) -> (block, stats)`` where ``stats`` carries
        every shard's packed guard-stat partial (sharded out_spec, no
        extra collective); :func:`repro.robustness.health.unpack_partials`
        sums them for :func:`~repro.robustness.health.build_report`.
        ``schedule`` overrides the plan's resolved schedule — the
        degradation ladder re-executes through here with widened entries;
        executors are cached per (direction, schedule, nfields)."""
        if schedule is None:
            schedule = (self.batched_schedule(nfields) if nfields > 1
                        else self.schedule)
        schedule = as_schedule(schedule)
        key = (direction, schedule, nfields)
        if key not in self._guarded_exec:
            nbatch = 1 if nfields > 1 else 0
            if direction == "forward":
                stages, pencils, sched = self.stages, self.pencil_trace, schedule
                in_pen, out_pen, sign = self.input_pencil, self.output_pencil, fftcore.FORWARD
            else:
                stages, pencils = _reverse_plan(self.stages, self.pencil_trace)
                sched = schedule[::-1]
                in_pen, out_pen, sign = self.output_pencil, self.input_pencil, fftcore.BACKWARD
            guard_axes = tuple(n for g in self.grid for n in group_names(g))

            def guarded_fn(block, *, _stages=stages, _pencils=pencils,
                           _sched=sched, _sign=sign):
                return _run_stages(block, stages=_stages, pencils=_pencils,
                                   schedule=_sched, impl=self.impl,
                                   sign=_sign, nbatch=nbatch, guard=True)

            # shard-local stat vectors concatenate along axis 0 — the
            # runner sums the partials on the host, so the guarded hot
            # path carries no stats collective at all
            stats_spec = P(guard_axes) if guard_axes else P()
            self._guarded_exec[key] = shard_map(
                guarded_fn, mesh=self.mesh,
                in_specs=in_pen.batched_spec(nbatch),
                out_specs=(out_pen.batched_spec(nbatch), stats_spec),
                check_vma=False)
        return self._guarded_exec[key]

    def warm(self, directions=("forward", "backward"), *,
             nfields: int = 1) -> int:
        """Precompile the plan's hot executors by running each requested
        direction once on a zero block — schedule resolution (including a
        tuner sweep for ``method="auto"``), tracing, compilation and
        weight transfer all happen here instead of on the first real
        request (the serving registry's warm start).  Guarded plans warm
        the guarded executor — the one :func:`~repro.robustness.runner.
        run_guarded` dispatches to; ``nfields > 1`` warms the batched
        multi-field executor for that batch size.  Returns the number of
        executors exercised."""
        n = 0
        for direction in directions:
            if direction == "forward":
                pen, dt = self.input_pencil, self.input_dtype
            elif direction == "backward":
                pen, dt = self.output_pencil, self.spectral_dtype
            else:
                raise ValueError(f"unknown direction {direction!r}")
            shape = ((nfields,) if nfields > 1 else ()) + pen.physical
            shard = pen.batched_sharding(1) if nfields > 1 else pen.sharding
            xpad = jax.device_put(jnp.zeros(shape, dt), shard)
            if self.guard != "off":
                out = self.guarded_padded(direction, nfields=nfields)(xpad)
            elif nfields > 1:
                out = self._many_padded(nfields, direction)(xpad)
            elif direction == "forward":
                out = self.forward_padded(xpad)
            else:
                out = self.backward_padded(xpad)
            jax.block_until_ready(out)
            n += 1
        return n

    def forward(self, x: jax.Array) -> jax.Array:
        """Logical-shape convenience wrapper (pads, transforms, unpads).
        A ``d+1``-dim input is treated as a stack of fields along a leading
        batch axis and routed through the batched executor.  When the plan
        was built with ``guard != "off"`` this returns
        ``(result, HealthReport)`` instead (see :mod:`repro.robustness`)."""
        if x.ndim == self.d + 1:
            return self.forward_many(x)
        x = x.astype(self.input_dtype)
        xpad = pad_global(x, self.input_pencil)
        if self.guard != "off":
            from repro.robustness import runner

            y, report = runner.run_guarded(self, xpad, "forward")
            return unpad_global(y, self.output_pencil), report
        y = self.forward_padded(xpad)
        return unpad_global(y, self.output_pencil)

    def backward(self, x: jax.Array) -> jax.Array:
        if x.ndim == self.d + 1:
            return self.backward_many(x)
        xpad = pad_global(x.astype(self.spectral_dtype), self.output_pencil)
        if self.guard != "off":
            from repro.robustness import runner

            y, report = runner.run_guarded(self, xpad, "backward")
            return unpad_global(y, self.input_pencil), report
        y = self.backward_padded(xpad)
        return unpad_global(y, self.input_pencil)

    def forward_many(self, xs):
        """Transform N fields through one batched plan execution.

        ``xs`` is either one array with a leading batch axis
        (``(N, *shape)``) or a pytree (list/tuple/dict/...) of N
        logical-shape fields; the result mirrors the input structure.
        Every exchange stage ships all N fields per its batched-schedule
        entry — one collective per stage under ``batch_fusion="stacked"``
        instead of the N a per-field loop issues."""
        return self._apply_many(xs, "forward")

    def backward_many(self, xs):
        return self._apply_many(xs, "backward")

    def _apply_many(self, xs, direction: str):
        if direction == "forward":
            in_pen, out_pen, dt = self.input_pencil, self.output_pencil, self.input_dtype
        else:
            in_pen, out_pen, dt = self.output_pencil, self.input_pencil, self.spectral_dtype
        if hasattr(xs, "ndim"):  # stacked array, not a pytree of fields
            if xs.ndim != self.d + 1:
                raise ValueError(
                    f"stacked {direction} input must be (nfields, *{in_pen.logical}); "
                    f"got ndim={xs.ndim} for a d={self.d} plan")
            stacked, treedef = xs.astype(dt), None
        else:
            leaves, treedef = jax.tree_util.tree_flatten(xs)
            if not leaves:
                raise ValueError(f"{direction}_many needs at least one field")
            stacked = jnp.stack([jnp.asarray(leaf).astype(dt) for leaf in leaves])
        nfields = stacked.shape[0]
        xpad = pad_global(stacked, in_pen, nbatch=1)
        report = None
        if self.guard != "off":
            from repro.robustness import runner

            if nfields == 1:  # guarded executors key nbatch off nfields
                y, report = runner.run_guarded(self, xpad[0], direction)
                y = y[None]
            else:
                y, report = runner.run_guarded(self, xpad, direction,
                                               nfields=nfields)
        else:
            y = self._many_padded(nfields, direction)(xpad)
        y = unpad_global(y, out_pen, nbatch=1)
        if treedef is not None:
            y = jax.tree_util.tree_unflatten(
                treedef, [y[i] for i in range(nfields)])
        return y if report is None else (y, report)

    # -- analysis -----------------------------------------------------------

    def model_flops(self, nfields: int = 1) -> float:
        """5 N log2 N per 1-D transform, summed over the plan (the classic
        FFT nominal-flops convention; stages transforming real data — r2c
        and dct/dst on a still-real block — counted as half).  ``nfields``
        scales the whole plan for a batched multi-field execution (every
        field walks identical stage traces)."""
        return nfields * sum(self._stage_flops_at(i) for i, st in enumerate(self.stages)
                             if isinstance(st, FFTStage))

    def _stage_flops_at(self, i: int, stages=None, pencils=None, dtypes=None) -> float:
        """Nominal flops of FFT stage ``i`` of a plan walk: 5 n log2 n per
        transform × the batch of the other axes' *current* logical extents
        (read off the pencil trace, so pruned/Hermitian-reduced axes count
        at their truncated extent once truncated)."""
        stages = stages if stages is not None else self.stages
        pencils = pencils if pencils is not None else self.pencil_trace
        dtypes = dtypes if dtypes is not None else self.dtype_trace
        st = stages[i]
        before = pencils[i]
        n = st.n
        batch = 1.0
        for ax, ext in enumerate(before.logical):
            if ax != st.axis:
                batch *= ext
        flops = 5.0 * n * math.log2(max(n, 2)) * batch
        if st.spec.kind == "r2c" or dtypes[i] == jnp.float32:
            flops *= 0.5  # transform of real data
        return flops

    def _stage_itemsize(self, i: int, dtypes=None) -> int:
        dtypes = dtypes if dtypes is not None else self.dtype_trace
        return 8 if dtypes[i] == jnp.complex64 else 4

    def comm_bytes_per_device(
        self, itemsize: int | None = None, *, method: str | None = None,
        comm_dtype: str | None = None, nfields: int = 1,
    ) -> int:
        """Wire bytes each device sends across all exchanges (roofline
        term), at the narrowed payload width of each stage's ``comm_dtype``
        (default: the plan's resolved schedule — per-stage tuned payloads
        for method="auto", the uniform policy otherwise; pass
        ``comm_dtype`` to price a hypothetical uniform payload).  The
        element count is method-independent; ``method`` adds the
        materialized local-copy traffic the engine pays on top
        (traditional: pack+unpack; pipelined: slice concat; fused: none).
        ``itemsize=None`` prices each stage at its traced dtype width
        (complex64 exchanges at 8, still-real f32 exchanges at 4).
        ``nfields`` prices a batched multi-field execution (stacked wire
        payload and N× local-copy traffic)."""
        from repro.core.redistribute import (
            exchange_local_copy_elems, exchange_wire_bytes, pipeline_slices)

        if comm_dtype is None:
            batched = self._batched_sched_memo.get(nfields) if nfields > 1 else None
            if batched is not None:
                # a resolved batched schedule carries the per-stage tuned
                # payloads of *this* batch size
                entries = [tuple(e)[:3] for e in as_schedule(batched)]
            elif self.method == "auto" and "schedule" not in self.__dict__:
                # stay pure arithmetic: a byte count must never trigger the
                # tuner; price the uniform budget until a schedule exists
                entries = [("fused", 1, self.comm_dtype)] * self.n_exchanges
            else:
                entries = [tuple(e)[:3] for e in self.schedule]
        else:
            entries = [("fused", 1, canonical_comm_dtype(comm_dtype))] * self.n_exchanges
        total, ex_i = 0, 0
        for i, st in enumerate(self.stages):
            if isinstance(st, ExchangeStage):
                isz = itemsize if itemsize is not None else self._stage_itemsize(i)
                e_method, e_chunks, e_dtype = entries[ex_i]
                slices = (pipeline_slices(self.pencil_trace[i], st.v, st.w,
                                          chunks=e_chunks)
                          if e_method == "pipelined" else 1)
                total += exchange_wire_bytes(self.pencil_trace[i], st.v, st.w,
                                             itemsize=isz, comm_dtype=e_dtype,
                                             nfields=nfields, slices=slices)
                ex_i += 1
                if method is not None:
                    total += exchange_local_copy_elems(
                        self.pencil_trace[i], st.v, st.w, method=method) * isz * nfields
        return total

    def model_time_s(
        self,
        *,
        itemsize: int | None = None,
        peak_flops: float = 197e12,
        ici_bw: float = 50e9,
        hbm_bw: float = 819e9,
        ici_latency_s: float | None = None,
        schedule: Schedule | None = None,
        direction: str = "forward",
        nfields: int = 1,
        batch_fusion: str | None = None,
        exchange_only: bool = False,
    ) -> float:
        """Overlap-aware modeled wall time of one transform: FFT stages at
        ``peak_flops``; each exchange via
        :func:`repro.core.redistribute.exchange_time_model`, which credits a
        pipelined exchange with hiding the following stage's FFT compute.
        ``direction="backward"`` walks the reversed plan (whose per-stage
        logical extents and overlap pairings differ for pruned/r2c axes);
        ``itemsize=None`` prices each exchange at its traced dtype width.

        ``nfields > 1`` prices a batched multi-field execution; each stage's
        fusion mode comes from the (possibly 4-field) ``schedule`` entries,
        or uniformly from ``batch_fusion`` when given — stacked exchanges
        pay one collective latency for all fields, pipelined-across-fields
        hides per-field collectives under the previous field's FFT.

        The hardware coefficients (``peak_flops`` / ``ici_bw`` / ``hbm_bw``
        / ``ici_latency_s``) are free parameters so the scaling harness
        (:mod:`repro.core.modelfit`) can least-squares fit them against
        measured sweeps; ``exchange_only=True`` prices the exchanges-only
        executor fftbench times under ``--measure redistribution`` (FFT
        stages contribute nothing and no overlap credit applies)."""
        from repro.core.redistribute import ICI_LATENCY_S, exchange_time_model

        if ici_latency_s is None:
            ici_latency_s = ICI_LATENCY_S

        if schedule is None:
            schedule = self.batched_schedule(nfields) if nfields > 1 else self.schedule
        if direction == "forward":
            stages, pencils, dtypes = self.stages, self.pencil_trace, self.dtype_trace
        elif direction == "backward":
            stages, pencils = _reverse_plan(self.stages, self.pencil_trace)
            dtypes = self.dtype_trace[::-1]
            schedule = schedule[::-1]
        else:
            raise ValueError(f"unknown direction {direction!r}")
        ndev = group_size(self.mesh, tuple(n for g in self.grid for n in
                                           ((g,) if isinstance(g, str) else g)))
        total, ex_i, i = 0.0, 0, 0
        while i < len(stages):
            st = stages[i]
            if isinstance(st, ExchangeStage):
                entry = StageEntry.make(schedule[ex_i])
                method, chunks, comm_dtype, ex_impl, fusion = entry
                if batch_fusion is not None:
                    fusion = batch_fusion
                ex_i += 1
                src_pen = pencils[i]  # state before this exchange
                isz = itemsize if itemsize is not None else self._stage_itemsize(i, dtypes)
                nxt = stages[i + 1] if i + 1 < len(stages) else None
                fft_s = 0.0
                if isinstance(nxt, FFTStage) and nxt.axis == st.w:
                    if not exchange_only:
                        fft_s = (self._stage_flops_at(i + 1, stages, pencils, dtypes)
                                 / ndev / peak_flops)
                    i += 1  # folded into the exchange term
                total += exchange_time_model(
                    src_pen, st.v, st.w, itemsize=isz, method=method,
                    chunks=chunks, comm_dtype=comm_dtype, impl=ex_impl,
                    ici_bw=ici_bw, hbm_bw=hbm_bw, ici_latency_s=ici_latency_s,
                    overlap_compute_s=fft_s,
                    nfields=nfields, batch_fusion=fusion)
            elif not exchange_only:
                total += nfields * self._stage_flops_at(i, stages, pencils, dtypes) / ndev / peak_flops
            i += 1
        return total

    def model_collective_launches(
        self, *, nfields: int = 1, schedule: Schedule | None = None,
        batch_fusion: str | None = None, direction: str = "forward",
    ) -> int:
        """Total latency-priced collective launches one transform issues
        under its (resolved) schedule — the exact multiplier
        :meth:`model_time_s` applies to ``ici_latency_s``, exposed so the
        scaling harness can fit the latency coefficient from measured
        sweeps (see :func:`repro.core.redistribute
        .exchange_collective_launches` for the per-exchange accounting)."""
        from repro.core.redistribute import exchange_collective_launches

        if schedule is None:
            schedule = self.batched_schedule(nfields) if nfields > 1 else self.schedule
        if direction == "backward":
            schedule = schedule[::-1]
        elif direction != "forward":
            raise ValueError(f"unknown direction {direction!r}")
        total, ex_i = 0, 0
        for i, st in enumerate(self.stages):
            if not isinstance(st, ExchangeStage):
                continue
            entry = StageEntry.make(schedule[ex_i])
            ex_i += 1
            fusion = batch_fusion if batch_fusion is not None else entry.batch_fusion
            total += exchange_collective_launches(
                self.pencil_trace[i], st.v, st.w, method=entry.method,
                chunks=entry.chunks, nfields=nfields, batch_fusion=fusion)
        return total

    def audit(self, *, nfields: int = 1, direction: str = "forward",
              schedule=None):
        """Statically audit this plan's compiled artifact against its
        schedule contracts (collective counts, wire bytes, the
        no-realignment invariant, dtype flow).  Convenience wrapper around
        :func:`repro.analysis.planlint.audit_plan`; returns its
        :class:`~repro.analysis.planlint.AuditReport`."""
        from repro.analysis.planlint import audit_plan

        return audit_plan(self, nfields=nfields, direction=direction,
                          schedule=schedule)


def _repad(pencil: Pencil, axis: int, divisor: int) -> Pencil:
    m = divisor
    if pencil.placement[axis] is not None:
        m = math.lcm(m, group_size(pencil.mesh, pencil.placement[axis]))
    new_physical = list(pencil.physical)
    new_physical[axis] = pad_to_multiple(pencil.logical[axis], m)
    from dataclasses import replace

    return replace(pencil, physical=tuple(new_physical))


def _reverse_plan(stages, pencils):
    """Backward schedule: reverse stage order; exchanges swap v/w; each FFT
    stage keeps its spec — the BACKWARD sign selects the inverse transform
    (ifft, c2r, DCT/DST inverse, pruned zero-scatter)."""
    rev_stages: list[Stage] = []
    rev_pencils: list[Pencil] = [pencils[-1]]
    # pencils[i] is the state *before* stages[i]; build reversed trace.
    for idx in range(len(stages) - 1, -1, -1):
        st = stages[idx]
        before = pencils[idx]
        if isinstance(st, ExchangeStage):
            rev_stages.append(ExchangeStage(v=st.w, w=st.v, group=st.group))
        else:
            rev_stages.append(st)
        rev_pencils.append(before)
    return tuple(rev_stages), tuple(rev_pencils)


def _run_stages(block, *, stages, pencils, schedule, impl, sign, nbatch=0,
                guard=False):
    """Execute the plan on one shard (inside shard_map).  ``schedule`` gives
    a :class:`StageEntry` (or any legacy tuple form) per exchange stage, in
    this plan's stage order; each exchange is emitted together with the FFT of
    its newly-aligned axis (always the next stage in forward and backward
    plans) so the engine can interleave collective and compute — per slice
    for method="pipelined", per field for batch_fusion="pipelined-across-
    fields".  ``nbatch=1`` executes a stacked multi-field block: FFT stages
    transform all fields in one vectorized call and exchange stages follow
    their schedule entry's batch_fusion mode.

    ``guard=True`` additionally returns this shard's packed guard-stat
    vector (:func:`repro.robustness.health.pack_stats`): the always-on
    output probe, plus — only when the schedule has lossy wire stages —
    the pre/post block-energy Parseval bracket and the per-stage
    non-finite/saturation counters.  No collective is emitted for it —
    the guarded executor's sharded out_spec hands the runner every
    shard's partial and the host sums them."""
    cur = pencils[0]
    per_stage = []
    lossy = guard and _health.schedule_is_lossy(as_schedule(schedule))
    energy_in = _health.block_energy(block) if lossy else jnp.float32(0.0)
    ex_i = i = 0
    while i < len(stages):
        st = stages[i]
        if isinstance(st, ExchangeStage):
            entry = StageEntry.make(schedule[ex_i])
            nxt_st = stages[i + 1] if i + 1 < len(stages) else None
            fft_st = nxt_st if isinstance(nxt_st, FFTStage) and nxt_st.axis == st.w else None
            block, used_fft, stats = _run_exchange_stage(
                block, st, fft_st, pencils[i + 1],
                pencils[i + 2] if fft_st is not None else None,
                entry, impl=impl, sign=sign, nbatch=nbatch, guard=guard,
                stage_index=ex_i)
            ex_i += 1
            if guard:
                per_stage.append(stats)
            i += 2 if used_fft else 1
        else:
            block = _fft_padded_axis(block, st, cur, pencils[i + 1], impl=impl,
                                     sign=sign, nbatch=nbatch)
            i += 1
        cur = pencils[i]
    if not guard:
        return block
    energy_out = _health.block_energy(block) if lossy else jnp.float32(0.0)
    last = stages[-1]
    probe_axis = last.axis + nbatch if isinstance(last, FFTStage) else None
    probe = _health.output_probe(block, probe_axis)
    return block, _health.pack_stats(per_stage, energy_in, energy_out, probe)


def _run_exchange_stage(block, ex: ExchangeStage, fft_st: FFTStage | None,
                        mid: Pencil, after: Pencil | None, entry, *,
                        impl, sign, nbatch, guard=False, stage_index=None):
    """One exchange stage (+ the FFT of its newly-aligned axis, when
    ``fft_st`` is given), under one :class:`StageEntry` schedule entry.  Returns ``(block, used_fft, stats)``
    where ``stats`` is the stage's guard-counter dict (None unless
    ``guard``).  The fault-injection taps are free no-ops without an armed
    :class:`repro.robustness.FaultPlan`.

    batch_fusion (stacked ``nbatch=1`` blocks only):

    ``"stacked"``                 — one collective ships all fields (plus
        the chunk-sliced interleave when method="pipelined"); the FFT runs
        batched over the whole stack.
    ``"pipelined-across-fields"`` — per-field collectives emitted so field
        i's all-to-all sits between field i-1's and field i's FFTs, giving
        XLA a per-field DMA/compute overlap window.
    ``"per-field"``               — strictly serialized per-field
        exchange+FFT pairs (the baseline loop, inside one jit).
    """
    method, chunks, comm_dtype, ex_impl, fusion = entry
    with _faults.stage_context(stage_index, method, comm_dtype):
        _faults.check_compile(method, comm_dtype)
        block = _faults.tap_stage_input(block)
        if nbatch and fusion != "stacked":
            nf = block.shape[0]
            fields = [jax.lax.index_in_dim(block, f, axis=0, keepdims=False)
                      for f in range(nf)]
            stats = _health.zero_stats() if guard else None

            def do_exchange(fb):
                nonlocal stats
                r = exchange_shard(fb, ex.v, ex.w, ex.group, method=method,
                                   chunks=chunks, comm_dtype=comm_dtype,
                                   impl=ex_impl, guard=guard)
                if guard:
                    r, s = r
                    stats = _health.add_stats(stats, s)
                return r

            def do_fft(fb):
                if fft_st is None:
                    return fb
                return _fft_padded_axis(fb, fft_st, mid, after, impl=impl, sign=sign)

            outs = []
            if fusion == "per-field":
                for fb in fields:
                    if fft_st is not None and method == "pipelined" and chunks > 1:
                        r = _exchange_then_fft(
                            fb, ex, fft_st, mid, after, chunks=chunks,
                            comm_dtype=comm_dtype, exchange_impl=ex_impl,
                            impl=impl, sign=sign, guard=guard)
                        if guard:
                            r, s = r
                            stats = _health.add_stats(stats, s)
                        outs.append(r)
                    else:
                        outs.append(do_fft(do_exchange(fb)))
            else:  # pipelined-across-fields
                exchanged = []
                for f, fb in enumerate(fields):
                    exchanged.append(do_exchange(fb))
                    if f:  # field f's collective emitted before field f-1's FFT
                        outs.append(do_fft(exchanged[f - 1]))
                outs.append(do_fft(exchanged[-1]))
            return jnp.stack(outs), fft_st is not None, stats

        if fft_st is not None and method == "pipelined" and chunks > 1:
            res = _exchange_then_fft(block, ex, fft_st, mid, after,
                                     chunks=chunks, comm_dtype=comm_dtype,
                                     exchange_impl=ex_impl, impl=impl,
                                     sign=sign, nbatch=nbatch, guard=guard)
            block, stats = res if guard else (res, None)
            return block, True, stats
        res = exchange_shard(block, ex.v, ex.w, ex.group, method=method,
                             chunks=chunks, comm_dtype=comm_dtype,
                             impl=ex_impl, nbatch=nbatch, guard=guard)
        block, stats = res if guard else (res, None)
        if fft_st is not None:
            block = _fft_padded_axis(block, fft_st, mid, after, impl=impl,
                                     sign=sign, nbatch=nbatch)
        return block, fft_st is not None, stats


def _exchange_then_fft(block, ex: ExchangeStage, fft_st: FFTStage,
                       mid: Pencil, after: Pencil, *, chunks, impl, sign,
                       comm_dtype=None, exchange_impl="jnp", nbatch=0,
                       guard=False):
    """Pipelined exchange fused with the next stage's 1-D FFT: issue the
    per-slice all-to-alls interleaved with the per-slice transforms.  Each
    slice is a disjoint v-subrange of the fused output, so slicing commutes
    with the FFT along ``w`` and the concat reproduces the unpipelined
    result (bitwise for lossless ``comm_dtype``, to the codec's error bound
    for bf16/int8 since slices quantize independently); the payoff is that
    XLA may run slice i+1's collective DMA under slice i's FFT compute.
    With ``nbatch=1`` each slice carries every field's sub-range."""
    res = exchange_shard_sliced(block, ex.v, ex.w, ex.group, chunks=chunks,
                                comm_dtype=comm_dtype, impl=exchange_impl,
                                nbatch=nbatch, guard=guard)
    pieces, stats = res if guard else (res, None)
    out = [_fft_padded_axis(p, fft_st, mid, after, impl=impl, sign=sign, nbatch=nbatch)
           for p in pieces]
    out = out[0] if len(out) == 1 else jnp.concatenate(out, axis=ex.v + nbatch)
    return (out, stats) if guard else out


def _fft_padded_axis(block, st: FFTStage, cur: Pencil, nxt: Pencil, *, impl, sign, nbatch=0):
    """One transform stage along a locally-complete axis, honouring padding:
    slice to the logical extent, transform at the true length (pruning
    gather/scatter folded in by :func:`fftcore.local_transform`), re-pad.
    Because the slice/pad bracket the transform inside the shard function,
    XLA fuses them with the adjacent exchange's unpack — dealiasing rides
    the existing exchange path instead of costing separate HBM passes.
    ``nbatch`` leading batch axes transform vectorized (``st.axis`` stays
    field-relative, matching the pencil traces)."""
    axis = st.axis + nbatch
    n_log_in = cur.logical[st.axis]
    if block.shape[axis] != cur.physical[st.axis]:
        raise AssertionError(
            f"axis {st.axis}: local extent {block.shape[axis]} != physical {cur.physical[st.axis]}"
        )
    if n_log_in != block.shape[axis]:
        block = jax.lax.slice_in_dim(block, 0, n_log_in, axis=axis)
    block = fftcore.local_transform(block, st.axis, sign, st.spec, n=st.n,
                                    impl=impl, nbatch=nbatch)
    n_phys_out = nxt.physical[st.axis]
    if block.shape[axis] != n_phys_out:
        pads = [(0, 0)] * block.ndim
        pads[axis] = (0, n_phys_out - block.shape[axis])
        block = jnp.pad(block, pads)
    return block
