"""Plan configuration — the single validated surface for ``ParallelFFT``.

Two types live here:

:class:`PlanConfig` — a frozen dataclass consolidating the execution
    knobs that used to sprawl across ``ParallelFFT.__init__``'s keyword
    list (``method`` / ``impl`` / ``exchange_impl`` / ``chunks`` /
    ``comm_dtype`` / ``batch_fusion`` / ``tuner_cache`` / ``guard``).
    All validation happens in one place (``__post_init__``), so every
    consumer — the plan itself, the tuner, the benchmarks — sees an
    already-canonical config.  ``ParallelFFT(mesh, shape, grid,
    config=PlanConfig(...))`` is the supported surface; the legacy
    kwargs still work through a deprecation shim that forwards into a
    PlanConfig and warns once per process.

:class:`StageEntry` — one exchange stage's tuned/selected execution
    entry: ``(method, chunks, comm_dtype, impl, batch_fusion)``.  This
    replaces the historical raw ``(method, chunks, comm_dtype[,
    batch_fusion])`` 3-vs-4 tuples; being a NamedTuple it still unpacks
    and indexes like one (``entry[2]`` is the comm_dtype everywhere it
    always was), and :meth:`StageEntry.make` upgrades any legacy tuple —
    the ``impl`` and ``batch_fusion`` vocabularies are disjoint, so a
    4-tuple's last field is classified unambiguously.

The new ``impl`` stage field selects the *exchange-local* implementation:

``"jnp"``    — the reference path: :mod:`repro.core.quant` codecs plus the
    engine's jnp pack/unpack copies (multiple HBM round-trips).
``"pallas"`` — the fused exchange kernels of
    :mod:`repro.kernels.exchange`: quantize/narrow + chunk-layout
    pack fused into one HBM-read → VMEM → HBM-write pass on the encode
    side, and dequantize + unpack-transpose fused on the decode side, so
    the only HBM traffic between 1-D FFTs is the collective itself (the
    paper's no-realignment thesis, now holding for lossy wire payloads
    too).  Interpret mode makes the same kernels run on CPU.

Note this is distinct from the plan-level ``impl`` (the local *FFT*
implementation, ``"jnp"`` | ``"matmul"``); the exchange impl is
``PlanConfig.exchange_impl`` and per-stage ``StageEntry.impl``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields, replace
from typing import NamedTuple

from repro.core.quant import canonical_comm_dtype

#: exchange-local implementations a stage entry may carry
EXCHANGE_IMPLS = ("jnp", "pallas")

#: batch_fusion execution modes for a stacked multi-field exchange stage
#: (mirrored by repro.core.redistribute.BATCH_FUSIONS, which re-exports it)
BATCH_FUSIONS = ("stacked", "pipelined-across-fields", "per-field")

#: exchange engines a stage entry may carry ("auto" is plan-level only)
METHODS = ("fused", "traditional", "pipelined")


class StageEntry(NamedTuple):
    """One exchange stage's execution entry.

    Unpacks/indexes like the raw tuples it replaced: ``entry[0]`` method,
    ``entry[1]`` chunks, ``entry[2]`` comm_dtype; the new ``impl`` field
    sits at index 3 and ``batch_fusion`` at 4.
    """

    method: str
    chunks: int
    comm_dtype: str
    impl: str = "jnp"
    batch_fusion: str = "stacked"

    @classmethod
    def make(cls, entry) -> "StageEntry":
        """Normalize any schedule-entry form — a StageEntry, a legacy
        ``(method, chunks, comm_dtype)`` or ``(..., batch_fusion)`` tuple,
        or a full 5-tuple — into a validated StageEntry.  A legacy
        4-tuple's last field is classified by vocabulary (``impl`` and
        ``batch_fusion`` values are disjoint)."""
        if isinstance(entry, cls):
            return entry.validate()
        t = tuple(entry)
        if len(t) == 3:
            return cls(t[0], int(t[1]), t[2]).validate()
        if len(t) == 4:
            if t[3] in BATCH_FUSIONS:
                return cls(t[0], int(t[1]), t[2], "jnp", t[3]).validate()
            return cls(t[0], int(t[1]), t[2], t[3]).validate()
        if len(t) == 5:
            return cls(t[0], int(t[1]), t[2], t[3], t[4]).validate()
        raise ValueError(f"schedule entry {entry!r} has {len(t)} fields; expected 3-5")

    def validate(self) -> "StageEntry":
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}; expected one of {METHODS}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.impl not in EXCHANGE_IMPLS:
            raise ValueError(f"unknown exchange impl {self.impl!r}; expected one of {EXCHANGE_IMPLS}")
        if self.batch_fusion not in BATCH_FUSIONS:
            raise ValueError(
                f"unknown batch_fusion {self.batch_fusion!r}; expected one of {BATCH_FUSIONS}")
        d = canonical_comm_dtype(self.comm_dtype)
        return self if d == self.comm_dtype else self._replace(comm_dtype=d)


def as_schedule(entries) -> tuple[StageEntry, ...]:
    """Normalize an iterable of schedule entries (any legacy form) into a
    tuple of :class:`StageEntry` — the one normalizer every consumer of a
    user/disk-provided schedule shares."""
    return tuple(StageEntry.make(e) for e in entries)


@dataclass(frozen=True)
class PlanConfig:
    """Validated execution config for one :class:`~repro.core.pfft.ParallelFFT`.

    Fields (see the ParallelFFT docstring for full semantics):

    method:        "fused" (paper) | "traditional" | "pipelined" | "auto".
    impl:          local 1-D FFT implementation ("jnp" | "matmul").
    exchange_impl: exchange-local pack/codec implementation ("jnp" |
                   "pallas").  Explicit methods run every stage with it;
                   for ``method="auto"`` it is a *candidate budget* — the
                   tuner sweeps pallas kernels (where applicable) only
                   when this is "pallas", and picks them per stage only
                   where they win.
    chunks:        slice count for method="pipelined".
    comm_dtype:    wire payload policy / accuracy budget (canonicalized).
    batch_fusion:  multi-field execution mode for the explicit methods.
    tuner_cache:   schedule-cache path for method="auto".
    guard:         runtime-guard mode ("off" | "strict" | "degrade").
    """

    method: str = "fused"
    impl: str = "jnp"
    exchange_impl: str = "jnp"
    chunks: int = 4
    comm_dtype: str | None = None
    batch_fusion: str = "stacked"
    tuner_cache: str | None = None
    guard: str = "off"

    def __post_init__(self):
        if self.method not in (*METHODS, "auto"):
            raise ValueError(f"unknown method {self.method!r}; expected one of {(*METHODS, 'auto')}")
        if self.impl not in ("jnp", "matmul"):
            raise ValueError(f"unknown FFT impl {self.impl!r}; expected 'jnp' or 'matmul'")
        if self.exchange_impl not in EXCHANGE_IMPLS:
            raise ValueError(
                f"unknown exchange_impl {self.exchange_impl!r}; expected one of {EXCHANGE_IMPLS}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.batch_fusion not in BATCH_FUSIONS:
            raise ValueError(
                f"unknown batch_fusion {self.batch_fusion!r}; expected one of {BATCH_FUSIONS}")
        # lazy import-cycle-free guard-mode check (health has no core deps)
        from repro.robustness.health import GUARD_MODES

        if self.guard not in GUARD_MODES:
            raise ValueError(f"unknown guard {self.guard!r}; expected one of {GUARD_MODES}")
        object.__setattr__(self, "comm_dtype", canonical_comm_dtype(self.comm_dtype))

    def replace(self, **changes) -> "PlanConfig":
        """Functional update (re-validates through ``__post_init__``)."""
        return replace(self, **changes)

    def stage_entry(self) -> StageEntry:
        """The uniform StageEntry an explicit-method config implies for
        every exchange stage (``method="auto"`` resolves per stage via the
        tuner instead)."""
        chunks = self.chunks if self.method == "pipelined" else 1
        return StageEntry(self.method, chunks, self.comm_dtype,
                          self.exchange_impl, self.batch_fusion)

    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}

    @classmethod
    def from_legacy_kwargs(cls, **kwargs) -> "PlanConfig":
        """Build a config from the legacy ParallelFFT keyword set, keeping
        each unset field at its default (the deprecation shim's helper)."""
        return cls(**{k: v for k, v in kwargs.items() if v is not None})


# make `field` referenced for linters that dislike unused imports via
# dataclasses API surface changes
_ = field
