"""Pencil (distributed-array alignment) abstraction — paper Sec. 3.4/3.5.

A ``Pencil`` describes how a d-dimensional global array is laid out over a
named JAX mesh: for each array axis, either ``None`` (axis is *aligned*, i.e.
fully local) or the mesh-axis name(s) it is block-distributed over.  This is
the JAX analogue of the paper's Cartesian process topologies + 1-D subgroups
(``MPI_CART_SUB``): a mesh axis name *is* a process subgroup, and naming it in
a collective restricts communication to that subgroup — the paper's key
observation that a pencil decomposition is a collection of slab
decompositions over 1-D subgroups falls out for free.

Physical vs logical extents: XLA SPMD needs equal shards, so each axis is
stored padded to a multiple of every subgroup size it is ever distributed
over (``lcm`` policy; see core/decomp.py and DESIGN.md §7).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import cached_property

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.decomp import pad_to_multiple

# A "group" is one mesh axis name or a tuple of names (composed subgroup).
Group = str | tuple[str, ...]


def group_names(group: Group) -> tuple[str, ...]:
    return (group,) if isinstance(group, str) else tuple(group)


def group_size(mesh: Mesh, group: Group) -> int:
    return int(np.prod([mesh.shape[n] for n in group_names(group)], dtype=np.int64))


@dataclass(frozen=True)
class Pencil:
    """Alignment state of a distributed d-dim array.

    ``logical``   — true global extents (paper's N_m).
    ``physical``  — stored global extents (padded; equal-shard policy).
    ``placement`` — per array axis: mesh axis name(s) or None (aligned).
    """

    mesh: Mesh = field(repr=False)
    logical: tuple[int, ...]
    physical: tuple[int, ...]
    placement: tuple[Group | None, ...]

    def __post_init__(self):
        assert len(self.logical) == len(self.physical) == len(self.placement)
        for ext, grp in zip(self.physical, self.placement):
            if grp is not None:
                m = group_size(self.mesh, grp)
                if ext % m != 0:
                    raise ValueError(
                        f"physical extent {ext} not divisible by group {grp} (size {m})"
                    )

    @property
    def ndim(self) -> int:
        return len(self.logical)

    @cached_property
    def spec(self) -> P:
        return P(*self.placement)

    def batched_spec(self, nbatch: int = 1) -> P:
        """PartitionSpec with ``nbatch`` leading replicated field/batch axes
        (the in/out spec of a stacked multi-field ``shard_map``)."""
        return P(*((None,) * nbatch), *self.placement)

    @cached_property
    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec)

    def batched_sharding(self, nbatch: int = 1) -> NamedSharding:
        return NamedSharding(self.mesh, self.batched_spec(nbatch))

    @cached_property
    def local_shape(self) -> tuple[int, ...]:
        out = []
        for ext, grp in zip(self.physical, self.placement):
            out.append(ext if grp is None else ext // group_size(self.mesh, grp))
        return tuple(out)

    def aligned(self, axis: int) -> bool:
        return self.placement[axis] is None

    def exchanged(self, v: int, w: int) -> "Pencil":
        """Alignment after the paper's v→w exchange: axis ``v`` (currently
        aligned) takes over the subgroup of axis ``w`` (currently
        distributed); axis ``w`` becomes aligned.  Physical extents are
        unchanged — redistribution never resizes (paper Eq. 20)."""
        if not self.aligned(v):
            raise ValueError(f"axis v={v} must be aligned, placement={self.placement}")
        grp = self.placement[w]
        if grp is None:
            raise ValueError(f"axis w={w} must be distributed, placement={self.placement}")
        m = group_size(self.mesh, grp)
        if self.physical[v] % m != 0:
            raise ValueError(
                f"axis v={v} physical extent {self.physical[v]} not divisible by |{grp}|={m}"
            )
        new_placement = list(self.placement)
        new_placement[v] = grp
        new_placement[w] = None
        return replace(self, placement=tuple(new_placement))

    def with_axis_extent(self, axis: int, logical: int) -> "Pencil":
        """New pencil with axis ``axis`` resized (r2c/c2r extent change).

        The physical extent is re-padded preserving this pencil's divisibility
        requirement for that axis (lcm of 1 and its current group)."""
        m = 1 if self.placement[axis] is None else group_size(self.mesh, self.placement[axis])
        new_logical = list(self.logical)
        new_physical = list(self.physical)
        new_logical[axis] = logical
        new_physical[axis] = pad_to_multiple(logical, m)
        return replace(self, logical=tuple(new_logical), physical=tuple(new_physical))


def make_pencil(
    mesh: Mesh,
    logical: tuple[int, ...],
    placement: tuple[Group | None, ...],
    *,
    divisors: tuple[int, ...] | None = None,
) -> Pencil:
    """Build a Pencil, padding each axis to satisfy ``divisors`` (per-axis
    required divisibility, e.g. the lcm of every subgroup the axis will ever
    be distributed over during an FFT plan) and its current placement."""
    physical = []
    for i, (ext, grp) in enumerate(zip(logical, placement)):
        need = divisors[i] if divisors is not None else 1
        if grp is not None:
            need = math.lcm(need, group_size(mesh, grp))
        physical.append(pad_to_multiple(ext, need))
    return Pencil(mesh=mesh, logical=logical, physical=tuple(physical), placement=placement)


def pad_global(x: jax.Array, pencil: Pencil, *, nbatch: int = 0) -> jax.Array:
    """Zero-pad a logical global array to the pencil's physical extents
    (``nbatch`` leading batch axes of ``x`` are left untouched)."""
    pads = [(0, 0)] * nbatch + [(0, p - l) for l, p in zip(pencil.logical, pencil.physical)]
    if all(p == (0, 0) for p in pads):
        return x
    return jax.numpy.pad(x, pads)


def unpad_global(x: jax.Array, pencil: Pencil, *, nbatch: int = 0) -> jax.Array:
    """Slice a physical global array back to its logical extents."""
    if pencil.logical == pencil.physical:
        return x
    return x[(slice(None),) * nbatch + tuple(slice(0, l) for l in pencil.logical)]
