"""Shared quantization core — the repo's single scale/quantize/dequantize
implementation.

Two consumers:

* :mod:`repro.core.redistribute` — reduced-precision exchange payloads
  (``comm_dtype``): the v→w all-to-all ships bf16 or int8 re/im planes
  instead of complex64, cutting wire bytes 2–4× on comm-bound shapes.
  Batched (multi-field) exchanges stack N fields and run every codec
  *once* over the stacked block — one HBM quantize/dequantize pass total
  instead of one per field; the int8 codec keeps one scale per
  (field, destination-chunk) block (``block_axis`` accepts a tuple) so
  fields of different magnitude never share a max-abs.
* :mod:`repro.optim.compress` — int8 gradient compression with error
  feedback for the DP reduction.

Codecs (all symmetric, zero-point-free):

``complex64`` — lossless passthrough (no codec; callers skip encode/decode).
``bf16``      — plain ``bfloat16`` cast of the f32 re/im planes.  bf16 keeps
    f32's 8-bit exponent, so no scale is needed or shipped: the codec is a
    pure rounding of each mantissa to 8 bits (~3 decimal digits).  2× fewer
    wire bytes.
``int8``      — per-block max-abs scaling: one f32 scale per index of a
    caller-chosen *block axis* (max |x| over all other axes, floored, /127),
    payload ``round(x/scale)`` clipped to [-127, 127].  4× fewer wire bytes
    plus a tiny f32 scale vector that must ride along (for a collective:
    a second, scale-sized all-to-all).

Complex arrays are quantized as stacked (re, im) f32 planes —
:func:`complex_to_planes` / :func:`planes_to_complex` — sharing one scale
per block across both planes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: accepted comm_dtype policy names, lossless first
COMM_DTYPES = ("complex64", "bf16", "int8")

_ALIASES = {
    None: "complex64",
    "complex64": "complex64",
    "c64": "complex64",
    "none": "complex64",
    "bf16": "bf16",
    "bfloat16": "bf16",
    "int8": "int8",
}

#: scale floor: keeps all-zero blocks (padding) from dividing by zero
_EPS = 1e-12


def canonical_comm_dtype(comm_dtype) -> str:
    """Normalize a comm_dtype spec (None / alias / dtype-like) to one of
    :data:`COMM_DTYPES`; raises ``ValueError`` for anything else."""
    key = comm_dtype if comm_dtype is None else str(comm_dtype).lower()
    try:
        return _ALIASES[key]
    except KeyError:
        raise ValueError(
            f"unknown comm_dtype {comm_dtype!r}; expected one of {COMM_DTYPES}"
        ) from None


def wire_ratio(comm_dtype) -> int:
    """Payload compression factor vs the uncompressed dtype: wire bytes =
    itemsize // wire_ratio (int8 scales priced separately)."""
    return {"complex64": 1, "bf16": 2, "int8": 4}[canonical_comm_dtype(comm_dtype)]


# ---------------------------------------------------------------------------
# int8 codec
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array, *, block_axis: int | tuple[int, ...] = 0,
                  scale_div=None, with_stats: bool = False):
    """Symmetric per-block int8 quantization of an f32 array.

    One scale per index combination of the ``block_axis`` axis (or axes —
    a tuple quantizes per cross-product block, e.g. ``(batch, chunk)`` for
    a stacked multi-field exchange payload, so fields of very different
    magnitude don't share one max-abs): max |x| over all *other* axes.
    Returns ``(q, scale)`` with ``q`` int8 of ``x.shape`` and ``scale`` f32
    keeping the block axes' extents and 1 elsewhere (keepdims layout,
    broadcastable against ``q``).

    Non-finite inputs are *sanitized*: a NaN/Inf element would otherwise
    poison the block's max-abs, making the scale (and so every dequantized
    element of the block) NaN.  The max-abs is taken over the finite
    elements only and non-finite elements quantize to 0 — the corruption
    stays local to the bad elements and is reported, not amplified.  Pass
    ``with_stats=True`` to additionally get ``{"nonfinite", "saturated"}``
    f32 scalar counts (the runtime-guard hook: saturation rides the clip
    the codec already does, costing no extra HBM pass).

    ``scale_div`` (fault injection only) divides the scale, forcing
    saturation — see :mod:`repro.robustness.faults`.
    """
    axes = (block_axis,) if isinstance(block_axis, int) else tuple(block_axis)
    axes = tuple(a % x.ndim for a in axes)
    red = tuple(i for i in range(x.ndim) if i not in axes)
    finite = jnp.isfinite(x)
    xf = jnp.where(finite, x, 0.0)
    amax = jnp.max(jnp.abs(xf), axis=red, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / 127.0
    if scale_div is not None:
        scale = scale / scale_div
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    scale = scale.astype(jnp.float32)
    if not with_stats:
        return q, scale
    stats = {
        "nonfinite": jnp.sum(~finite, dtype=jnp.float32),
        # |q| == 127 without an int32 cast: a convert out of int8 here
        # would unbalance planlint's PLAN006 quantize/dequantize pairing
        "saturated": jnp.sum((q == 127) | (q == -127), dtype=jnp.float32),
    }
    return q, scale, stats


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_int8` (up to the quantization error):
    ``scale`` broadcasts against ``q``."""
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# bf16 codec
# ---------------------------------------------------------------------------


def encode_bf16(x: jax.Array) -> jax.Array:
    """f32 → bf16 (round-to-nearest-even mantissa truncation; no scale)."""
    return x.astype(jnp.bfloat16)


def decode_bf16(p: jax.Array) -> jax.Array:
    return p.astype(jnp.float32)


# ---------------------------------------------------------------------------
# complex <-> re/im planes
# ---------------------------------------------------------------------------


def complex_to_planes(y: jax.Array) -> jax.Array:
    """complex64 array → stacked ``(2, *y.shape)`` f32 (re, im) planes."""
    return jnp.stack([jnp.real(y), jnp.imag(y)]).astype(jnp.float32)


def planes_to_complex(p: jax.Array) -> jax.Array:
    """Inverse of :func:`complex_to_planes`."""
    return jax.lax.complex(p[0].astype(jnp.float32), p[1].astype(jnp.float32))
