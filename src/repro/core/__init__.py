"""Core library: the paper's global-redistribution method and parallel FFT.

Public API:
  decompose, AxisDecomp            — balanced block decomposition (Alg. 1)
  Pencil, make_pencil              — distributed-array alignment state
  exchange, exchange_shard         — the paper's fused v→w redistribution
                                     (comm_dtype=None|"complex64"|"bf16"|
                                      "int8" wire payloads)
  exchange_shard_sliced            — the pipelined (sliced) exchange engine
  ParallelFFT                      — slab/pencil/d-dim distributed FFT
                                     (method="fused"|"traditional"|
                                      "pipelined"|"auto")
  quant                            — shared quantization codecs (bf16/int8)
  tuner                            — per-stage exchange-engine autotuner
"""

from repro.core.decomp import AxisDecomp, decompose, local_lengths, pad_to_multiple, start_indices
from repro.core.pencil import Pencil, group_size, make_pencil, pad_global, unpad_global
from repro.core.quant import canonical_comm_dtype
from repro.core.redistribute import (exchange, exchange_cost_bytes, exchange_shard,
                                     exchange_shard_sliced, exchange_time_model,
                                     exchange_wire_bytes)
from repro.core.pfft import ParallelFFT

__all__ = [
    "AxisDecomp",
    "decompose",
    "local_lengths",
    "pad_to_multiple",
    "start_indices",
    "Pencil",
    "group_size",
    "make_pencil",
    "pad_global",
    "unpad_global",
    "canonical_comm_dtype",
    "exchange",
    "exchange_cost_bytes",
    "exchange_shard",
    "exchange_shard_sliced",
    "exchange_time_model",
    "exchange_wire_bytes",
    "ParallelFFT",
]
