"""Core library: the paper's global-redistribution method and parallel FFT.

Public API:
  decompose, AxisDecomp            — balanced block decomposition (Alg. 1)
  Pencil, make_pencil              — distributed-array alignment state
  exchange, exchange_shard         — the paper's fused v→w redistribution
  ParallelFFT                      — slab/pencil/d-dim distributed FFT
"""

from repro.core.decomp import AxisDecomp, decompose, local_lengths, pad_to_multiple, start_indices
from repro.core.pencil import Pencil, group_size, make_pencil, pad_global, unpad_global
from repro.core.redistribute import exchange, exchange_shard
from repro.core.pfft import ParallelFFT

__all__ = [
    "AxisDecomp",
    "decompose",
    "local_lengths",
    "pad_to_multiple",
    "start_indices",
    "Pencil",
    "group_size",
    "make_pencil",
    "pad_global",
    "unpad_global",
    "exchange",
    "exchange_shard",
    "ParallelFFT",
]
