"""Mesh + shard_map compat helpers, version-adaptive across jax 0.4.x–0.8.x.

Every ``shard_map`` / ``make_mesh`` / axis-size call in the repo routes
through this module so the rest of the codebase can be written against one
API surface:

* ``jax.shard_map`` (0.8.x) vs ``jax.experimental.shard_map.shard_map``
  (0.4.x–0.7.x) — resolved at import time.
* ``check_vma`` (0.8.x) vs ``check_rep`` (older) — translated, or dropped
  when the installed shard_map understands neither keyword.
* ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
  ``jax.make_mesh`` — only passed when the installed jax has them.
* ``lax.axis_size`` (0.6+) — falls back to ``lax.psum(1, axis)``, which
  constant-folds to a static int for a concrete operand.
"""

from __future__ import annotations

import inspect

import jax
from jax import lax
from jax.sharding import Mesh

try:  # jax >= 0.6: explicit/auto/manual mesh axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x/0.5.x: meshes have no axis types
    AxisType = None

if hasattr(jax, "shard_map"):  # jax >= 0.8 top-level export
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``shard_map`` with the replication-check kwarg translated per version
    (``check_vma`` on 0.8.x, ``check_rep`` before, dropped if unknown)."""
    kwargs = {}
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def balanced_dims(ndev: int) -> tuple[int, int]:
    """Factor ``ndev`` into the most-square (a, b) with a*b == ndev, a <= b
    — the 2-D process grid the examples/benchmarks use for pencil plans."""
    a = int(ndev**0.5)
    while ndev % a:
        a -= 1
    return a, ndev // a


def make_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported
    (stable across 0.8→0.9); plain mesh on jax < 0.6."""
    if AxisType is not None:
        return jax.make_mesh(shape, axis_names, axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def set_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` on 0.8.x; on older jax the Mesh object is itself the
    context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis (or tuple of axes) inside shard_map."""
    if hasattr(lax, "axis_size"):
        return int(lax.axis_size(axis_name))
    return int(lax.psum(1, axis_name))
