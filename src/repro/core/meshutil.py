"""Mesh + shard_map compat helpers (jax 0.8.x)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh

shard_map = jax.shard_map


def make_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types (stable across 0.8→0.9)."""
    return jax.make_mesh(shape, axis_names, axis_types=(AxisType.Auto,) * len(axis_names))
