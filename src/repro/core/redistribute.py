"""Global redistribution (the paper's contribution — Sec. 3.3.2, Alg. 2/3).

Three implementations of the v→w exchange of a distributed array:

``method="fused"`` — the paper's method.  One ``lax.all_to_all`` with
    ``split_axis=v, concat_axis=w``: the strided split/concat description
    plays the role of MPI subarray datatypes, and the single collective is
    the analogue of ``MPI_ALLTOALLW``.  No local transpose materializes in
    user code; XLA:TPU's collective engine performs the strided
    gather/scatter as part of the exchange.

``method="traditional"`` — what P3DFFT/2DECOMP&FFT/FFTW-MPI do (paper
    Sec. 3.3.1, Eqs. 15–17): pack chunks contiguously with an explicit local
    transpose (a materialized copy), run a contiguous all-to-all on the
    leading chunk axis, then unpack with a second local transpose.  With
    ``transposed_out=True`` the unpack copy is skipped and the output keeps
    the permuted chunk-major layout (FFTW's "transposed out", Eq. 19) —
    callers must handle the layout.

``method="pipelined"`` — the fused exchange sliced into ``chunks`` pieces
    along the *post-exchange v shard* so each slice is an independent
    all-to-all whose output is one contiguous sub-range of the fused
    output.  The union of the slices is bit-identical to ``fused``; the
    point is scheduling freedom: a caller (``pfft._run_stages``) can
    interleave each slice's collective with the next stage's 1-D FFT on the
    previous slice, letting XLA overlap collective DMA with MXU/VPU compute
    instead of serializing exchange→transform.  This is the TPU analogue of
    the paper's note that the single-collective formulation "enables future
    speedups from optimizations in the internal datatype handling engines"
    (cf. partitioned/persistent-collective MPI FFTs, arXiv:2306.16589).

``method="auto"`` (plan level only, see :mod:`repro.core.tuner`) —
    micro-benchmarks {fused, traditional, pipelined×chunks} × the allowed
    ``comm_dtype`` payloads per exchange stage of a plan and caches the
    winning schedule on disk.

Both operate *per shard* (inside ``shard_map``) via ``exchange_shard`` and
at the jit level on globally-sharded arrays via ``exchange``.

Batched multi-field exchange (``nbatch``)
-----------------------------------------

Real spectral workloads (Navier–Stokes: u, v, w plus nonlinear products)
push *many* fields through the same plan, and issuing one small all-to-all
per field per stage leaves the interconnect latency-bound.  Every engine
therefore accepts ``nbatch``: the leading ``nbatch`` axes of ``block`` are
field/batch axes and ``v``/``w`` are *field-relative* array axes (the
engine offsets them internally).  The whole stacked payload of all fields
ships in **one** collective per exchange — message aggregation in the
spirit of P3DFFT's many-variable API (arXiv:1905.02803) and the
collective-optimized FFTs of arXiv:2306.16589 — and a lossy ``comm_dtype``
codec runs once over the stacked block (one HBM quantize/dequantize pass
total instead of one per field; int8 keeps one scale per (field,
destination chunk) so fields of different magnitude never share a
max-abs).  ``exchange_shard(stacked, v, w, group, nbatch=1)`` is the
batched entry point :class:`repro.core.pfft.ParallelFFT` uses for its
``batch_fusion="stacked"`` execution mode.

Communication compression (``comm_dtype``)
------------------------------------------

Every engine accepts a ``comm_dtype`` payload policy (codecs in
:mod:`repro.core.quant`); the wire pattern is encode → all-to-all the
narrow payload (+ one tiny f32 scale all-to-all for int8) → decode:

``"complex64"`` (default / ``None``) — lossless passthrough.  Bit-identical
    to the uncompressed exchange for all three engines: the collective sees
    the original complex64 buffer.
``"bf16"`` — the complex block travels as stacked (re, im) bf16 planes:
    2× fewer wire bytes.  bf16 keeps f32's exponent so no scale is shipped;
    accuracy contract: each exchanged value is rounded to 8 mantissa bits
    (~3 decimal digits), and a full FFT round trip stays within ~1e-3
    relative L2 of the exact result.
``"int8"`` — per-destination-chunk max-abs int8 planes: 4× fewer wire
    bytes plus one f32 scale per destination rank (a second, scale-sized
    all-to-all).  Accuracy contract: per-element error ≤ chunk-max/254 per
    exchange; a full round trip stays within ~1e-2 relative L2.  Expected
    to win only when the exchange is firmly ICI-bound — the codec pays two
    extra HBM passes over the block (quantize + dequantize), so on small /
    compute-bound shapes complex64 or bf16 wins; the tuner prices exactly
    this trade when ``method="auto"`` is given an accuracy budget.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import quant
from repro.core.decomp import local_lengths
from repro.core.meshutil import axis_size as _mesh_axis_size, shard_map
from repro.core.pencil import Group, Pencil, group_names, group_size
from repro.core.planconfig import BATCH_FUSIONS, EXCHANGE_IMPLS  # noqa: F401 — re-exported
from repro.core.quant import canonical_comm_dtype, wire_ratio
from repro.kernels.exchange import ops as _xk
from repro.robustness import faults as _faults, health as _health

Method = str  # "fused" | "traditional" | "pipelined"
CommDtype = str  # "complex64" | "bf16" | "int8" (None accepted as complex64)
Impl = str  # "jnp" | "pallas" (exchange-local implementation, see planconfig)

#: chunk counts the tuner sweeps for the pipelined method
PIPELINE_CHUNK_CANDIDATES = (2, 4, 8)


def _all_to_all_comm(
    y: jax.Array,
    axis_name,
    *,
    split_axis: int,
    concat_axis: int,
    comm_dtype: CommDtype | None = None,
    batch_axes: tuple[int, ...] = (),
    guard: bool = False,
    impl: Impl = "jnp",
) -> jax.Array:
    """``lax.all_to_all(..., tiled=True)`` with an optional reduced-precision
    wire payload (the comm-compression core all three engines share).

    ``complex64``: the collective runs on ``y`` directly — bit-identical to
    an uncompressed exchange.  ``bf16``/``int8``: ``y`` is encoded to
    stacked (re, im) planes (a plain f32 plane for real input), the narrow
    payload is exchanged with the split/concat axes shifted past the plane
    axis, and the result is decoded back to ``y``'s dtype.  For int8 the
    per-destination-chunk scales ride in a second, scale-sized all-to-all
    so each receiver dequantizes chunk ``j`` with sender ``j``'s scale.

    ``batch_axes`` names the field/batch axes of a stacked multi-field
    payload (``y``-axis indices): the collective and the bf16 codec are
    batch-oblivious, but the int8 codec blocks its scales per (field,
    destination chunk) so fields of different magnitude never share one
    max-abs — the scale all-to-all ships ``m × prod(batch extents)`` f32s.

    ``guard=True`` additionally returns per-payload health stats (see
    :mod:`repro.robustness.health`) riding the codec's existing reductions:
    the return becomes ``(out, {"nonfinite", "saturated"})``.  Only the
    lossy codecs scan their payload — a complex64 exchange returns zero
    counters at zero traced cost, because any non-finite it ships
    propagates through the remaining stages into the executor's
    output-energy guard (detection is global there, not per-stage).  The
    fault taps (:mod:`repro.robustness.faults`) trace zero ops unless a
    FaultPlan is armed, so an unguarded exchange compiles bit-identically.

    ``impl="pallas"`` runs the lossy codec through the fused exchange
    kernels (:mod:`repro.kernels.exchange`): encode and decode each become
    one pallas call instead of the multi-pass jnp chain, and — because the
    narrowing convert lives *inside* an opaque kernel — XLA cannot hoist
    it across the collective, so the wire genuinely carries the narrow
    payload (the single-host CPU backend widens the jnp bf16 wire back to
    f32; see planlint PLAN002).  A lossless payload has no codec to fuse
    and always takes the jnp path below (``pallas_applicable``).
    """
    d = canonical_comm_dtype(comm_dtype)
    if d == "complex64":
        stats = _health.zero_stats() if guard else None
        out = lax.all_to_all(y, axis_name, split_axis=split_axis,
                             concat_axis=concat_axis, tiled=True)
        out = _faults.tap_wire(out, "payload")
        return (out, stats) if guard else out
    iscomplex = jnp.iscomplexobj(y)
    if impl == "pallas":
        if batch_axes != tuple(range(len(batch_axes))):
            raise ValueError("impl='pallas' requires leading batch axes; "
                             f"got {batch_axes}")
        m = _axis_size(axis_name)
        sd = _faults.scale_div() if d == "int8" else None
        q, scale, stats = _xk.encode_payload(
            y, axis=split_axis, m=m, nbatch=len(batch_axes), codec=d,
            guard=guard, scale_div=sd)
        # payload is (P, *y.shape) re/im planes: split/concat shift past P
        qx = lax.all_to_all(q, axis_name, split_axis=split_axis + 1,
                            concat_axis=concat_axis + 1, tiled=True)
        qx = _faults.tap_wire(qx, "payload")
        sx = None
        if scale is not None:  # int8: (F, M) per-(field, chunk) scales
            sx = lax.all_to_all(scale, axis_name, split_axis=1,
                                concat_axis=1, tiled=True)
            sx = _faults.tap_wire(sx, "scale")
        out = _xk.decode_payload(qx, axis=concat_axis, m=m,
                                 nbatch=len(batch_axes), scale=sx, codec=d,
                                 iscomplex=iscomplex)
        return (out, stats) if guard else out
    planes = quant.complex_to_planes(y) if iscomplex else y[None].astype(jnp.float32)
    sa, ca = split_axis + 1, concat_axis + 1
    ba = tuple(b + 1 for b in batch_axes)  # planes coords

    if d == "bf16":
        stats = _health.payload_stats(planes) if guard else None
        p = lax.all_to_all(quant.encode_bf16(planes), axis_name,
                           split_axis=sa, concat_axis=ca, tiled=True)
        p = quant.decode_bf16(_faults.tap_wire(p, "payload"))
        out = quant.planes_to_complex(p) if iscomplex else p[0]
        return (out, stats) if guard else out

    # int8: one scale per (field, destination chunk) of the split axis.
    m = _axis_size(axis_name)
    nv = planes.shape[sa]
    if nv % m != 0:
        raise ValueError(f"split axis extent {nv} not divisible by group size {m}")
    view = list(planes.shape)
    view[sa : sa + 1] = [m, nv // m]
    # block axes in view coords: the m-chunk axis plus every batch axis
    # (axes past the inserted nv//m axis shift right by one)
    block_axes = (sa,) + tuple(b if b < sa else b + 1 for b in ba)
    qargs = dict(block_axis=block_axes, scale_div=_faults.scale_div())
    if guard:
        q, scale, stats = quant.quantize_int8(planes.reshape(view),
                                              with_stats=True, **qargs)
    else:
        q, scale = quant.quantize_int8(planes.reshape(view), **qargs)
        stats = None
    q = q.reshape(planes.shape)
    # scale keepdims (view coords) -> planes coords: drop the nv//m axis
    s = scale.reshape([e for i, e in enumerate(scale.shape) if i != sa + 1])
    qx = lax.all_to_all(q, axis_name, split_axis=sa, concat_axis=ca, tiled=True)
    sx = lax.all_to_all(s, axis_name, split_axis=sa, concat_axis=ca, tiled=True)
    qx = _faults.tap_wire(qx, "payload")
    sx = _faults.tap_wire(sx, "scale")
    # received chunk j along the concat axis was quantized with sender j's
    # scale: view ca as (m, ca_out/m) and broadcast sx over the chunk
    out_view = list(qx.shape)
    out_view[ca : ca + 1] = [m, qx.shape[ca] // m]
    dq = quant.dequantize_int8(qx.reshape(out_view), jnp.expand_dims(sx, ca + 1))
    p = dq.reshape(qx.shape)
    out = quant.planes_to_complex(p) if iscomplex else p[0]
    return (out, stats) if guard else out


def exchange_shard(
    block: jax.Array,
    v: int,
    w: int,
    group: Group,
    *,
    method: Method = "fused",
    chunks: int = 1,
    transposed_out: bool = False,
    comm_dtype: CommDtype | None = None,
    nbatch: int = 0,
    guard: bool = False,
    impl: Impl = "jnp",
) -> jax.Array:
    """Per-shard v→w exchange over mesh subgroup ``group``.

    Input block: axis ``v`` full (locally complete), axis ``w`` holds this
    rank's shard.  Output block: axis ``v`` holds this rank's shard, axis
    ``w`` full.  Mirrors the paper's EXCHANGE(P, A, v, B, w) (Alg. 3).

    ``chunks`` only affects ``method="pipelined"``; ``transposed_out`` only
    affects ``method="traditional"``.  ``comm_dtype`` selects the wire
    payload encoding (see module docstring): ``None``/``"complex64"`` is
    lossless and bit-identical to the uncompressed exchange.

    ``nbatch`` marks the leading ``nbatch`` axes of ``block`` as stacked
    field/batch axes (see module docstring): ``v``/``w`` stay
    *field-relative* and the one collective ships every field's payload —
    the batched multi-field entry point.  With ``transposed_out=True`` the
    chunk axis still comes out leading (before the batch axes).

    ``guard=True`` returns ``(out, stats)`` with this exchange's fused
    health counters (see :func:`_all_to_all_comm`).

    ``impl="pallas"`` fuses each side's local work (codec, and for
    ``traditional`` the pack/unpack realignment too) into one exchange
    kernel per side — see :mod:`repro.kernels.exchange`.  It applies to
    lossy payloads only (a lossless exchange has no local codec pass to
    fuse) and to ``transposed_out=False``; inapplicable combinations
    execute the jnp reference path, so ``impl`` never changes results
    beyond the documented codec parity bounds.
    """
    if v == w:
        raise ValueError("exchange requires v != w (paper Alg. 3)")
    names = group_names(group)
    axis_name = names[0] if len(names) == 1 else names
    bv, bw = v + nbatch, w + nbatch
    batch_axes = tuple(range(nbatch))

    if method == "fused":
        # The paper's method: one generalized all-to-all; the split/concat
        # axes are the "subarray datatype" description.
        return _all_to_all_comm(block, axis_name, split_axis=bv, concat_axis=bw,
                                comm_dtype=comm_dtype, batch_axes=batch_axes,
                                guard=guard, impl=impl)

    if method == "pipelined":
        r = exchange_shard_sliced(block, v, w, group, chunks=chunks,
                                  comm_dtype=comm_dtype, nbatch=nbatch,
                                  guard=guard, impl=impl)
        pieces, stats = r if guard else (r, None)
        out = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=bv)
        return (out, stats) if guard else out

    if method == "traditional":
        m = _axis_size(axis_name)
        nv = block.shape[bv]
        if nv % m != 0:
            raise ValueError(f"axis v={v} extent {nv} not divisible by group size {m}")
        d = canonical_comm_dtype(comm_dtype)
        if impl == "pallas" and not transposed_out and _xk.pallas_applicable(method, d):
            # One kernel packs chunk-major AND encodes (Eqs. 15-16 cost no
            # extra pass); the inverse kernel scatters + dequantizes (Eq. 17).
            sd = _faults.scale_div() if d == "int8" else None
            payload, scale, stats = _xk.pack_chunks(
                block, axis=bv, m=m, nbatch=nbatch, codec=d, guard=guard,
                scale_div=sd)
            y = lax.all_to_all(payload, axis_name, split_axis=0,
                               concat_axis=0, tiled=True)
            y = _faults.tap_wire(y, "payload")
            sx = None
            if scale is not None:  # int8: (M, F) scales, chunk-major like the payload
                sx = lax.all_to_all(scale, axis_name, split_axis=0,
                                    concat_axis=0, tiled=True)
                sx = _faults.tap_wire(sx, "scale")
            out = _xk.unpack_chunks(y, v=v, w=w, m=m, nbatch=nbatch,
                                    scale=sx, codec=d,
                                    iscomplex=jnp.iscomplexobj(block))
            return (out, stats) if guard else out
        # Eq. (15): reshape v -> (m, nv/m); stride change only, free.
        shape = list(block.shape)
        shape[bv : bv + 1] = [m, nv // m]
        y = block.reshape(shape)
        # Eq. (16): bring the chunk axis to the front — the materialized
        # local transpose (the costly pack step traditional codes pay for).
        y = jnp.moveaxis(y, bv, 0)
        # Eq. (17)+ALLTOALL: contiguous exchange on the leading chunk axis.
        r = _all_to_all_comm(y, axis_name, split_axis=0, concat_axis=0,
                             comm_dtype=comm_dtype,
                             batch_axes=tuple(b + 1 for b in batch_axes),
                             guard=guard)
        y, stats = r if guard else (r, None)
        # Unpack: leading chunk q now carries peer q's w-shard (global w order).
        if transposed_out:
            # FFTW "transposed out": keep chunk-major layout, caller handles it.
            return (y, stats) if guard else y
        # Insert the chunk axis just before w (chunk-major == global w order)
        # and merge (m, w_shard) -> w_full: the second materialized copy.
        z = jnp.moveaxis(y, 0, bw)
        shape = list(z.shape)
        shape[bw : bw + 2] = [shape[bw] * shape[bw + 1]]
        return (z.reshape(shape), stats) if guard else z.reshape(shape)

    raise ValueError(f"unknown method {method!r}")


def exchange_shard_sliced(
    block: jax.Array,
    v: int,
    w: int,
    group: Group,
    *,
    chunks: int,
    comm_dtype: CommDtype | None = None,
    nbatch: int = 0,
    guard: bool = False,
    impl: Impl = "jnp",
) -> list[jax.Array]:
    """The fused v→w exchange as ``chunks`` independent per-slice
    all-to-alls (the ``pipelined`` engine).

    The input's v axis is viewed as ``(m, b)`` — ``m`` the subgroup size,
    ``b = n_v/m`` the post-exchange shard extent — and sliced along ``b``.
    Slice ``i``'s all-to-all splits the ``m`` factor across ranks and
    concatenates along ``w``, so rank ``r``'s slice ``i`` output is exactly
    rows ``[r*b + off_i, r*b + off_i + len_i)`` of the fused output:
    concatenating the slices along ``v`` reproduces ``fused`` bit for bit
    for lossless payloads (``comm_dtype=None``/``"complex64"``), while each
    slice remains a standalone collective XLA may overlap with unrelated
    compute.  (Under a lossy ``comm_dtype`` the slices quantize
    independently — different max-abs blocks than the fused engine — so the
    results agree only to the codec's error bound, not bitwise.)

    ``nbatch`` leading batch axes ride along whole in every slice
    (``v``/``w`` field-relative, as in :func:`exchange_shard`): each slice
    is still one collective carrying all fields' sub-range.

    ``guard=True`` returns ``(pieces, stats)``: one stats dict summed over
    all slices (each slice's codec counters added together).
    """
    names = group_names(group)
    axis_name = names[0] if len(names) == 1 else names
    m = _axis_size(axis_name)
    bv, bw = v + nbatch, w + nbatch
    nv = block.shape[bv]
    if nv % m != 0:
        raise ValueError(f"axis v={v} extent {nv} not divisible by group size {m}")
    b = nv // m
    sizes = [n for n in local_lengths(b, max(1, min(chunks, b))) if n > 0]
    # view v as (m, b); the concat axis shifts right if it follows v
    shape = list(block.shape)
    shape[bv : bv + 1] = [m, b]
    y = block.reshape(shape)
    w_eff = bw if bw < bv else bw + 1
    pieces = []
    stats = _health.zero_stats() if guard else None
    off = 0
    for n in sizes:
        piece = lax.slice_in_dim(y, off, off + n, axis=bv + 1)
        off += n
        r = _all_to_all_comm(piece, axis_name, split_axis=bv, concat_axis=w_eff,
                             comm_dtype=comm_dtype,
                             batch_axes=tuple(range(nbatch)), guard=guard,
                             impl=impl)
        if guard:
            p, s = r
            stats = _health.add_stats(stats, s)
        else:
            p = r
        # p's m-factor axis now has extent 1: merge (1, n) -> (n,)
        pshape = list(p.shape)
        pshape[bv : bv + 2] = [n]
        pieces.append(p.reshape(pshape))
    return (pieces, stats) if guard else pieces


def _axis_size(axis_name) -> int:
    return _mesh_axis_size(axis_name)


def exchange(
    x: jax.Array,
    src: Pencil,
    v: int,
    w: int,
    *,
    method: Method = "fused",
    chunks: int = 1,
    comm_dtype: CommDtype | None = None,
    impl: Impl = "jnp",
) -> tuple[jax.Array, Pencil]:
    """Jit-level v→w exchange of a globally-sharded array.

    ``x`` must be laid out per ``src``: axis ``v`` aligned (locally
    complete) and axis ``w`` distributed on *input*; the paper's Eq. (20)
    contract is that the output has the roles swapped — axis ``v``
    distributed over ``w``'s subgroup and axis ``w`` aligned.  Returns the
    redistributed array and its Pencil.
    """
    if not src.aligned(v):
        raise ValueError(f"input axis v={v} must be aligned; placement={src.placement}")
    group = src.placement[w]
    if group is None:
        raise ValueError(f"input axis w={w} must be distributed; placement={src.placement}")
    dst = src.exchanged(v, w)
    fn = shard_map(
        partial(exchange_shard, v=v, w=w, group=group, method=method,
                chunks=chunks, comm_dtype=comm_dtype, impl=impl),
        mesh=src.mesh,
        in_specs=src.spec,
        out_specs=dst.spec,
        check_vma=False,
    )
    return fn(x), dst


# ---------------------------------------------------------------------------
# Cost / time models (roofline + tuner priors)
# ---------------------------------------------------------------------------


def exchange_cost_bytes(src: Pencil, v: int, w: int) -> int:  # noqa: ARG001 — (src, v, w) parity with the exchange_* family
    """Elements each rank sends in the exchange (itemsize excluded): the
    full local block minus the chunk it keeps.  Identical for all methods —
    the element count is a property of the redistribution, not the engine.
    Used by the roofline model; see :func:`exchange_wire_bytes` for the
    actual wire bytes under a ``comm_dtype`` payload policy."""
    m = group_size(src.mesh, src.placement[w])  # type: ignore[arg-type]
    local = int(np.prod(src.local_shape, dtype=np.int64))
    return local * (m - 1) // m


def exchange_wire_bytes(
    src: Pencil, v: int, w: int, *, itemsize: int = 8,
    comm_dtype: CommDtype | None = None, nfields: int = 1, slices: int = 1,
) -> int:
    """Bytes each rank actually puts on the wire: the exchanged elements at
    the narrowed payload width (bf16 planes: itemsize/2; int8 planes:
    itemsize/4 plus one f32 scale per peer destination).  ``nfields``
    prices a stacked multi-field exchange: payload × N, and int8 ships one
    scale per (field, destination).  ``slices`` is the pipelined engine's
    collective count (see :func:`pipeline_slices`): the payload bytes are
    invariant to slicing, but each int8 slice quantizes independently and
    ships its own scale set."""
    d = canonical_comm_dtype(comm_dtype)
    total = exchange_cost_bytes(src, v, w) * nfields * itemsize // wire_ratio(d)
    if d == "int8":
        m = group_size(src.mesh, src.placement[w])  # type: ignore[arg-type]
        # per-(field, destination) f32 scales (kept chunk excluded)
        total += 4 * (m - 1) * nfields * max(1, slices)
    return total


def pipeline_slices(src: Pencil, v: int, w: int, *, chunks: int) -> int:
    """Number of independent all-to-all slices the pipelined engine emits
    for this exchange: ``min(chunks, b)`` nonempty pieces of the
    post-exchange shard extent ``b = n_v/m`` (mirrors the slicing loop in
    :func:`exchange_shard_sliced`, so planlint's expected-launch count and
    the executed collective count can never drift apart)."""
    m = group_size(src.mesh, src.placement[w])  # type: ignore[arg-type]
    b = src.local_shape[v] // m
    return len([n for n in local_lengths(b, max(1, min(chunks, b))) if n > 0])


def exchange_engine_ops(
    src: Pencil, v: int, w: int, *, method: Method = "fused", chunks: int = 1,
    transposed_out: bool = False, nbatch: int = 0,
    comm_dtype: CommDtype | None = None, impl: Impl = "jnp",
) -> dict[str, int]:
    """Materialized realignment ops (``transpose`` / ``concatenate`` jaxpr
    eqns) each engine's shard function emits *outside* the collective — the
    contract :mod:`repro.analysis.planlint` checks the lowered jaxpr
    against.

    ``fused`` emits none: the strided split/concat rides inside the single
    all-to-all (the paper's Sec. 3.3.2 claim, stated as an auditable
    count).  ``traditional`` pays its documented pack and unpack moveaxis
    copies — except when the moved axis is already leading (``v+nbatch ==
    0`` packs for free; ``w+nbatch == 0`` or ``transposed_out`` skips the
    unpack), where jnp.moveaxis is the identity and no transpose eqn
    exists.  ``pipelined`` emits one concatenate reassembling its slices
    whenever it actually slices (>1 pieces).

    ``impl="pallas"`` (where applicable: lossy payload, and for
    traditional no ``transposed_out``) folds traditional's pack/unpack
    into the exchange kernels' index maps — zero engine-attributed
    transposes, the no-realignment invariant planlint's PLAN009 verifies.
    Pipelined's slice-reassembly concatenate remains either way."""
    if method == "traditional":
        if (impl == "pallas" and not transposed_out
                and canonical_comm_dtype(comm_dtype) != "complex64"):
            return {"transposes": 0, "concats": 0}
        bv, bw = v + nbatch, w + nbatch
        t = int(bv != 0) + int(bw != 0 and not transposed_out)
        return {"transposes": t, "concats": 0}
    if method == "pipelined":
        s = pipeline_slices(src, v, w, chunks=chunks)
        return {"transposes": 0, "concats": int(s > 1)}
    if method == "fused":
        return {"transposes": 0, "concats": 0}
    raise ValueError(f"unknown method {method!r}")


def exchange_local_copy_elems(
    src: Pencil, v: int, w: int, *, method: Method = "fused",
    comm_dtype: CommDtype | None = None, impl: Impl = "jnp",
) -> int:  # noqa: ARG001 — (src, v, w) parity with the exchange_* family
    """Elements of *materialized local copies* the method pays on top of the
    wire payload and codec: traditional's pack+unpack transposes touch the
    local block twice; pipelined's final concat materializes it once; fused
    pays none (the layout change rides inside the collective).  Under
    ``impl="pallas"`` with a lossy payload, traditional's pack/unpack ride
    the codec kernels' index maps — the engine pays no copies of its own
    (pipelined's reassembly concat remains)."""
    local = int(np.prod(src.local_shape, dtype=np.int64))
    if impl == "pallas" and canonical_comm_dtype(comm_dtype) != "complex64":
        return {"fused": 0, "pipelined": local, "traditional": 0}.get(method, 0)
    return {"fused": 0, "pipelined": local, "traditional": 2 * local}.get(method, 0)


#: modeled fixed cost per issued collective (launch + rendezvous); the term
#: that makes per-field exchanges of many small fields latency-bound and a
#: stacked batched exchange win
ICI_LATENCY_S = 1e-6


def exchange_collective_launches(
    src: Pencil, v: int, w: int, *, method: Method = "fused",
    chunks: int = 1, nfields: int = 1, batch_fusion: str = "stacked",
) -> int:  # noqa: ARG001 — (src, v, w) parity with the exchange_* family
    """Number of latency-priced collective launches this exchange issues —
    exactly the multiplier :func:`exchange_time_model` applies to
    ``ici_latency_s``, stated as a count so the scaling harness can fit the
    latency coefficient against measurements (the int8 scale all-to-all is
    not latency-priced by the time model, so it is not counted here
    either; planlint's launch audit covers it instead).

    ``stacked`` (or a single field) issues one collective per exchange —
    ``chunks`` of them for a chunked pipelined engine; ``per-field`` and
    ``pipelined-across-fields`` both issue that count per field."""
    per_exchange = chunks if method == "pipelined" and chunks > 1 else 1
    n = max(1, nfields)
    if n == 1 or batch_fusion == "stacked":
        return per_exchange
    if batch_fusion in ("per-field", "pipelined-across-fields"):
        return n * per_exchange
    raise ValueError(f"unknown batch_fusion {batch_fusion!r}; expected one of {BATCH_FUSIONS}")


def exchange_time_model(
    src: Pencil,
    v: int,
    w: int,
    *,
    itemsize: int = 8,
    method: Method = "fused",
    chunks: int = 1,
    comm_dtype: CommDtype | None = None,
    ici_bw: float = 50e9,
    hbm_bw: float = 819e9,
    overlap_compute_s: float = 0.0,
    nfields: int = 1,
    batch_fusion: str = "stacked",
    ici_latency_s: float = ICI_LATENCY_S,
    impl: Impl = "jnp",
) -> float:
    """Overlap-aware modeled seconds for one exchange (+ the 1-D FFT stage
    that follows it, whose *per-field* time the caller passes as
    ``overlap_compute_s``).

    fused/traditional serialize collective then compute; pipelined with c
    slices exposes only the first slice's collective and the last slice's
    compute, overlapping the rest:

        T = c·T_lat + T_comm/c + max(T_comm, T_fft)·(c-1)/c + T_fft/c

    A narrowed ``comm_dtype`` shrinks T_comm to the wire bytes of
    :func:`exchange_wire_bytes` but adds two HBM passes over the local
    block (quantize before / dequantize after the collective).

    ``nfields`` fields ship under one of the ``batch_fusion`` modes:

    ``"stacked"``                  — one collective carries all N fields:
        1 latency, N× bytes/compute (wins when latency-bound).
    ``"pipelined-across-fields"``  — N collectives, field i's collective
        hidden under field i-1's FFT:
        T = N·T_lat + T_comm + (N-1)·max(T_comm, T_fft) + T_fft.
    ``"per-field"``                — N fully serialized exchange+FFT pairs
        (the baseline a per-field loop pays).
    """
    d = canonical_comm_dtype(comm_dtype)
    comm_s = exchange_wire_bytes(src, v, w, itemsize=itemsize, comm_dtype=d) / ici_bw
    copy_s = (exchange_local_copy_elems(src, v, w, method=method, comm_dtype=d,
                                        impl=impl) * itemsize / hbm_bw)
    if d != "complex64":
        # pallas: the codec is one lean pass per side (read wide + write
        # narrow / read narrow + write wide) — the scale reduction and any
        # pack realignment ride the same pass.  jnp: each side additionally
        # materializes the full-width re/im plane stack (the quantize pass
        # cannot fuse with the producer across its own amax reduction).
        local = int(np.prod(src.local_shape, dtype=np.int64))
        per_side = itemsize + itemsize // wire_ratio(d)
        if impl != "pallas":
            per_side += itemsize
        copy_s += 2 * local * per_side / hbm_bw

    def one(comm, fft):
        """One exchange of ``comm`` seconds of wire plus ``fft`` seconds of
        following compute, under the plan's engine."""
        if method == "pipelined" and chunks > 1:
            c = chunks
            return (c * ici_latency_s + comm / c
                    + max(comm, fft) * (c - 1) / c + fft / c)
        return ici_latency_s + comm + fft

    n = max(1, nfields)
    if n == 1 or batch_fusion == "stacked":
        return one(comm_s * n, overlap_compute_s * n) + copy_s * n
    if batch_fusion == "per-field":
        return n * (one(comm_s, overlap_compute_s) + copy_s)
    if batch_fusion == "pipelined-across-fields":
        # each field's exchange is emitted whole (chunked engines still
        # issue `chunks` collectives per field — price every launch)
        launches = n * (chunks if method == "pipelined" and chunks > 1 else 1)
        fft = overlap_compute_s
        return (launches * ici_latency_s + comm_s + (n - 1) * max(comm_s, fft)
                + fft + n * copy_s)
    raise ValueError(f"unknown batch_fusion {batch_fusion!r}; expected one of {BATCH_FUSIONS}")
