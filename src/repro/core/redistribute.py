"""Global redistribution (the paper's contribution — Sec. 3.3.2, Alg. 2/3).

Two implementations of the v→w exchange of a distributed array:

``method="fused"`` — the paper's method.  One ``lax.all_to_all`` with
    ``split_axis=v, concat_axis=w``: the strided split/concat description
    plays the role of MPI subarray datatypes, and the single collective is
    the analogue of ``MPI_ALLTOALLW``.  No local transpose materializes in
    user code; XLA:TPU's collective engine performs the strided
    gather/scatter as part of the exchange.

``method="traditional"`` — what P3DFFT/2DECOMP&FFT/FFTW-MPI do (paper
    Sec. 3.3.1, Eqs. 15–17): pack chunks contiguously with an explicit local
    transpose (a materialized copy), run a contiguous all-to-all on the
    leading chunk axis, then unpack with a second local transpose.  With
    ``transposed_out=True`` the unpack copy is skipped and the output keeps
    the permuted chunk-major layout (FFTW's "transposed out", Eq. 19) —
    callers must handle the layout.

Both operate *per shard* (inside ``shard_map``) via ``exchange_shard`` and
at the jit level on globally-sharded arrays via ``exchange``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.core.meshutil import shard_map
from repro.core.pencil import Group, Pencil, group_names, group_size

Method = str  # "fused" | "traditional"


def exchange_shard(
    block: jax.Array,
    v: int,
    w: int,
    group: Group,
    *,
    method: Method = "fused",
    transposed_out: bool = False,
) -> jax.Array:
    """Per-shard v→w exchange over mesh subgroup ``group``.

    Input block: axis ``v`` full (locally complete), axis ``w`` holds this
    rank's shard.  Output block: axis ``v`` holds this rank's shard, axis
    ``w`` full.  Mirrors the paper's EXCHANGE(P, A, v, B, w) (Alg. 3).
    """
    if v == w:
        raise ValueError("exchange requires v != w (paper Alg. 3)")
    names = group_names(group)
    axis_name = names[0] if len(names) == 1 else names

    if method == "fused":
        # The paper's method: one generalized all-to-all; the split/concat
        # axes are the "subarray datatype" description.
        return lax.all_to_all(block, axis_name, split_axis=v, concat_axis=w, tiled=True)

    if method == "traditional":
        m = _axis_size(axis_name)
        nv = block.shape[v]
        if nv % m != 0:
            raise ValueError(f"axis v={v} extent {nv} not divisible by group size {m}")
        # Eq. (15): reshape v -> (m, nv/m); stride change only, free.
        shape = list(block.shape)
        shape[v : v + 1] = [m, nv // m]
        y = block.reshape(shape)
        # Eq. (16): bring the chunk axis to the front — the materialized
        # local transpose (the costly pack step traditional codes pay for).
        y = jnp.moveaxis(y, v, 0)
        # Eq. (17)+ALLTOALL: contiguous exchange on the leading chunk axis.
        y = lax.all_to_all(y, axis_name, split_axis=0, concat_axis=0, tiled=True)
        # Unpack: leading chunk q now carries peer q's w-shard (global w order).
        if transposed_out:
            # FFTW "transposed out": keep chunk-major layout, caller handles it.
            return y
        # Insert the chunk axis just before w (chunk-major == global w order)
        # and merge (m, w_shard) -> w_full: the second materialized copy.
        z = jnp.moveaxis(y, 0, w)
        shape = list(z.shape)
        shape[w : w + 2] = [shape[w] * shape[w + 1]]
        return z.reshape(shape)

    raise ValueError(f"unknown method {method!r}")


def _axis_size(axis_name) -> int:
    size = lax.axis_size(axis_name)
    return int(size)


def exchange(
    x: jax.Array,
    src: Pencil,
    v: int,
    w: int,
    *,
    method: Method = "fused",
) -> tuple[jax.Array, Pencil]:
    """Jit-level v→w exchange of a globally-sharded array.

    ``x`` must be laid out per ``src`` (axis v aligned... no: axis v aligned
    on *output*).  Per paper Eq. (20): input has axis w distributed / axis v
    aligned; output has axis v distributed / axis w aligned.  Returns the
    redistributed array and its Pencil.
    """
    if not src.aligned(v):
        raise ValueError(f"input axis v={v} must be aligned; placement={src.placement}")
    group = src.placement[w]
    if group is None:
        raise ValueError(f"input axis w={w} must be distributed; placement={src.placement}")
    dst = src.exchanged(v, w)
    fn = shard_map(
        partial(exchange_shard, v=v, w=w, group=group, method=method),
        mesh=src.mesh,
        in_specs=src.spec,
        out_specs=dst.spec,
        check_vma=False,
    )
    return fn(x), dst


def exchange_cost_bytes(src: Pencil, v: int, w: int) -> int:
    """Bytes each rank sends in the exchange (itemsize excluded): the full
    local block minus the chunk it keeps.  Used by the roofline model."""
    import numpy as np

    m = group_size(src.mesh, src.placement[w])  # type: ignore[arg-type]
    local = int(np.prod(src.local_shape, dtype=np.int64))
    return local * (m - 1) // m
