"""Global redistribution (the paper's contribution — Sec. 3.3.2, Alg. 2/3).

Three implementations of the v→w exchange of a distributed array:

``method="fused"`` — the paper's method.  One ``lax.all_to_all`` with
    ``split_axis=v, concat_axis=w``: the strided split/concat description
    plays the role of MPI subarray datatypes, and the single collective is
    the analogue of ``MPI_ALLTOALLW``.  No local transpose materializes in
    user code; XLA:TPU's collective engine performs the strided
    gather/scatter as part of the exchange.

``method="traditional"`` — what P3DFFT/2DECOMP&FFT/FFTW-MPI do (paper
    Sec. 3.3.1, Eqs. 15–17): pack chunks contiguously with an explicit local
    transpose (a materialized copy), run a contiguous all-to-all on the
    leading chunk axis, then unpack with a second local transpose.  With
    ``transposed_out=True`` the unpack copy is skipped and the output keeps
    the permuted chunk-major layout (FFTW's "transposed out", Eq. 19) —
    callers must handle the layout.

``method="pipelined"`` — the fused exchange sliced into ``chunks`` pieces
    along the *post-exchange v shard* so each slice is an independent
    all-to-all whose output is one contiguous sub-range of the fused
    output.  The union of the slices is bit-identical to ``fused``; the
    point is scheduling freedom: a caller (``pfft._run_stages``) can
    interleave each slice's collective with the next stage's 1-D FFT on the
    previous slice, letting XLA overlap collective DMA with MXU/VPU compute
    instead of serializing exchange→transform.  This is the TPU analogue of
    the paper's note that the single-collective formulation "enables future
    speedups from optimizations in the internal datatype handling engines"
    (cf. partitioned/persistent-collective MPI FFTs, arXiv:2306.16589).

``method="auto"`` (plan level only, see :mod:`repro.core.tuner`) —
    micro-benchmarks {fused, traditional, pipelined×chunks} per exchange
    stage of a plan and caches the winning schedule on disk.

Both operate *per shard* (inside ``shard_map``) via ``exchange_shard`` and
at the jit level on globally-sharded arrays via ``exchange``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from repro.core.decomp import local_lengths
from repro.core.meshutil import axis_size as _mesh_axis_size, shard_map
from repro.core.pencil import Group, Pencil, group_names, group_size

Method = str  # "fused" | "traditional" | "pipelined"

#: chunk counts the tuner sweeps for the pipelined method
PIPELINE_CHUNK_CANDIDATES = (2, 4, 8)


def exchange_shard(
    block: jax.Array,
    v: int,
    w: int,
    group: Group,
    *,
    method: Method = "fused",
    chunks: int = 1,
    transposed_out: bool = False,
) -> jax.Array:
    """Per-shard v→w exchange over mesh subgroup ``group``.

    Input block: axis ``v`` full (locally complete), axis ``w`` holds this
    rank's shard.  Output block: axis ``v`` holds this rank's shard, axis
    ``w`` full.  Mirrors the paper's EXCHANGE(P, A, v, B, w) (Alg. 3).

    ``chunks`` only affects ``method="pipelined"``; ``transposed_out`` only
    affects ``method="traditional"``.
    """
    if v == w:
        raise ValueError("exchange requires v != w (paper Alg. 3)")
    names = group_names(group)
    axis_name = names[0] if len(names) == 1 else names

    if method == "fused":
        # The paper's method: one generalized all-to-all; the split/concat
        # axes are the "subarray datatype" description.
        return lax.all_to_all(block, axis_name, split_axis=v, concat_axis=w, tiled=True)

    if method == "pipelined":
        pieces = exchange_shard_sliced(block, v, w, group, chunks=chunks)
        return pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, axis=v)

    if method == "traditional":
        m = _axis_size(axis_name)
        nv = block.shape[v]
        if nv % m != 0:
            raise ValueError(f"axis v={v} extent {nv} not divisible by group size {m}")
        # Eq. (15): reshape v -> (m, nv/m); stride change only, free.
        shape = list(block.shape)
        shape[v : v + 1] = [m, nv // m]
        y = block.reshape(shape)
        # Eq. (16): bring the chunk axis to the front — the materialized
        # local transpose (the costly pack step traditional codes pay for).
        y = jnp.moveaxis(y, v, 0)
        # Eq. (17)+ALLTOALL: contiguous exchange on the leading chunk axis.
        y = lax.all_to_all(y, axis_name, split_axis=0, concat_axis=0, tiled=True)
        # Unpack: leading chunk q now carries peer q's w-shard (global w order).
        if transposed_out:
            # FFTW "transposed out": keep chunk-major layout, caller handles it.
            return y
        # Insert the chunk axis just before w (chunk-major == global w order)
        # and merge (m, w_shard) -> w_full: the second materialized copy.
        z = jnp.moveaxis(y, 0, w)
        shape = list(z.shape)
        shape[w : w + 2] = [shape[w] * shape[w + 1]]
        return z.reshape(shape)

    raise ValueError(f"unknown method {method!r}")


def exchange_shard_sliced(
    block: jax.Array,
    v: int,
    w: int,
    group: Group,
    *,
    chunks: int,
) -> list[jax.Array]:
    """The fused v→w exchange as ``chunks`` independent per-slice
    all-to-alls (the ``pipelined`` engine).

    The input's v axis is viewed as ``(m, b)`` — ``m`` the subgroup size,
    ``b = n_v/m`` the post-exchange shard extent — and sliced along ``b``.
    Slice ``i``'s all-to-all splits the ``m`` factor across ranks and
    concatenates along ``w``, so rank ``r``'s slice ``i`` output is exactly
    rows ``[r*b + off_i, r*b + off_i + len_i)`` of the fused output:
    concatenating the slices along ``v`` reproduces ``fused`` bit for bit,
    while each slice remains a standalone collective XLA may overlap with
    unrelated compute.
    """
    names = group_names(group)
    axis_name = names[0] if len(names) == 1 else names
    m = _axis_size(axis_name)
    nv = block.shape[v]
    if nv % m != 0:
        raise ValueError(f"axis v={v} extent {nv} not divisible by group size {m}")
    b = nv // m
    sizes = [n for n in local_lengths(b, max(1, min(chunks, b))) if n > 0]
    # view v as (m, b); the concat axis shifts right if it follows v
    shape = list(block.shape)
    shape[v : v + 1] = [m, b]
    y = block.reshape(shape)
    w_eff = w if w < v else w + 1
    pieces = []
    off = 0
    for n in sizes:
        piece = lax.slice_in_dim(y, off, off + n, axis=v + 1)
        off += n
        p = lax.all_to_all(piece, axis_name, split_axis=v, concat_axis=w_eff, tiled=True)
        # p's m-factor axis now has extent 1: merge (1, n) -> (n,)
        pshape = list(p.shape)
        pshape[v : v + 2] = [n]
        pieces.append(p.reshape(pshape))
    return pieces


def _axis_size(axis_name) -> int:
    return _mesh_axis_size(axis_name)


def exchange(
    x: jax.Array,
    src: Pencil,
    v: int,
    w: int,
    *,
    method: Method = "fused",
    chunks: int = 1,
) -> tuple[jax.Array, Pencil]:
    """Jit-level v→w exchange of a globally-sharded array.

    ``x`` must be laid out per ``src``: axis ``v`` aligned (locally
    complete) and axis ``w`` distributed on *input*; the paper's Eq. (20)
    contract is that the output has the roles swapped — axis ``v``
    distributed over ``w``'s subgroup and axis ``w`` aligned.  Returns the
    redistributed array and its Pencil.
    """
    if not src.aligned(v):
        raise ValueError(f"input axis v={v} must be aligned; placement={src.placement}")
    group = src.placement[w]
    if group is None:
        raise ValueError(f"input axis w={w} must be distributed; placement={src.placement}")
    dst = src.exchanged(v, w)
    fn = shard_map(
        partial(exchange_shard, v=v, w=w, group=group, method=method, chunks=chunks),
        mesh=src.mesh,
        in_specs=src.spec,
        out_specs=dst.spec,
        check_vma=False,
    )
    return fn(x), dst


# ---------------------------------------------------------------------------
# Cost / time models (roofline + tuner priors)
# ---------------------------------------------------------------------------


def exchange_cost_bytes(src: Pencil, v: int, w: int) -> int:
    """Elements each rank sends in the exchange (itemsize excluded): the
    full local block minus the chunk it keeps.  Identical for all methods —
    the wire payload is a property of the redistribution, not the engine.
    Used by the roofline model."""
    m = group_size(src.mesh, src.placement[w])  # type: ignore[arg-type]
    local = int(np.prod(src.local_shape, dtype=np.int64))
    return local * (m - 1) // m


def exchange_local_copy_elems(src: Pencil, v: int, w: int, *, method: Method = "fused") -> int:
    """Elements of *materialized local copies* the method pays on top of the
    wire payload: traditional's pack+unpack transposes touch the local block
    twice; pipelined's final concat materializes it once; fused pays none
    (the layout change rides inside the collective)."""
    local = int(np.prod(src.local_shape, dtype=np.int64))
    return {"fused": 0, "pipelined": local, "traditional": 2 * local}.get(method, 0)


def exchange_time_model(
    src: Pencil,
    v: int,
    w: int,
    *,
    itemsize: int = 8,
    method: Method = "fused",
    chunks: int = 1,
    ici_bw: float = 50e9,
    hbm_bw: float = 819e9,
    overlap_compute_s: float = 0.0,
) -> float:
    """Overlap-aware modeled seconds for one exchange (+ the 1-D FFT stage
    that follows it, whose time the caller passes as ``overlap_compute_s``).

    fused/traditional serialize collective then compute; pipelined with c
    slices exposes only the first slice's collective and the last slice's
    compute, overlapping the rest:

        T = T_comm/c + max(T_comm, T_fft)·(c-1)/c + T_fft/c
    """
    comm_s = exchange_cost_bytes(src, v, w) * itemsize / ici_bw
    copy_s = exchange_local_copy_elems(src, v, w, method=method) * itemsize / hbm_bw
    if method == "pipelined" and chunks > 1:
        c = chunks
        pipe = comm_s / c + max(comm_s, overlap_compute_s) * (c - 1) / c + overlap_compute_s / c
        return pipe + copy_s
    return comm_s + overlap_compute_s + copy_s
