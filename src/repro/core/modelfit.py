"""Fit the analytic time model's hardware coefficients to measured sweeps.

The paper's evaluation (Sec. 5) is scaling curves; ours additionally ships
an analytic model (:meth:`repro.core.pfft.ParallelFFT.model_time_s` built
on :func:`repro.core.redistribute.exchange_time_model`) with every point.
This module closes the loop: given a *series* of measured points (one
scaling sweep at varying device count / grid size), least-squares fit the
model's bandwidth and latency coefficients, compute per-point residuals,
and flag points the model misses by more than ``miss_factor`` — the
machine-readable report the tuner consumes as priors
(:func:`active_priors` → candidate pruning in
:func:`repro.core.tuner.tune_plan`).

The fit uses the model's *linear surrogate*: each point carries

* ``compute_s``  — the model's comm-free residual (FFT flops at the
  reference ``peak_flops`` plus codec/copy HBM passes at the reference
  ``hbm_bw``), i.e. ``model_time_s(ici_bw=huge, ici_latency_s=0)``;
* ``wire_bytes`` — bytes on the wire per device for the measured quantity
  (:meth:`~repro.core.pfft.ParallelFFT.comm_bytes_per_device`);
* ``launches``   — latency-priced collective launches
  (:meth:`~repro.core.pfft.ParallelFFT.model_collective_launches`);

and the fit solves ``measured ≈ compute_s + wire_bytes/ici_bw +
launches·ici_latency_s`` for ``(1/ici_bw, ici_latency_s)`` by ordinary
least squares with a nonnegativity clamp (a negative coefficient refits
the other alone).  The surrogate drops the pipelined engine's overlap
``max()`` credit — exactly the structural misses the >2× flagging is for.

Everything here is pure numpy + stdlib: the collector side of the scaling
harness (``benchmarks/scalebench.py``) runs it without touching jax, and
the per-point model terms are produced inside the per-device-count worker
subprocesses where the plan objects actually exist.
"""

from __future__ import annotations

import json
import math
import os
from pathlib import Path

import numpy as np

#: reference coefficients the model terms are evaluated at (mirrors the
#: defaults of exchange_time_model / model_time_s)
REFERENCE_COEFFS = {
    "ici_bw": 50e9,
    "hbm_bw": 819e9,
    "peak_flops": 197e12,
    "ici_latency_s": 1e-6,
}

#: a point whose measured/fitted ratio leaves [1/f, f] is a model miss
DEFAULT_MISS_FACTOR = 2.0


def _point_features(p: dict) -> tuple[float, float, float, float]:
    """(measured_s, compute_s, wire_bytes, launches) of one sweep point.

    Accepts both the nested scalebench form (``{"best_s": ..., "model":
    {"compute_s": ..., "wire_bytes_per_dev": ..., "launches": ...}}``) and
    an already-flat dict (the synthetic-series test form)."""
    model = p.get("model") or p
    return (float(p["best_s"] if "best_s" in p else p["measured_s"]),
            float(model["compute_s"]),
            float(model["wire_bytes_per_dev"]),
            float(model["launches"]))


def fit_series(points: list[dict], *, miss_factor: float = DEFAULT_MISS_FACTOR,
               ) -> dict:
    """Least-squares fit of (1/ici_bw, ici_latency_s) for one scaling
    series; returns a JSON-able dict with the fitted coefficients, per-point
    fitted times and residual ratios, and the flagged >``miss_factor``
    misses.

    ``points`` need ≥1 entries; with a single point only the bandwidth
    coefficient is fit (latency pinned to 0 — one equation cannot separate
    the two terms)."""
    feats = [_point_features(p) for p in points]
    meas = np.array([f[0] for f in feats])
    comp = np.array([f[1] for f in feats])
    bytes_ = np.array([f[2] for f in feats])
    launch = np.array([f[3] for f in feats])
    rhs = meas - comp

    def _solve(cols):
        a = np.stack(cols, axis=1)
        sol, *_ = np.linalg.lstsq(a, rhs, rcond=None)
        return sol

    beta = lat = 0.0
    # column-normalized rank probe (raw bytes dwarf launch counts; an
    # unscaled rank test would call any matrix rank-1)
    two_col = np.stack([bytes_ / max(bytes_.max(), 1.0),
                        launch / max(launch.max(), 1.0)], axis=1)
    if (len(points) >= 2 and np.ptp(bytes_) > 0 and np.ptp(launch) > 0
            and np.linalg.matrix_rank(two_col, tol=1e-6) == 2):
        # rank check: a series whose launches scale exactly with its bytes
        # (e.g. a uniform-chunked sweep) cannot separate the two terms —
        # attribute everything to bandwidth rather than splitting by the
        # minimum-norm accident
        beta, lat = _solve([bytes_, launch])
    if beta < 0 or lat < 0 or (beta == 0 and lat == 0):
        # clamp: refit the surviving single coefficient alone
        beta = lat = 0.0
        if bytes_.any():
            (beta,) = _solve([bytes_])
        if beta <= 0 and launch.any():
            beta = 0.0
            (lat,) = _solve([launch])
        beta, lat = max(beta, 0.0), max(lat, 0.0)
    fitted = comp + beta * bytes_ + lat * launch
    with np.errstate(divide="ignore", invalid="ignore"):
        resid = np.where(fitted > 0, meas / fitted, np.inf)
    log_err = np.log(np.clip(resid, 1e-30, None))
    misses = []
    per_point = []
    for i, p in enumerate(points):
        entry = {
            "ndev": p.get("ndev"),
            "shape": p.get("shape"),
            "measured_s": float(meas[i]),
            "fit_time_s": float(fitted[i]),
            "model_time_s": (p.get("model") or {}).get("time_s", p.get("model_time_s")),
            "residual": float(resid[i]),
        }
        per_point.append(entry)
        if not (1.0 / miss_factor <= resid[i] <= miss_factor):
            misses.append({**entry, "why": (
                "model underestimates (measured slower than fit)"
                if resid[i] > miss_factor else
                "model overestimates (measured faster than fit)")})
    return {
        "ici_bw": float(1.0 / beta) if beta > 0 else math.inf,
        "ici_latency_s": float(lat),
        "npoints": len(points),
        "miss_factor": miss_factor,
        "rmse_log": float(np.sqrt(np.mean(log_err**2))) if len(points) else 0.0,
        "points": per_point,
        "misses": misses,
    }


def fit_report(series_points: dict[str, list[dict]], *,
               device_kind: str | None = None, backend: str | None = None,
               miss_factor: float = DEFAULT_MISS_FACTOR) -> dict:
    """Fit every series and aggregate the finite fitted coefficients into
    one priors block (median across series — robust to a series whose
    sweep never stressed one of the terms)."""
    fits = {name: fit_series(pts, miss_factor=miss_factor)
            for name, pts in series_points.items() if pts}
    bws = [f["ici_bw"] for f in fits.values() if math.isfinite(f["ici_bw"])]
    lats = [f["ici_latency_s"] for f in fits.values() if f["ici_latency_s"] > 0]
    priors = {
        "ici_bw": float(np.median(bws)) if bws else REFERENCE_COEFFS["ici_bw"],
        "ici_latency_s": (float(np.median(lats)) if lats
                          else REFERENCE_COEFFS["ici_latency_s"]),
        # the surrogate holds these at reference; recorded so a prior
        # consumer prices the non-fitted terms consistently
        "hbm_bw": REFERENCE_COEFFS["hbm_bw"],
        "peak_flops": REFERENCE_COEFFS["peak_flops"],
    }
    n_misses = sum(len(f["misses"]) for f in fits.values())
    return {
        "schema": "modelfit-v1",
        "device_kind": device_kind,
        "backend": backend,
        "priors": priors,
        "n_misses": n_misses,
        "series": fits,
    }


# -- tuner priors -----------------------------------------------------------
#
# The fitted coefficients double as *tuner priors*: with a priors file
# armed (REPRO_MODEL_PRIORS), repro.core.tuner ranks each stage's candidate
# set by modeled time at the fitted coefficients and micro-benchmarks only
# the top-K — measurements steer the model, the model then prunes the sweep.


def default_priors_path() -> Path | None:
    """Priors are armed only via ``$REPRO_MODEL_PRIORS`` (an explicit
    opt-in: a stray priors file must never silently change what the tuner
    measures on an unrelated machine)."""
    env = os.environ.get("REPRO_MODEL_PRIORS")
    return Path(env) if env else None


def save_priors(report: dict, path: str | Path) -> Path:
    """Write a fit report (or a bare priors dict) where the tuner will find
    it; returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    return path


def load_priors(path: str | Path) -> dict | None:
    """The priors block of a fit report at ``path`` (or of a bare priors
    dict), or None for anything unusable — like the tuner cache, a corrupt
    priors file must never raise."""
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return None
    if not isinstance(data, dict):
        return None
    block = data.get("priors", data)
    if not isinstance(block, dict) or "ici_bw" not in block:
        return None
    out = dict(REFERENCE_COEFFS)
    for k in out:
        try:
            v = float(block.get(k, out[k]))
        except (TypeError, ValueError):
            return None
        if math.isfinite(v) and v > 0:
            out[k] = v
    return out


def active_priors() -> dict | None:
    """The armed priors, or None (the common case: no env override)."""
    path = default_priors_path()
    return load_priors(path) if path else None
